"""Serving launcher: batched prefill+decode (reduced config) or the
decode-cell dry-run on the production mesh.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --new 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
      --shape decode_32k --dry-run
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=12)
    args = ap.parse_args()

    if args.dry_run:
        import subprocess
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd))

    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.models.model import LM
    from repro.serve.engine import Engine

    cfg = get_reduced(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab)
    frontend = None
    if cfg.frontend is not None:
        frontend = jax.random.normal(
            key, (args.batch, cfg.frontend.n_positions,
                  cfg.frontend.d_frontend), jnp.float32)
    n_front = cfg.frontend.n_positions if cfg.family == "vlm" else 0
    engine = Engine(model, params,
                    t_max=args.prompt_len + n_front + args.new + 1)
    out = engine.generate(prompts, args.new, frontend=frontend)
    for b in range(args.batch):
        print(f"seq{b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
