"""Legacy objective-driven MCTS driver (paper §III-C).

The tree search itself now lives in :mod:`repro.search.mcts` as a
:class:`~repro.search.strategy.SearchStrategy`; this module keeps the
original ``MCTS(graph, n_streams, objective, seed).run(iterations)``
interface as a thin wrapper (propose one schedule, call the objective,
observe) so existing callers and tests are untouched. New code should
prefer ``repro.search.run_search`` with ``MCTSSearch``, which adds
batched + memoized evaluation.

The wrapper is sequence-identical to the pre-refactor implementation:
one selection/expansion/rollout per iteration, objective call, then
backpropagation, with the same RNG consumption order. Importing this
module emits a :class:`DeprecationWarning` (the tree search's real
home is :mod:`repro.search.mcts`; ``repro.core`` therefore loads it
lazily), so the shim can eventually be deleted —
tests/test_shims.py asserts the lazy names resolve to the
:mod:`repro.search.mcts` objects.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

warnings.warn(
    "repro.core.mcts is a deprecated legacy wrapper; use "
    "repro.search.MCTSSearch with repro.search.run_search (batched + "
    "memoized evaluation) instead of MCTS.run",
    DeprecationWarning, stacklevel=2)

from repro.core.dag import Graph, Schedule

__all__ = ["EXPLORATION_C", "MCTS", "MCTSResult", "Node"]


def __getattr__(name: str):
    # EXPLORATION_C / Node re-export lazily: importing repro.search at
    # module load would cycle (core -> search -> engine -> core) now
    # that the evaluation engine lives outside repro.search.
    if name in ("EXPLORATION_C", "Node"):
        import repro.search.mcts as _m
        return getattr(_m, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class MCTSResult:
    schedules: list[Schedule]
    times: list[float]
    root: "Node"


class MCTS:
    """Paper-faithful MCTS. ``objective`` maps a Schedule to a time."""

    def __init__(self, graph: Graph, n_streams: int,
                 objective: Callable[[Schedule], float],
                 seed: int = 0):
        from repro.search.mcts import MCTSSearch
        self.graph = graph
        self.n_streams = n_streams
        self.objective = objective
        self._search = MCTSSearch(graph, n_streams, seed=seed)
        self.schedules: list[Schedule] = []
        self.times: list[float] = []
        self._seen: set[tuple] = set()

    @property
    def root(self) -> Node:
        return self._search.root

    @property
    def rng(self):
        return self._search.rng

    def run(self, iterations: int) -> MCTSResult:
        for _ in range(iterations):
            batch = self._search.propose(1)
            if not batch:
                break
            schedule = batch[0]
            t = self.objective(schedule)
            key = schedule.key()
            if key not in self._seen:
                self._seen.add(key)
                self.schedules.append(schedule)
                self.times.append(t)
            self._search.observe(schedule, t)
        return MCTSResult(self.schedules, self.times, self._search.root)
