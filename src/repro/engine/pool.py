"""Process-pool evaluation backend: shard cache misses over workers.

Discrete-event simulations of distinct schedules are independent, so a
batch of canonical-unique cache misses shards cleanly over a
``multiprocessing`` pool. Everything stateful stays in the parent —
the memo cache, the ``cache_hits`` / ``cache_misses`` meters behind
``run_search(sim_budget=)``, and the (canonical key, draw index) noise
— so a pooled search is **bit-identical** to the serial backend: same
(features, labels, times), same budget accounting, any worker count
(tests/test_engine_pool.py locks this).

Workers are initialized once with (graph, machine, durations) — the
same precomputed duration table the parent would use, so worker math is
the serial simulator's math — then receive contiguous shards of each
miss batch as compact ``(k, 2, N)`` int32 canonical encodings (the
base class computes them for the cache keys anyway): shipping arrays
instead of pickled ``Schedule`` object trees keeps IPC cost below the
simulation cost it parallelizes. Workers rebuild the schedules and run
the serial discrete-event simulator; the canonical stream relabel is a
bijection, under which the simulator is exactly invariant (columns of
per-stream state permute), so results stay bit-identical to evaluating
the original schedules. Shards are dispatched via ``imap_unordered``
with an index tag — a straggler shard never serializes collection of
the others — and reassembled by index into the first-appearance miss
order the base class expects.

The default start method is ``forkserver`` (falling back to ``spawn``
where unavailable): the parent typically has JAX loaded — whose thread
pools make plain ``fork`` a documented deadlock hazard — while
``repro.core``'s import tree is deliberately JAX-free (lazy imports in
``core/executor.py``), so fresh workers start in well under a second
with nothing but numpy. Pass ``start_method="fork"`` explicitly for
single-threaded parents where inheriting the loaded modules is safe
and cheapest.
"""
from __future__ import annotations

import multiprocessing
import os
from typing import Sequence

import numpy as np

from repro.core.costmodel import Machine, simulate
from repro.core.dag import BoundOp, Graph, Schedule
from repro.engine.base import EvaluatorBase

_WORKER_STATE: tuple | None = None


def _init_worker(graph: Graph, machine: Machine,
                 durations: dict[str, float]) -> None:
    global _WORKER_STATE
    _WORKER_STATE = (graph, machine, durations, list(graph.ops))


def _simulate_shard(encoded: np.ndarray) -> list[float]:
    graph, machine, durations, names = _WORKER_STATE
    out = []
    for row in encoded:
        items = tuple(
            BoundOp(names[o], None if s < 0 else int(s))
            for o, s in zip(row[0], row[1]))
        out.append(simulate(graph, Schedule(items), machine,
                            durations=durations).makespan)
    return out


def _simulate_shard_indexed(task: tuple[int, np.ndarray]
                            ) -> tuple[int, list[float]]:
    """(shard index, encodings) -> (shard index, makespans).

    The index rides along so shards can be dispatched out of order
    (``imap_unordered``) and still be reassembled exactly.
    """
    idx, encoded = task
    return idx, _simulate_shard(encoded)


class PoolEvaluator(EvaluatorBase):
    """Evaluation backend fanning cache misses out to worker processes.

    ``n_workers=None`` uses the CPU count. Small miss batches (fewer
    than ``2 * min_shard`` schedules, i.e. not enough to give two
    shards a meaningful size) skip the pool entirely — IPC would cost
    more than the simulations. ``close()`` (or use as a context
    manager) tears the pool down; it is also re-created lazily after a
    close, so a closed evaluator still works.
    """

    backend = "pool"

    def __init__(self, graph: Graph, machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0,
                 n_workers: int | None = None, min_shard: int = 8,
                 start_method: str | None = None, **base_kwargs):
        super().__init__(graph, machine, noise_sigma, noise_seed,
                         **base_kwargs)
        if self.graph is None:
            raise TypeError(
                "the pool backend shards schedule simulations of a "
                f"Graph; design space {self.space.name!r} has no graph "
                "(use backend='sim' for spaces with an analytic cost, "
                "or 'wallclock' for kernel runners)")
        self.n_workers = n_workers or (os.cpu_count() or 2)
        self.min_shard = max(1, min_shard)
        if start_method is None:
            methods = multiprocessing.get_all_start_methods()
            start_method = "forkserver" if "forkserver" in methods \
                else "spawn"
        self.start_method = start_method
        self._pool = None

    def _ensure_pool(self):
        if self._pool is None:
            ctx = multiprocessing.get_context(self.start_method)
            self._pool = ctx.Pool(
                self.n_workers, initializer=_init_worker,
                initargs=(self.graph, self.machine, self._durations))
        return self._pool

    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        n = len(schedules)
        if n < self.min_shard * 2 or self.n_workers < 2:
            return _serial_measure(self.graph, self.machine,
                                   self._durations, schedules)
        n_shards = min(self.n_workers, max(2, n // self.min_shard))
        bounds = [n * k // n_shards for k in range(n_shards + 1)]
        shards = [encoded[bounds[k]:bounds[k + 1]]
                  for k in range(n_shards)]
        # imap_unordered instead of the map() barrier: each shard is
        # tagged with its index and collected as it finishes, so one
        # straggler shard no longer serializes result collection —
        # while reassembly by index keeps the output order (and
        # therefore the whole search) bit-identical to serial.
        parts: dict[int, list[float]] = {}
        for idx, part in self._ensure_pool().imap_unordered(
                _simulate_shard_indexed, list(enumerate(shards))):
            parts[idx] = part
        out: list[float] = []
        for idx in range(n_shards):
            out.extend(parts[idx])
        return out

    def close(self) -> None:
        """Graceful teardown: let in-flight shards finish, then reap.

        ``Pool.close()`` + ``join()`` — never ``terminate()`` here,
        which would kill workers mid-shard and lose paid simulations.
        Idempotent; the pool is re-created lazily on next use.
        """
        if self._pool is not None:
            self._pool.close()
            self._pool.join()
            self._pool = None
        super().close()

    def __del__(self):
        # Last-resort fallback only: at interpreter shutdown a graceful
        # close()+join() may deadlock on already-collected machinery,
        # so terminate() is correct *here* (and only here). Guard
        # everything — modules can be half torn down by the time
        # __del__ runs.
        try:
            pool = getattr(self, "_pool", None)
            if pool is not None:
                pool.terminate()
                pool.join()
                self._pool = None
        except Exception:
            pass


def _serial_measure(graph: Graph, machine: Machine,
                    durations: dict[str, float],
                    schedules: Sequence[Schedule]) -> list[float]:
    return [simulate(graph, s, machine, durations=durations).makespan
            for s in schedules]
