"""End-to-end training driver: data -> train_step -> checkpoints.

Default is CPU-sized (a ~10M-param smollm-family model, a few hundred
steps). ``--preset 100m`` selects a ~100M-parameter config for real
hardware; ``--arch`` trains any assigned architecture's reduced config.

Usage:
  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import dataclasses
import time

import jax

from repro.checkpoint.store import CheckpointStore
from repro.configs import ARCHS, get_reduced
from repro.data.pipeline import DataConfig, batch_for
from repro.ft.restart import LoopConfig, TrainLoop
from repro.ft.straggler import StragglerMonitor
from repro.models.config import ModelConfig
from repro.models.model import LM
from repro.optim.adamw import AdamW, warmup_cosine
from repro.train.step import make_train_step

PRESETS = {
    "cpu": ModelConfig(
        name="smol-cpu", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=1024, vocab=8192, mlp="swiglu"),
    "100m": ModelConfig(
        name="smol-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32768, mlp="swiglu"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="cpu")
    ap.add_argument("--arch", choices=ARCHS, default=None,
                    help="train an assigned arch's reduced config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-ckpt")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.arch else PRESETS[args.preset]
    model = LM(cfg)
    print(f"model {cfg.name}: {model.n_params():,} params")

    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(args.lr, 20, args.steps))
    opt_state = opt.init(params)
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab, packed=True)
    step = jax.jit(make_train_step(model, opt,
                                   microbatches=args.microbatches))

    monitor = StragglerMonitor()
    loop = TrainLoop(
        step, lambda s: batch_for(dcfg, s, cfg),
        CheckpointStore(args.ckpt_dir),
        LoopConfig(total_steps=args.steps, ckpt_every=50,
                   log_every=10),
        monitor=monitor)
    t0 = time.perf_counter()
    params, opt_state = loop.run(params, opt_state)
    wall = time.perf_counter() - t0
    for h in loop.history:
        print(f"step {int(h['step']):5d}  loss {h['loss']:.4f}  "
              f"ce {h['ce']:.4f}")
    tok = args.steps * args.batch * args.seq
    print(f"{args.steps} steps, {tok:,} tokens in {wall:.1f}s "
          f"({tok / wall:,.0f} tok/s)")
    rep = monitor.report()
    print("stragglers:", rep.slow_ranks if rep else "none")


if __name__ == "__main__":
    main()
