"""Logical-axis sharding rules engine (see DESIGN.md §5).

Arrays are described by *logical* axis names ("batch", "d_ff", ...);
a rules dict maps logical names to mesh axes. :func:`spec_for` resolves
names to a :class:`jax.sharding.PartitionSpec` with three safeguards:

  * every mesh axis is used by at most one array dimension (first dim
    in order wins; a later dim whose rule names a taken axis shards on
    the rule's remaining untaken axes, or replicates if none are left),
  * a dimension only shards if its size divides the product of its mesh
    axes (non-divisible dims silently replicate — e.g. a global batch
    of 1, or 15 heads on a 16-way model axis),
  * rule entries naming mesh axes absent from the current mesh are
    silently dropped (so one rules dict serves single-pod and
    multi-pod meshes).

Baseline scheme: TP over "model" (heads / d_ff / vocab / experts),
batch over ("pod", "data"); per-cell overrides (FSDP, KV fallbacks)
come from ``repro.launch.inputs.rules_for``.

:func:`constrain` is the model-internal activation hook: a no-op unless
a :func:`activation_sharding` context is active (models stay mesh-
agnostic; the launch layer binds the context per cell).
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Any, Iterable, Mapping, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "kv_seq": "data",
    "heads": "model",
    "kv_heads": "model",
    "heads_x_dim": "model",
    "d_ff": "model",
    "d_inner": "model",
    "vocab": "model",
    "experts": "model",
    "kv_stored": "model",
}


def _as_axes(value: Any) -> tuple[str, ...]:
    if value is None:
        return ()
    if isinstance(value, str):
        return (value,)
    return tuple(value)


def _merged_rules(rules: Mapping[str, Any] | None) -> dict[str, tuple]:
    out = {k: _as_axes(v) for k, v in DEFAULT_RULES.items()}
    if rules:
        out.update({k: _as_axes(v) for k, v in rules.items()})
    return out


def spec_for(shape: Sequence[int], names: Sequence[str | None],
             mesh, rules: Mapping[str, Any] | None = None) -> P:
    """PartitionSpec for an array of ``shape`` with logical ``names``.

    ``mesh`` only needs ``axis_names`` and ``devices.shape`` (tests use
    a lightweight stand-in; real code passes :class:`jax.sharding.Mesh`).
    """
    merged = _merged_rules(rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    taken: set[str] = set()
    entries: list[Any] = []
    for dim, name in zip(shape, names):
        axes = [a for a in merged.get(name, ())
                if a in sizes and a not in taken] if name else []
        total = math.prod(sizes[a] for a in axes) if axes else 1
        if axes and dim % total == 0:
            taken.update(axes)
            entries.append(tuple(axes) if len(axes) > 1 else axes[0])
        else:
            entries.append(None)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def tree_shardings(axes_tree, mesh, rules: Mapping[str, Any] | None,
                   shapes_tree):
    """NamedSharding pytree for ``shapes_tree``.

    ``axes_tree`` mirrors ``shapes_tree`` with tuples of logical names
    (or None for fully replicated leaves) in place of arrays.
    """
    is_names = lambda x: x is None or (  # noqa: E731
        isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                     for a in x))
    ax_leaves = jax.tree.flatten(axes_tree, is_leaf=is_names)[0]
    sh_leaves, sh_def = jax.tree.flatten(shapes_tree)
    if len(ax_leaves) != len(sh_leaves):
        raise ValueError(
            f"axes tree has {len(ax_leaves)} leaves, shapes tree "
            f"{len(sh_leaves)}")
    out = []
    for names, leaf in zip(ax_leaves, sh_leaves):
        if names is None:
            names = (None,) * len(leaf.shape)
        out.append(NamedSharding(
            mesh, spec_for(leaf.shape, names, mesh, rules)))
    return jax.tree.unflatten(sh_def, out)


def batch_spec(mesh, extra_dims: int = 1,
               rules: Mapping[str, Any] | None = None,
               batch_size: int | None = None) -> P:
    """Spec for a (batch, ...) array: dim 0 on the batch axes, the
    ``extra_dims`` trailing dims replicated.

    When ``batch_size`` is known, a non-divisible batch replicates
    (the spec_for safeguard); when unknown, the caller owns ensuring
    the batch divides the mesh's batch axes.
    """
    merged = _merged_rules(rules)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in merged.get("batch", ()) if a in sizes]
    if batch_size is not None and axes and \
            batch_size % math.prod(sizes[a] for a in axes) != 0:
        axes = []
    if not axes:
        return P()
    entry = tuple(axes) if len(axes) > 1 else axes[0]
    return P(entry, *(None,) * extra_dims)


# -- activation-sharding context --------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh, rules: Mapping[str, Any] | None):
    """Bind (mesh, rules) so model-internal :func:`constrain` calls
    resolve; contexts nest (innermost wins)."""
    stack = getattr(_ctx, "stack", None)
    if stack is None:
        stack = _ctx.stack = []
    stack.append((mesh, rules))
    try:
        yield
    finally:
        stack.pop()


def constrain(x, names: Iterable[str | None]):
    """Apply a logical sharding constraint to activation ``x``.

    No-op (returns ``x`` unchanged) outside an
    :func:`activation_sharding` context, so models run un-jitted and
    un-meshed in unit tests.
    """
    stack = getattr(_ctx, "stack", None)
    if not stack:
        return x
    mesh, rules = stack[-1]
    spec = spec_for(x.shape, tuple(names), mesh, rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))
