"""Elastic re-meshing: resume a checkpoint under a different mesh.

Checkpoints store logical (unsharded) arrays, so scaling from e.g.
(data=16, model=16) to (data=14, model=16) after losing nodes is a
re-placement: rebuild shardings from the same logical-axis rules against
the new mesh and ``device_put``. Divisibility degradation is handled by
the rules engine (a dim that no longer divides is replicated rather than
failing). The expensive part on a real cluster — moving bytes — is
exactly what ``device_put`` to the new sharding expresses.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
from jax.sharding import Mesh

from repro.dist import sharding as shd


def remesh_state(state, axes_tree, new_mesh: Mesh,
                 rules: Mapping[str, Any] | None = None):
    """Re-place a (params-like) pytree under ``new_mesh``."""
    shapes = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
    shardings = shd.tree_shardings(axes_tree, new_mesh, rules, shapes)
    return jax.tree.map(jax.device_put, state, shardings)


def degraded_mesh(devices, axis_names: tuple[str, ...],
                  lost: int) -> Mesh:
    """Largest rectangular mesh after losing ``lost`` devices.

    Shrinks the leading (data) axis — the standard recovery shape — and
    drops the remainder devices.
    """
    import numpy as np
    devs_nd = np.asarray(devices)
    n = devs_nd.size - lost
    rest = 1
    # Keep trailing axes' extents; shrink axis 0.
    # Caller passes the original mesh shape via devices ndarray.
    devs = devs_nd.reshape(-1)
    shape = list(devs_nd.shape)
    for s in shape[1:]:
        rest *= s
    lead = n // rest
    if lead < 1:
        raise ValueError("not enough devices left for the mesh")
    keep = lead * rest
    return Mesh(devs[:keep].reshape(lead, *shape[1:]), axis_names)
