"""The :class:`DesignSpace` protocol: what a search needs from a space.

Everything upstream of measurement — strategies proposing candidates,
the evaluator's canonical memo keys, the persistent store's content
addresses, the rules pipeline's feature vectors — used to be written
against one candidate type, the paper's :class:`~repro.core.dag.
Schedule` over a :class:`~repro.core.dag.Graph`. This module factors
that coupling into an explicit protocol so the same stack searches any
parameterized design:

  * **identity** — ``encode_batch`` turns candidates into canonical
    int32 rows whose bytes are the cache/store keys (stream-bijection
    normal form for schedules, value indices for parameter grids);
    ``candidate_key``/``tie_key`` are the per-candidate hashable and
    total-order forms.
  * **moves** — sequential construction (``moves``/``finalize``, what
    MCTS expands), whole-candidate sampling (``random_candidate``),
    elite mutation (``mutate``) and full enumeration
    (``enumerate_candidates``) for the strategies.
  * **featurization** — ``feature_basis``/``featurize``/
    ``apply_features`` produce the binary feature matrices the
    surrogates train on and the rules pipeline distills
    (order/stream pairs for schedules, value thresholds for
    parameters), so ``distill`` emits design rules for any space.
  * **evaluation support** — ``fingerprint`` is the persistent-store
    content address (:mod:`repro.engine.store`), ``durations`` the
    analytic per-op table, ``analytic_cost`` the simulation objective
    where one exists.

The paper's schedule spaces are the first registered instance
(:class:`~repro.space.schedule.ScheduleSpace` — bit-compatible with
the pre-protocol pipeline, locked by tests/test_design_space.py); the
repo's own Pallas kernel parameter grids
(:mod:`repro.kernels.autotune`) are the first non-graph ones.

:func:`as_space` is the compatibility seam: every public entry point
(``run_search``, ``make_evaluator``, ``distill``, the surrogates)
accepts a :class:`~repro.core.dag.Graph` or a :class:`DesignSpace`
and normalizes through it, so existing graph-first code is untouched.
"""
from __future__ import annotations

import random
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from repro.core.dag import Graph


class DesignSpace:
    """A searchable space of candidate designs (see module docstring).

    Subclasses must implement the identity block (``encode_batch``,
    ``candidate_key``, ``tie_key``), the move block (``moves``,
    ``move_key``, ``finalize``, ``candidate_moves``,
    ``enumerate_candidates``), the featurization block
    (``feature_basis``, ``featurize``, ``apply_features``) and
    ``fingerprint``; ``random_candidate`` and ``mutate`` have generic
    defaults built on the move block, and ``durations`` /
    ``analytic_cost`` default to "no analytic model".
    """

    name: str = "abstract"

    # -- identity ----------------------------------------------------------
    def encode_batch(self, candidates: Sequence[Any]
                     ) -> tuple[list[bytes], np.ndarray]:
        """(cache keys, canonical int32 encoding) for a candidate batch.

        Row ``i`` of the array is candidate ``i``'s canonical encoding;
        ``keys[i]`` is that row's bytes — the memo-cache and persistent-
        store key. Must be a pure function of the candidate (never of
        batch order or history).
        """
        raise NotImplementedError

    def decode_batch(self, enc: np.ndarray) -> list:
        """Candidates back from canonical ``encode_batch`` rows.

        The inverse of ``encode_batch`` on its own output: accepts the
        ``(B, ...)`` int32 array (or the flattened per-row form — the
        cache-key bytes reinterpreted) and returns the canonical
        candidate each row denotes. This is what lets an out-of-core
        sink store only compact encodings and re-featurize blocks on
        the fly. Optional — spaces that never feed a histogram sink
        need not implement it.
        """
        raise NotImplementedError(
            f"design space {self.name!r} cannot decode encodings")

    def candidate_key(self, candidate: Any):
        """Hashable canonical identity of one candidate (dedup key)."""
        raise NotImplementedError

    def tie_key(self, candidate: Any) -> tuple:
        """Total order on canonical encodings (deterministic
        tie-breaking for ``SearchResult.best``)."""
        raise NotImplementedError

    def describe(self, candidate: Any) -> str:
        """Human-readable one-liner for reports and logs."""
        return repr(candidate)

    # -- moves -------------------------------------------------------------
    def moves(self, prefix: list) -> list:
        """Legal next moves extending ``prefix`` (empty = complete).

        Sequential construction is the one move model every strategy
        shares: MCTS expands over it, rollouts/mutations complete
        through it, and a complete prefix ``finalize``\\ s into a
        candidate. Every candidate built through ``moves`` must be
        canonical (its ``candidate_key`` equals that of any equivalent
        construction).
        """
        raise NotImplementedError

    def move_key(self, move) -> tuple | Any:
        """Hashable identity of one move (MCTS child key)."""
        raise NotImplementedError

    def finalize(self, prefix: list) -> Any:
        """The candidate a complete move prefix denotes."""
        raise NotImplementedError

    def candidate_moves(self, candidate: Any) -> Sequence:
        """The move sequence that constructs ``candidate`` (the inverse
        of ``finalize``; MCTS path materialization)."""
        raise NotImplementedError

    def enumerate_candidates(self) -> Iterator[Any]:
        """Every candidate, in the space's canonical order."""
        raise NotImplementedError

    def random_candidate(self, rng: random.Random) -> Any:
        """Uniform random completion through ``moves`` (rollout policy).

        The default consumes ``rng`` exactly like the historical
        ``random_schedule`` helper — one ``rng.choice`` per move — so
        schedule-space searches stay bit-identical.
        """
        prefix: list = []
        while True:
            options = self.moves(prefix)
            if not options:
                return self.finalize(prefix)
            prefix.append(rng.choice(options))

    def mutate(self, candidate: Any, rng: random.Random) -> Any:
        """Truncate at a random point and recomplete randomly.

        The elite-mutation move of :class:`~repro.search.surrogate.
        SurrogateGuided`; the default matches its historical RNG
        consumption (one ``randrange`` for the cut, one ``choice`` per
        rebuilt move) bit for bit.
        """
        seq = list(self.candidate_moves(candidate))
        cut = rng.randrange(1, len(seq)) if len(seq) > 1 else 0
        prefix = seq[:cut]
        while True:
            options = self.moves(prefix)
            if not options:
                return self.finalize(prefix)
            prefix.append(rng.choice(options))

    # -- featurization -----------------------------------------------------
    def feature_basis(self):
        """Incremental featurizer: ``.add(candidates)`` absorbs,
        ``.matrix()`` emits the constant-pruned
        :class:`~repro.core.features.FeatureMatrix`."""
        raise NotImplementedError

    def featurize(self, candidates: Sequence[Any]):
        """Constant-pruned feature matrix for a candidate corpus.

        Raises :class:`~repro.core.features.DegenerateFeatureSpaceError`
        when no discriminating feature survives pruning.
        """
        raise NotImplementedError

    def apply_features(self, candidates: Sequence[Any],
                       features: list) -> np.ndarray:
        """Evaluate an explicit feature list on new candidates
        (classify-the-full-space / surrogate-predict path)."""
        raise NotImplementedError

    def feature_universe(self):
        """Names-only candidate-feature tracker for out-of-core
        corpora: ``.add(candidates)`` absorbs (O(1) memory per
        candidate), ``.candidate_features()`` lists the unpruned
        feature list in the basis order, ``.merge(other)`` unions two
        hosts' universes. Optional — only histogram sinks need it.
        """
        raise NotImplementedError(
            f"design space {self.name!r} has no feature universe")

    # -- evaluation support ------------------------------------------------
    def durations(self, machine) -> dict:
        """Per-op analytic duration table (empty when inapplicable)."""
        return {}

    def fingerprint(self, machine, durations: dict,
                    objective: str) -> bytes:
        """16-byte content address of *what a stored base time means*
        in this space (see :mod:`repro.engine.store`). Everything that
        determines the ``canonical key -> base time`` mapping must be
        hashed; spaces with different candidates, problem instances, or
        objectives must never collide.
        """
        raise NotImplementedError

    def analytic_cost(self, candidate: Any, machine,
                      durations: dict) -> float:
        """The analytic-model objective, where the space has one."""
        raise NotImplementedError(
            f"design space {self.name!r} has no analytic cost model; "
            "evaluate it with the wallclock backend")


# -- the registry -------------------------------------------------------------

SPACES: dict[str, Callable[..., DesignSpace]] = {}
"""Design-space factories: name -> ``factory(**kwargs) -> DesignSpace``."""


def register_space(name: str,
                   factory: Callable[..., DesignSpace]) -> None:
    """Add (or replace) a design-space factory under ``name``."""
    SPACES[name] = factory


def make_space(name: str, **kwargs) -> DesignSpace:
    """Construct a registered design space by name."""
    try:
        factory = SPACES[name]
    except KeyError:
        raise ValueError(
            f"unknown design space {name!r}; registered: "
            f"{sorted(SPACES)}") from None
    return factory(**kwargs)


def as_space(obj, n_streams: int | None = None) -> DesignSpace:
    """Normalize ``Graph``-or-``DesignSpace`` to a :class:`DesignSpace`.

    The compatibility seam behind every public graph-first signature:
    a :class:`~repro.core.dag.Graph` wraps into a
    :class:`~repro.space.schedule.ScheduleSpace` (``n_streams``
    defaults to 2, the paper's setting); a space passes through
    (``n_streams`` must then be None — the space already fixed it).
    """
    if isinstance(obj, DesignSpace):
        if n_streams is not None:
            raise TypeError(
                f"n_streams={n_streams} conflicts with the explicit "
                f"design space {obj.name!r} (which already fixes its "
                "move structure); pass one or the other")
        return obj
    if isinstance(obj, Graph):
        from repro.space.schedule import ScheduleSpace
        return ScheduleSpace(obj, 2 if n_streams is None else n_streams)
    raise TypeError(
        f"expected a Graph or DesignSpace, got {type(obj).__name__!r}")
