"""engine.pool: sharded evaluation is byte-identical to serial.

The memo cache, budget meters, and noise live in the parent, so a
pooled search must reproduce the serial backend exactly — same
(features, labels, times), same ``sim_budget`` accounting — for any
worker count. Worker processes rebuild schedules from the compact
canonical encodings, whose stream relabel the simulator is invariant
under; this is what the identity here locks.
"""
import random

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro.core.dag import spmv_dag_fine
from repro.search.strategy import random_schedule


@pytest.fixture(scope="module")
def pool_ev():
    g = spmv_dag_fine()
    with E.make_evaluator(g, "pool", n_workers=2, min_shard=1) as ev:
        yield g, ev


def test_pool_bit_identical_to_serial(pool_ev):
    g, ev = pool_ev
    rng = random.Random(7)
    scheds = [random_schedule(g, 2, rng) for _ in range(64)]
    assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]


def test_pool_accounting_matches_serial():
    g = spmv_dag_fine()
    rng = random.Random(8)
    scheds = [random_schedule(g, 2, rng) for _ in range(40)]
    batch = scheds + scheds[:10]
    ser = E.make_evaluator(g, "sim")
    with E.make_evaluator(g, "pool", n_workers=2, min_shard=1) as ev:
        assert ev.evaluate(batch) == ser.evaluate(batch)
        assert (ev.cache_hits, ev.cache_misses) == \
            (ser.cache_hits, ser.cache_misses)
        assert len(ev) == len(ser)


def test_run_search_pool_byte_identical_dataset():
    """The acceptance lock: run_search(backend='pool') returns
    byte-identical (features, labels, times) to the serial backend at
    equal sim_budget."""
    g = spmv_dag_fine()
    datasets = {}
    for backend, kwargs in (("sim", {}),
                            ("pool", {"n_workers": 2, "min_shard": 1})):
        res = S.run_search(g, S.MCTSSearch(g, 2, seed=5), budget=None,
                           sim_budget=60, batch_size=8,
                           backend=backend, backend_kwargs=kwargs)
        datasets[backend] = (res, *res.dataset())
    res_a, fm_a, lab_a, t_a = datasets["sim"]
    res_b, fm_b, lab_b, t_b = datasets["pool"]
    assert t_a.tobytes() == t_b.tobytes()
    assert fm_a.X.tobytes() == fm_b.X.tobytes()
    assert fm_a.names() == fm_b.names()
    assert np.array_equal(lab_a.labels, lab_b.labels)
    assert (res_a.cache_hits, res_a.cache_misses) == \
        (res_b.cache_hits, res_b.cache_misses)


def test_pool_noise_identical_to_serial_noise():
    """(canonical key, draw index) noise seeding: pooled noisy
    evaluation equals serial noisy evaluation exactly."""
    g = C.spmv_dag()
    rng = random.Random(3)
    scheds = [random_schedule(g, 2, rng) for _ in range(24)]
    with E.make_evaluator(g, "pool", n_workers=2, min_shard=1,
                          noise_sigma=0.05, noise_seed=11) as pooled:
        noisy_pool = pooled.evaluate(scheds)
    ser = E.make_evaluator(g, "sim", noise_sigma=0.05, noise_seed=11)
    assert noisy_pool == ser.evaluate(scheds)


def test_pool_close_is_reentrant(pool_ev):
    g, _ = pool_ev
    ev = E.make_evaluator(g, "pool", n_workers=2, min_shard=1)
    rng = random.Random(9)
    scheds = [random_schedule(g, 2, rng) for _ in range(8)]
    first = ev.evaluate(scheds)
    ev.close()
    ev.close()  # idempotent
    # Lazily re-created after close; cache still warm.
    assert ev.evaluate(scheds) == first
    assert ev.cache_hits == len(scheds)
    ev.close()


def test_pool_stats_reports_backend(pool_ev):
    g, ev = pool_ev
    st = ev.stats()
    assert st["backend"] == "pool"
    assert set(st) == {"backend", "memory_hits", "store_hits", "misses",
                       "size", "hit_rate"}


def test_pool_close_is_graceful_and_del_safe():
    """close() must drain (close+join), never terminate, and __del__
    must be a no-op after an explicit close."""
    g = spmv_dag_fine()
    ev = E.make_evaluator(g, "pool", n_workers=2, min_shard=1)
    rng = random.Random(11)
    scheds = [random_schedule(g, 2, rng) for _ in range(16)]
    first = ev.evaluate(scheds)
    pool = ev._pool
    assert pool is not None
    ev.close()
    # Graceful teardown leaves completed results intact and the pool
    # object joined; the evaluator re-creates a pool lazily.
    assert ev._pool is None
    assert ev.evaluate(scheds) == first
    ev.close()
    ev.__del__()                        # guarded: must never raise
