"""The paper's technique as a framework feature: discover collective-
overlap design rules for OUR OWN train step.

The LM train step decomposes into an op-DAG (per-layer fwd/bwd compute,
per-layer gradient reduce-scatters, the optimizer update). "Streams" are
the TPU compute stream + ICI channels. The search portfolio (greedy
seeding → MCTS refinement → surrogate-screened exploitation) + the
machine model search the (emission order x channel assignment) space;
the decision tree then emits human-readable rules like "rs0 before
bwd2" or "rs1 different stream than bwd1" — exactly the paper's
output, for a 2026 workload.

Usage: PYTHONPATH=src python examples/schedule_search.py
           [--arch qwen2.5-32b] [--layers 4] [--iters 600]
           [--strategy portfolio|mcts] [--backend sim|vectorized|pool]
           [--surrogate ridge|boost]
           [--acquisition argmin_topk|ucb|expected_improvement]
           [--rules [PATH]] [--store PATH]
"""
import argparse

import repro.rules as R
import repro.search as S
from repro.configs import get_config
from repro.driver import ACQUISITIONS
from repro.core.stepdag import StepCosts, train_step_dag, \
    with_comm_durations
from repro.launch.costs import HBM_BW, LINK_BW, PEAK_FLOPS


def costs_from_arch(arch: str, layers: int, tokens_per_chip: int,
                    tp: int = 16, dp: int = 16) -> StepCosts:
    cfg = get_config(arch)
    n_per_layer = cfg.active_param_count() / cfg.n_layers
    # Per-chip, per-(coarsened)-layer costs; `layers` coarse stages.
    coarse = cfg.n_layers / layers
    fwd_flops = 2 * n_per_layer * tokens_per_chip * coarse / tp
    fwd_bytes = fwd_flops / 50.0          # ~50 flops/byte at bf16
    grad_bytes = n_per_layer * coarse * 4 / tp * (dp - 1) / dp
    return StepCosts(fwd_flops=fwd_flops, bwd_flops=2 * fwd_flops,
                     fwd_bytes=fwd_bytes, bwd_bytes=2 * fwd_bytes,
                     grad_bytes=grad_bytes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--layers", type=int, default=4,
                    help="coarse pipeline stages in the DAG")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--strategy", choices=("portfolio", "mcts"),
                    default="portfolio",
                    help="portfolio = greedy seeding + MCTS refinement "
                         "+ surrogate-screened exploitation")
    ap.add_argument("--backend", choices=("sim", "vectorized", "pool"),
                    default="sim",
                    help="evaluation engine (repro.engine registry); "
                         "all analytic backends are bit-identical — "
                         "this is a pure throughput choice (wallclock "
                         "additionally needs op impls; see "
                         "src/repro/engine/README.md)")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="schedules per propose() call; default 1 for "
                         "the sim backend (the paper's strictly "
                         "sequential loop) and 32 for vectorized/pool, "
                         "which only amortize across batches")
    ap.add_argument("--surrogate", choices=tuple(sorted(S.SURROGATES)),
                    default="ridge",
                    help="screening model for the portfolio's "
                         "exploitation phase (repro.search surrogate "
                         "registry; 'boost' = gradient-boosted trees)")
    ap.add_argument("--acquisition",
                    choices=tuple(sorted(ACQUISITIONS)),
                    default="argmin_topk",
                    help="how the candidate pool is ranked "
                         "(repro.driver acquisition registry; ucb / "
                         "expected_improvement add the boosted "
                         "ensemble's per-tree uncertainty — pair them "
                         "with --surrogate boost)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="persistent content-addressed evaluation "
                         "store (repro.engine.EvalStore): base times "
                         "measured this run are appended, and a later "
                         "run on the same graph/machine replays them "
                         "as store hits without re-simulating — "
                         "warm-start across processes and backends")
    ap.add_argument("--rules", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="render the full design-rule report "
                         "(repro.rules.distill) to PATH, or to stdout "
                         "when given without a value")
    args = ap.parse_args()
    if args.batch_size is None:
        args.batch_size = 1 if args.backend == "sim" else 32

    costs = costs_from_arch(args.arch, args.layers,
                            tokens_per_chip=16 * 4096 // 16)
    graph = with_comm_durations(train_step_dag(args.layers, costs),
                                LINK_BW)
    print(f"train-step DAG for {args.arch}: {graph.n_vertices()} ops, "
          f"{args.layers} stages")

    if args.strategy == "portfolio":
        strategy = S.PortfolioSearch(graph, args.channels, seed=0,
                                     surrogate=args.surrogate,
                                     acquisition=args.acquisition)
    else:
        strategy = S.MCTSSearch(graph, args.channels, seed=0)
    res = S.run_search(graph, strategy, budget=args.iters,
                       backend=args.backend, batch_size=args.batch_size,
                       store_path=args.store)
    times = res.times_array()
    best, best_t = res.best()
    print(f"explored {len(res.schedules)} schedules "
          f"({res.n_proposed} evaluations, {res.cache_hits} memo hits); "
          f"best {times.min() * 1e3:.2f} ms, "
          f"worst {times.max() * 1e3:.2f} ms "
          f"({times.max() / times.min():.2f}x)")
    if args.store is not None:
        print(f"evaluation store {args.store}: {res.store_hits} warm "
              f"hits, {res.cache_misses} new measurements appended")
    if args.strategy == "portfolio":
        q = strategy.screening_quality()
        print(f"surrogate screened {q['n_screened']} candidates "
              f"({q['n_compared']} simulated; rank corr "
              f"{q['spearman']:.2f})")
    print("best emission order:",
          " ".join(str(i) for i in best.items
                   if i.name not in ("start", "end")))

    report = R.distill(res)
    print(f"\n{report.labeling.n_classes} performance classes; "
          f"design rules:")
    print(R.render_rules_table(report.grouped(), top_k=2))
    if args.rules == "-":
        print("\n" + report.render())
    elif args.rules is not None:
        path = report.write(args.rules)
        print(f"\nfull design-rule report written to {path}")

    # Roofline context for the fastest schedule.
    total_flops = sum(op.flops for op in graph.ops.values())
    print(f"\ncompute-only bound {total_flops / PEAK_FLOPS * 1e3:.2f} ms;"
          f" best overlap schedule {times.min() * 1e3:.2f} ms "
          f"({total_flops / PEAK_FLOPS / times.min():.0%} of peak)")


if __name__ == "__main__":
    main()
