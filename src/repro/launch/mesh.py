"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    shape = (n_data, n_model)
    return jax.make_mesh(shape, ("data", "model"))
