"""Sharding rules engine + HLO analyzer + stepdag (no multi-device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.core as C
from repro.core.stepdag import StepCosts, train_step_dag, \
    with_comm_durations
from repro.dist import sharding as shd
from repro.launch import hlo as H


class FakeMesh:
    """Minimal stand-in with axis_names/devices.shape (no devices)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


def test_spec_for_basic_mapping():
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = shd.spec_for((1024, 4096), ("vocab", "d_model"), mesh)
    assert spec == P("model")


def test_spec_for_drops_nondivisible():
    mesh = FakeMesh((16, 16), ("data", "model"))
    # 15 heads don't divide 16: replicated.
    spec = shd.spec_for((960, 15, 64), ("d_model", "heads", "head_dim"),
                        mesh)
    assert spec == P()


def test_spec_for_axis_used_once():
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = shd.spec_for((256, 4096, 64, 128),
                        ("batch", "kv_seq", "kv_stored", "head_dim"),
                        mesh)
    # batch takes data; kv_seq wants data (taken) -> None; kv_stored
    # takes model.
    assert spec == P("data", None, "model")


def test_spec_for_multi_axis_dims():
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    spec = shd.spec_for((256, 4096), ("batch", "seq"), mesh,
                        rules={"batch": ("pod", "data")})
    assert spec == P(("pod", "data"))
    # absent axes silently dropped on the single-pod mesh
    mesh1 = FakeMesh((16, 16), ("data", "model"))
    spec1 = shd.spec_for((256, 4096), ("batch", "seq"), mesh1,
                         rules={"batch": ("pod", "data")})
    assert spec1 == P("data")


def test_spec_for_fsdp_fused_dims():
    mesh = FakeMesh((16, 16), ("data", "model"))
    spec = shd.spec_for((5120, 27648), ("d_model", "d_ff"), mesh,
                        rules={"d_ff": ("model", "data")})
    assert spec == P(None, ("model", "data"))


def test_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    assert shd.constrain(x, ("batch", "seq")) is x


# -- HLO analyzer ---------------------------------------------------------------

def test_hlo_dot_flops_with_loop_trips():
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    L, D = 6, 64
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xx = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c = jax.jit(f).lower(w, xx).compile()
    a = H.analyze(c.as_text())
    assert a.dot_flops == pytest.approx(L * 2 * D ** 3, rel=0.01)
    assert L in a.loop_trips
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # pre-0.5 jax returns [dict]
        ca = ca[0]
    raw = ca.get("flops", 0)
    assert raw < a.dot_flops  # the loop-once undercount we correct


def test_hlo_nested_loops_multiply():
    def f(w, x):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, w)
        return y.sum()

    L, D = 4, 32
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xx = jax.ShapeDtypeStruct((D, D), jnp.float32)
    c = jax.jit(f).lower(w, xx).compile()
    a = H.analyze(c.as_text())
    assert a.dot_flops == pytest.approx(L * 3 * 2 * D ** 3, rel=0.01)


def test_hlo_cpu_upcast_detection():
    a = jax.ShapeDtypeStruct((4096, 4096), jnp.bfloat16)
    c = jax.jit(lambda x, y: x @ y).lower(a, a).compile()
    an = H.analyze(c.as_text())
    # two 64MB f32 shadow copies of the bf16 inputs
    assert an.cpu_upcast_bytes >= 2 * 4096 * 4096 * 4


# -- stepdag: the paper's technique on the framework's own train step ------------

def test_train_step_dag_structure():
    costs = StepCosts(fwd_flops=1e12, bwd_flops=2e12, fwd_bytes=1e9,
                      bwd_bytes=2e9, grad_bytes=5e8)
    g = train_step_dag(3, costs)
    names = set(g.ops)
    assert {"fwd0", "fwd1", "fwd2", "bwd0", "bwd1", "bwd2",
            "rs0", "rs1", "rs2", "opt"} <= names
    order = g.topological_order()
    assert order.index("fwd2") < order.index("bwd2")
    assert order.index("bwd2") < order.index("bwd1")
    # rs ops depend only on their bwd
    assert g.preds["rs1"] == {"bwd1"}
    assert "opt" in g.succs["rs0"]


def test_stepdag_schedule_search_prefers_overlap():
    """MCTS over the train-step DAG finds overlap (rs on its own
    channel) faster than full serialization — the paper's technique on
    our own training loop."""
    costs = StepCosts(fwd_flops=2e12, bwd_flops=4e12, fwd_bytes=1e9,
                      bwd_bytes=2e9, grad_bytes=2e9)
    g = with_comm_durations(train_step_dag(4, costs), 50e9)
    from repro.search import MCTSSearch, run_search
    res = run_search(g, MCTSSearch(g, 2, seed=0), budget=300,
                     batch_size=1)
    best = res.schedules[int(np.argmin(res.times))]
    worst_t = max(res.times)
    best_t = min(res.times)
    assert best_t < worst_t  # schedule matters
    # In the best schedule the reduce-scatters overlap the backward
    # chain: total time is below the full-serialization sum.
    serial = sum(
        (op.duration if op.duration is not None else
         max(op.flops / 197e12, op.bytes_hbm / 819e9))
        for op in g.ops.values())
    assert best_t < serial
    streams = best.streams()
    assert len(set(streams.values())) >= 2  # uses a second channel


def test_stepdag_rules_mention_overlap():
    costs = StepCosts(fwd_flops=2e12, bwd_flops=4e12, fwd_bytes=1e9,
                      bwd_bytes=2e9, grad_bytes=2e9)
    g = with_comm_durations(train_step_dag(2, costs), 50e9)
    scheds = list(C.enumerate_schedules(g, 2))
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    if lab.n_classes < 2:
        pytest.skip("cost model yields a single class on this DAG")
    fm = C.featurize(g, scheds)
    tree = C.algorithm1(fm.X, lab.labels)
    rulesets = C.extract_rulesets(tree, fm.features)
    assert any("stream" in r.text() or "before" in r.text()
               for rs in rulesets for r in rs.rules)
