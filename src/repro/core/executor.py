"""Execute a scheduled DAG as a real JAX program (TPU stream semantics).

CUDA streams/events have no literal XLA equivalent; the TPU-native
rendering of the paper's semantics is *token chains*:

  * every stream is a serialization chain: each op's inputs are tied (via
    ``lax.optimization_barrier``) to the chain token, and the op's outputs
    produce the next token — same-stream ops are strictly ordered, exactly
    like a CUDA queue;
  * the host control thread is the "cpu" chain; a GPU op launch ties the
    op to the cpu token *at launch time* without advancing the cpu chain
    (launches are async);
  * CER/CES/CSWE sync ops from :mod:`repro.core.sync` become token joins
    between chains (Table III, verbatim).

Because tokens only add *scheduling* edges, every valid schedule of the
same DAG computes the same values — a property test asserts this. On real
TPU hardware the emitted dependency structure steers XLA's latency-hiding
scheduler; on this CPU container it provides correctness validation and a
wall-clock objective for MCTS.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

from repro.core.dag import Graph, OpKind, Schedule
from repro.core.sync import expand

# JAX is imported lazily inside the builders: this module is pulled in
# by ``repro.core``'s package init, and evaluation-engine worker
# processes (repro/engine/pool.py, forkserver/spawn start methods) must
# be able to import the package without paying — or multithreading
# themselves with — the JAX runtime they never use.

# An op implementation: (env, token) -> (outputs dict, token).
OpImpl = Callable[[dict, Any], tuple[dict, Any]]


def op_impl(fn: Callable, inputs: list[str], outputs: list[str]) -> OpImpl:
    """Lift a pure function into a token-threaded op implementation.

    ``fn(*input_values) -> tuple(output_values)`` (or a single array).
    """
    from jax import lax

    def impl(env: dict, tok):
        vals = [env[k] for k in inputs]
        if vals:
            *vals, tok = lax.optimization_barrier((*vals, tok))
        outs = fn(*vals)
        if not isinstance(outs, tuple):
            outs = (outs,)
        *outs, tok = lax.optimization_barrier((*outs, tok))
        return dict(zip(outputs, outs)), tok

    return impl


def _join(*toks):
    from jax import lax
    out = toks[0]
    for t in toks[1:]:
        out, _ = lax.optimization_barrier((out, t))
    return out


def build_runner(graph: Graph, schedule: Schedule,
                 impls: Mapping[str, OpImpl]) -> Callable[[dict], dict]:
    """Return ``run(env) -> env`` executing the expanded schedule."""
    import jax.numpy as jnp
    items = expand(graph, schedule)

    def run(env: dict) -> dict:
        env = dict(env)
        zero = jnp.zeros((), jnp.float32)
        cpu_tok = zero
        stream_tok: dict = {}
        event_tok: dict = {}
        for it in items:
            if it.kind == "CER":
                event_tok[it.anchor] = stream_tok.get(it.stream, zero)
            elif it.kind == "CES":
                cpu_tok = _join(cpu_tok,
                                *[event_tok[w] for w in it.waits])
            elif it.kind == "CSWE":
                s = it.stream
                stream_tok[s] = _join(stream_tok.get(s, zero),
                                      *[event_tok[w] for w in it.waits])
            else:
                impl = impls.get(it.name)
                if impl is None:  # start / end / pure-control CPU ops
                    continue
                op = graph.ops[it.name]
                if op.kind is OpKind.GPU:
                    s = it.stream
                    in_tok = _join(stream_tok.get(s, zero), cpu_tok)
                    outs, out_tok = impl(env, in_tok)
                    stream_tok[s] = out_tok
                else:
                    outs, cpu_tok = impl(env, cpu_tok)
                env.update(outs)
        return env

    return run


def jit_runner(graph: Graph, schedule: Schedule,
               impls: Mapping[str, OpImpl]):
    import jax
    return jax.jit(build_runner(graph, schedule, impls))
