"""Compatibility shim: rulesets now live in :mod:`repro.rules.rulesets`.

The §IV-D/§V design-rule generation moved into the rules distillation
subsystem — :mod:`repro.rules` — next to the tree trainer it consumes
and the :func:`repro.rules.distill` pipeline that renders
:class:`~repro.rules.pipeline.RuleReport`. Import from
:mod:`repro.rules` (or keep importing from here / :mod:`repro.core`;
both stay supported).
"""
from repro.rules.rulesets import (Rule, RuleSet, annotate_vs_canonical,
                                  class_range_accuracy,
                                  class_range_accuracy_loop,
                                  extract_rulesets, render_rules_table,
                                  rules_by_class)

__all__ = ["Rule", "RuleSet", "annotate_vs_canonical",
           "class_range_accuracy", "class_range_accuracy_loop",
           "extract_rulesets", "render_rules_table", "rules_by_class"]
