"""Acquisition-aware search driver subsystem.

The propose/observe control path of the whole reproduction, extracted
from ``repro.search.pipeline`` the way :mod:`repro.engine` extracted
evaluation and :mod:`repro.rules` extracted distillation:

* :class:`SearchDriver` (:mod:`repro.driver.driver`) — the round loop
  (propose pool -> acquisition -> evaluate -> observe -> sinks);
  ``repro.search.run_search`` is its bit-compatible thin wrapper.
* :data:`ACQUISITIONS` (:mod:`repro.driver.acquisitions`) — pool
  ranking: ``argmin_topk`` (the original screening), ``ucb``,
  ``expected_improvement``; uncertainty via ``predict_with_std``.
* :data:`SINKS` (:mod:`repro.driver.sinks`) — streaming consumers of
  evaluated batches: ``dataset`` (incremental featurization +
  histogram for streaming distillation), ``histogram`` (out-of-core:
  compact encodings + count histograms, distills without ever
  materializing the feature matrix), ``trace`` (per-round choice
  stream).

See README.md in this package for the round lifecycle, the registry
seams, and the determinism guarantees.

``SearchDriver`` is loaded lazily: :mod:`repro.driver.driver` imports
:mod:`repro.search.pipeline` (for ``SearchResult``), while
``repro.search.surrogate`` imports :mod:`repro.driver.acquisitions` —
eager loading here would make this package's import order depend on
who imports whom first.
"""
from repro.driver.acquisitions import (ACQUISITIONS, AcquisitionFn,
                                       argmin_topk, expected_improvement,
                                       make_acquisition, predict_with_std,
                                       register_acquisition,
                                       resolve_acquisition, ucb)
from repro.driver.sinks import (SINKS, DatasetSink, HistogramSink,
                                Sink, StreamingHistogram,
                                TelemetrySink, TraceSink, make_sink,
                                register_sink)

__all__ = [
    "SearchDriver",
    "ACQUISITIONS", "AcquisitionFn", "argmin_topk",
    "expected_improvement", "make_acquisition", "predict_with_std",
    "register_acquisition", "resolve_acquisition", "ucb",
    "SINKS", "DatasetSink", "HistogramSink", "Sink",
    "StreamingHistogram", "TelemetrySink", "TraceSink", "make_sink",
    "register_sink",
]


def __getattr__(name: str):
    if name == "SearchDriver":
        from repro.driver.driver import SearchDriver
        return SearchDriver
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
