"""whisper-tiny [audio]: enc-dec transformer backbone; the conv frame
frontend is a STUB — input_specs() provides precomputed frame embeddings
(batch, 1500, 384) [arXiv:2212.04356].

Adaptation note (DESIGN.md): whisper uses learned positions + GELU; the
backbone here uses the framework's RoPE + GELU. The brief specifies the
transformer backbone only.
"""
from repro.models.config import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_encoder_layers=4,
    d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, mlp="gelu",
    frontend=FrontendConfig(kind="audio", n_positions=1500,
                            d_frontend=384),
)

REDUCED = ModelConfig(
    name="whisper-tiny-reduced", family="encdec",
    n_layers=2, n_encoder_layers=2,
    d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, mlp="gelu",
    frontend=FrontendConfig(kind="audio", n_positions=16, d_frontend=32),
)
