"""The paper's contribution: MCTS design-space search + decision-tree
design rules for asynchronous compute/communication programs.

Pipeline (paper Fig. 2):

    Graph (dag.py)  ->  MCTS (repro.search.mcts) / exhaustive (enumerate.py)
        -> measured times (costmodel.py analytic | executor.py wall-clock)
        -> class labels (repro.rules.labels)
        -> feature vectors (features.py)
        -> decision tree (repro.rules.trees)
        -> design rules (repro.rules.rulesets)

The labels -> tree -> rules stack lives in :mod:`repro.rules` (one
call: :func:`repro.rules.distill`); this package re-exports the moved
names straight from their new homes so historical ``repro.core``
one-stop imports keep working. Search strategies live in
:mod:`repro.search` and design spaces in :mod:`repro.space`; the
pre-subsystem shim modules (``core/{mcts,dtree,labels,rules}.py``,
``search/evaluator.py``) are gone.
"""
from repro.core.dag import (BoundOp, CommRole, Graph, Op, OpKind, Schedule,
                            canonicalize_streams, spmv_dag,
                            validate_schedule)
from repro.core.sync import ExpandedItem, expand, expanded_names
from repro.core.enumerate import count_schedules, enumerate_schedules
from repro.core.costmodel import Machine, SimResult, makespan, simulate
from repro.rules.labels import Labeling, label_times
from repro.core.features import (DegenerateFeatureSpaceError, Feature,
                                 FeatureBasis, FeatureMatrix,
                                 apply_features, featurize, featurize_like)
from repro.rules.trees import DecisionTree, TreeSearchTrace, algorithm1
from repro.rules.rulesets import (Rule, RuleSet, annotate_vs_canonical,
                                  class_range_accuracy, extract_rulesets,
                                  render_rules_table, rules_by_class)
from repro.core.executor import build_runner, jit_runner, op_impl
from repro.core.stepdag import StepCosts, train_step_dag, with_comm_durations

__all__ = [
    "BoundOp", "CommRole", "Graph", "Op", "OpKind", "Schedule",
    "canonicalize_streams", "spmv_dag", "validate_schedule",
    "ExpandedItem", "expand", "expanded_names",
    "count_schedules", "enumerate_schedules",
    "Machine", "SimResult", "makespan", "simulate",
    "Labeling", "label_times",
    "DegenerateFeatureSpaceError", "Feature", "FeatureBasis",
    "FeatureMatrix", "apply_features", "featurize", "featurize_like",
    "DecisionTree", "TreeSearchTrace", "algorithm1",
    "Rule", "RuleSet", "annotate_vs_canonical", "class_range_accuracy",
    "extract_rulesets", "render_rules_table", "rules_by_class",
    "build_runner", "jit_runner", "op_impl",
    "StepCosts", "train_step_dag", "with_comm_durations",
]
