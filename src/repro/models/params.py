"""Declarative parameter specs.

Every module declares its parameters as a nested dict of :class:`Spec`
(shape + logical axes + initializer). From one spec tree we derive:

  * initialized parameters (``init``),
  * the logical-axis tree (for sharding rules, ``axes``),
  * abstract ShapeDtypeStructs for the dry-run (``abstract``).

Layer stacks add a leading "layers" axis via :func:`stack_specs`; the
dry-run never materializes parameters (ShapeDtypeStruct only).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"      # normal | zeros | ones
    scale: float | None = None  # None -> 1/sqrt(fan_in) for normal

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_specs(tree, n: int, axis_name: str | None = "layers"):
    """Prepend a stacking dimension of size ``n`` to every Spec."""
    return jax.tree.map(
        lambda s: Spec((n, *s.shape), (axis_name, *s.axes),
                       s.init, s.scale),
        tree, is_leaf=lambda x: isinstance(x, Spec))


def _init_one(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    scale = spec.scale if spec.scale is not None \
        else 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * scale).astype(dtype)


def init(specs, key: jax.Array, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def axes(specs):
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda x: isinstance(x, Spec))


def abstract(specs, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=lambda x: isinstance(x, Spec))


def count(specs) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, Spec)))
