"""Band-diagonal sparse matrices in ELL format (paper §III).

The paper's input: 150 000 rows/cols, 1 500 000 non-zeros uniformly
random within a band of half-width n/4 — chosen so the local and remote
multiplications are balanced when rows are block-partitioned across 4
ranks. We use a *circulant* band (wrap-around) so every rank is
symmetric, matching the cost model's symmetric-rank assumption.

ELL layout (TPU-friendly: rectangular, no row pointers):
    vals: (n, K) float32, cols: (n, K) int32
padded entries have val = 0 and col = row (a safe self-index).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class EllMatrix:
    vals: np.ndarray  # (n, K) float32
    cols: np.ndarray  # (n, K) int32
    n_cols: int

    @property
    def n_rows(self) -> int:
        return self.vals.shape[0]

    @property
    def k(self) -> int:
        return self.vals.shape[1]

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.n_rows, self.n_cols), dtype=np.float64)
        for i in range(self.n_rows):
            np.add.at(out[i], self.cols[i], self.vals[i].astype(np.float64))
        return out

    def matvec(self, x: np.ndarray) -> np.ndarray:
        """Dense-math oracle (float64)."""
        return (self.vals.astype(np.float64) *
                x.astype(np.float64)[self.cols]).sum(axis=1)


def band_matrix(n: int = 150_000, nnz: int = 1_500_000,
                half_bandwidth: int | None = None,
                seed: int = 0) -> EllMatrix:
    """Circulant band matrix with nnz uniform in the band."""
    if half_bandwidth is None:
        half_bandwidth = n // 4
    rng = np.random.default_rng(seed)
    per_row = nnz // n
    rem = nnz - per_row * n
    counts = np.full(n, per_row, dtype=np.int64)
    counts[rng.choice(n, size=rem, replace=False)] += 1
    k = int(counts.max())

    # Offsets uniform in [-half_bandwidth, half_bandwidth], wrap mod n.
    offs = rng.integers(-half_bandwidth, half_bandwidth + 1,
                        size=(n, k), dtype=np.int64)
    cols = (np.arange(n)[:, None] + offs) % n
    vals = rng.standard_normal((n, k)).astype(np.float32)
    # Mask padding beyond each row's count.
    mask = np.arange(k)[None, :] < counts[:, None]
    vals = np.where(mask, vals, 0.0).astype(np.float32)
    cols = np.where(mask, cols, np.arange(n)[:, None] % n)
    return EllMatrix(vals=vals, cols=cols.astype(np.int32), n_cols=n)


@dataclasses.dataclass
class RankPartition:
    """Per-rank split of a band matrix into local + remote halves.

    Local columns are re-indexed into [0, m); remote columns are
    re-indexed into the rank's halo buffer = concat(left block, right
    block) of length 2m (half-bandwidth == m, so the halo is exactly the
    two neighbor blocks).
    """

    local: EllMatrix    # cols index x_local (m,)
    remote: EllMatrix   # cols index halo (2m,)
    rank: int
    n_ranks: int

    @property
    def m(self) -> int:
        return self.local.n_rows


def partition(matrix: EllMatrix, n_ranks: int) -> list[RankPartition]:
    """Block-partition rows; split each rank's nnz into local/remote."""
    n = matrix.n_rows
    assert n % n_ranks == 0, "rows must divide evenly across ranks"
    m = n // n_ranks
    parts: list[RankPartition] = []
    for r in range(n_ranks):
        rows = slice(r * m, (r + 1) * m)
        vals = matrix.vals[rows]
        cols = matrix.cols[rows]
        lo, hi = r * m, (r + 1) * m
        is_local = (cols >= lo) & (cols < hi)

        def compact(v: np.ndarray, c: np.ndarray,
                    keep: np.ndarray, width: int,
                    reindex) -> EllMatrix:
            k = max(1, int(keep.sum(axis=1).max()))
            out_v = np.zeros((m, k), dtype=np.float32)
            out_c = np.zeros((m, k), dtype=np.int32)
            for i in range(m):
                sel = keep[i]
                cnt = int(sel.sum())
                out_v[i, :cnt] = v[i, sel]
                out_c[i, :cnt] = reindex(c[i, sel])
            return EllMatrix(out_v, out_c, width)

        local = compact(vals, cols, is_local & (vals != 0), m,
                        lambda c: c - lo)

        left = (r - 1) % n_ranks
        right = (r + 1) % n_ranks

        def halo_index(c: np.ndarray) -> np.ndarray:
            # halo = [left block (m), right block (m)]
            out = np.empty_like(c)
            in_left = (c >= left * m) & (c < (left + 1) * m)
            out[in_left] = c[in_left] - left * m
            in_right = (c >= right * m) & (c < (right + 1) * m)
            out[in_right] = c[in_right] - right * m + m
            bad = ~(in_left | in_right)
            if bad.any():
                raise ValueError("column outside halo - bandwidth too wide"
                                 f" for {n_ranks} ranks")
            return out

        remote = compact(vals, cols, (~is_local) & (vals != 0), 2 * m,
                         halo_index)
        parts.append(RankPartition(local=local, remote=remote,
                                   rank=r, n_ranks=n_ranks))
    return parts


def stack_partitions(parts: list[RankPartition]) -> dict[str, np.ndarray]:
    """Stack per-rank arrays with a leading rank axis (shard_map layout).

    ELL widths are padded to the max across ranks.
    """
    kl = max(p.local.k for p in parts)
    kr = max(p.remote.k for p in parts)

    def pad(m: EllMatrix, k: int) -> tuple[np.ndarray, np.ndarray]:
        pv = np.zeros((m.n_rows, k), dtype=np.float32)
        pc = np.zeros((m.n_rows, k), dtype=np.int32)
        pv[:, :m.k] = m.vals
        pc[:, :m.k] = m.cols
        return pv, pc

    lv, lc = zip(*[pad(p.local, kl) for p in parts])
    rv, rc = zip(*[pad(p.remote, kr) for p in parts])
    return {
        "local_vals": np.stack(lv), "local_cols": np.stack(lc),
        "remote_vals": np.stack(rv), "remote_cols": np.stack(rc),
    }
