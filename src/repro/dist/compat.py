"""JAX version compatibility shims.

``jax.shard_map`` (with the ``check_vma`` flag) is the modern spelling;
older jax (< 0.5) ships it as ``jax.experimental.shard_map.shard_map``
with the flag named ``check_rep``. All repo code routes through
:func:`shard_map` so either runtime works.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _legacy

    def shard_map(f=None, /, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if f is None:
            return lambda g: _legacy(g, **kwargs)
        return _legacy(f, **kwargs)
