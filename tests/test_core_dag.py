"""DAG model, sync insertion (Table III), enumeration, canonical form."""
import pytest

import repro.core as C
from repro.core.dag import BoundOp, Graph, Op, OpKind


def small_graph() -> Graph:
    g = Graph()
    g.add_op(Op("a", OpKind.CPU))
    g.add_op(Op("k1", OpKind.GPU, flops=1e6))
    g.add_op(Op("k2", OpKind.GPU, flops=1e6))
    g.add_op(Op("b", OpKind.CPU))
    g.add_edge("a", "k1")
    g.add_edge("k1", "k2")
    g.add_edge("k2", "b")
    return g.finalize()


def test_topological_order_contains_all():
    g = C.spmv_dag()
    order = g.topological_order()
    assert set(order) == set(g.ops)
    assert order[0] == Graph.START
    assert order[-1] == Graph.END


def test_cycle_detection():
    g = Graph()
    g.add_op(Op("x", OpKind.CPU))
    g.add_op(Op("y", OpKind.CPU))
    g.add_edge("x", "y")
    g.add_edge("y", "x")
    with pytest.raises(ValueError, match="cycle"):
        g.finalize()


def test_eligible_respects_deps():
    g = C.spmv_dag()
    assert g.eligible([]) == [Graph.START]
    first = g.eligible([Graph.START])
    assert "Pack" in first and "PostRecv" in first and "yL" in first
    assert "PostSend" not in first  # needs Pack


def test_validate_schedule_catches_violations():
    g = small_graph()
    good = C.Schedule((BoundOp("start"), BoundOp("a"), BoundOp("k1", 0),
                       BoundOp("k2", 0), BoundOp("b"), BoundOp("end")))
    C.validate_schedule(g, good)
    bad = C.Schedule((BoundOp("start"), BoundOp("k1", 0), BoundOp("a"),
                      BoundOp("k2", 0), BoundOp("b"), BoundOp("end")))
    with pytest.raises(ValueError, match="before preds"):
        C.validate_schedule(g, bad)
    unbound = C.Schedule((BoundOp("start"), BoundOp("a"), BoundOp("k1"),
                          BoundOp("k2", 0), BoundOp("b"), BoundOp("end")))
    with pytest.raises(ValueError, match="no stream"):
        C.validate_schedule(g, unbound)


def test_canonicalize_streams():
    items = (BoundOp("x", 3), BoundOp("c"), BoundOp("y", 1),
             BoundOp("z", 3))
    canon = C.canonicalize_streams(items)
    assert [i.stream for i in canon] == [0, None, 1, 0]
    assert C.canonicalize_streams(canon) == canon  # idempotent


# -- Table III ---------------------------------------------------------------

def test_sync_insertion_same_stream_no_sync():
    g = small_graph()
    s = C.Schedule((BoundOp("start"), BoundOp("a"), BoundOp("k1", 0),
                    BoundOp("k2", 0), BoundOp("b"), BoundOp("end")))
    names = C.expanded_names(g, s)
    # k1->k2 same stream: no CSWE. k2->b GPU->CPU: CER+CES.
    assert "CSWE-b4-k2" not in names
    assert "CER-after-k2" in names and "CES-b4-b" in names
    assert names.index("CER-after-k2") > names.index("k2")
    assert names.index("CES-b4-b") < names.index("b")


def test_sync_insertion_cross_stream():
    g = small_graph()
    s = C.Schedule((BoundOp("start"), BoundOp("a"), BoundOp("k1", 0),
                    BoundOp("k2", 1), BoundOp("b"), BoundOp("end")))
    names = C.expanded_names(g, s)
    assert "CER-after-k1" in names
    assert "CSWE-b4-k2" in names
    assert names.index("CSWE-b4-k2") < names.index("k2")


def test_sync_insertion_cpu_to_gpu_no_sync():
    g = small_graph()
    s = C.Schedule((BoundOp("start"), BoundOp("a"), BoundOp("k1", 0),
                    BoundOp("k2", 0), BoundOp("b"), BoundOp("end")))
    names = C.expanded_names(g, s)
    assert "CES-b4-k1" not in names  # a->k1 is CPU->GPU: none


def test_expanded_names_cache_invalidated_on_graph_mutation():
    """Mutating a graph after its sync tables were cached must not
    serve a stale expansion (Graph.version keys the cache)."""
    g = C.Graph()
    g.add_op(C.Op("k1", C.OpKind.GPU, duration=1e-6))
    g.add_op(C.Op("k2", C.OpKind.GPU, duration=1e-6))
    g.finalize()
    s = C.Schedule((BoundOp("start"), BoundOp("k1", 0),
                    BoundOp("k2", 1), BoundOp("end")))
    C.expanded_names(g, s)  # warm the cache
    g.add_edge("k1", "k2")  # now k1->k2 cross-stream needs a CSWE
    names = C.expanded_names(g, s)
    assert "CSWE-b4-k2" in names
    assert names == [it.name for it in C.expand(g, s)]


def test_expanded_names_matches_expand():
    """The featurizer's fast names-only path must stay in lockstep with
    the full Table III insertion in :func:`repro.core.sync.expand`."""
    import random

    import repro.search as S
    from repro.core.dag import halo3d_dag, spmv_dag_fine

    for g in (small_graph(), C.spmv_dag(), spmv_dag_fine(),
              halo3d_dag()):
        rng = random.Random(7)
        for n_streams in (1, 2, 3):
            for _ in range(10):
                s = S.random_schedule(g, n_streams, rng)
                assert C.expanded_names(g, s) == \
                    [it.name for it in C.expand(g, s)]


# -- enumeration ---------------------------------------------------------------

def test_enumeration_count_and_validity():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    # 3 GPU ops on <=2 streams: 4 canonical assignments per ordering.
    orderings = {s.order() for s in scheds}
    assert len(scheds) == 4 * len(orderings)
    keys = {s.key() for s in scheds}
    assert len(keys) == len(scheds)  # no duplicates
    for s in scheds[:50]:
        C.validate_schedule(g, s)


def test_enumeration_one_stream():
    g = C.spmv_dag()
    one = list(C.enumerate_schedules(g, 1))
    two = list(C.enumerate_schedules(g, 2))
    orderings = {s.order() for s in two}
    assert len(one) == len(orderings)  # single stream: 1 per ordering


def test_canonical_pruning_no_bijection_duplicates():
    g = C.spmv_dag()
    seen = set()
    for s in C.enumerate_schedules(g, 2):
        # swap stream labels; the swapped variant must not also appear
        swapped = tuple(
            (i.name, 1 - i.stream if i.stream is not None else None)
            for i in s.items)
        assert swapped not in seen or swapped == s.key()
        seen.add(s.key())


def test_fine_grained_dag_valid_and_costed():
    """Granularity ablation DAG: valid schedules, multi-channel cost
    model, and the overhead conclusion (EXPERIMENTS §Paper)."""
    from repro.core.dag import spmv_dag_fine
    g = spmv_dag_fine()
    assert {"Pack_l", "Pack_r", "PostSend_l", "WaitRecv_r",
            "yL", "yR"} <= set(g.ops)
    from repro.search import MCTSSearch, run_search
    res = run_search(g, MCTSSearch(g, 2, seed=0), budget=50,
                     batch_size=1)
    for s in res.schedules:
        C.validate_schedule(g, s)
    assert all(t > 0 for t in res.times)
    # fine granularity pays per-op overhead vs the coarse DAG's best
    coarse_best = min(C.makespan(C.spmv_dag(), s)
                      for s in C.enumerate_schedules(C.spmv_dag(), 2))
    assert min(res.times) > coarse_best * 0.9
