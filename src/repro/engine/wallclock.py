"""Wall-clock evaluation backend: the jitted token-chain runner as the
search objective.

:mod:`repro.core.executor` renders a schedule as a real JAX program
whose token chains reproduce the CUDA stream/event semantics; this
backend routes it through the evaluator contract, so *measured* time
shares the memo cache, dedup, and ``sim_budget`` accounting that the
analytic backends use — a search strategy cannot tell it is optimizing
wall clock instead of the machine model.

Per canonical-unique schedule it:

  1. builds and jits the runner (compile time excluded from timing);
  2. runs ``warmup`` calls, asserting **value correctness** on the
     first: every output must match the reference outputs computed
     once from a canonical (topological, single-stream) schedule —
     the sync insertion must make any valid schedule compute the same
     values (the executor's schedule-invariance property);
  3. times ``repeats`` calls (``block_until_ready`` inside the stopwatch
     — JAX dispatch is async) and records the **median**, the usual
     robust estimator for multimodal timing jitter.

On a CPU container the measured numbers rank schedules by Python/XLA
dispatch cost rather than TPU overlap quality — the point on this
hardware is the end-to-end plumbing (real measurements driving
``run_search``) and the correctness gate; on a TPU host the same class
is the paper's wall-clock objective.

:func:`demo_spmv_impls` supplies a tiny CPU-sized implementation set
for the coarse SpMV DAG so smoke tests and examples can run an
end-to-end wall-clock search anywhere.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Mapping, Sequence

import numpy as np

from repro.core.costmodel import Machine
from repro.core.dag import BoundOp, Graph, OpKind, Schedule
from repro.engine.base import EvaluatorBase


def reference_schedule(graph: Graph) -> Schedule:
    """A canonical valid schedule: topological order, all on stream 0."""
    return Schedule(tuple(
        BoundOp(n, 0 if graph.ops[n].kind is OpKind.GPU else None)
        for n in graph.topological_order()))


def _as_output_map(out) -> dict[str, np.ndarray]:
    """Normalize a runner's outputs (mapping / sequence / single array)
    to named numpy arrays for comparison."""
    if isinstance(out, Mapping):
        return {str(k): np.asarray(v) for k, v in out.items()}
    if isinstance(out, (tuple, list)):
        return {f"out{i}": np.asarray(v) for i, v in enumerate(out)}
    return {"out": np.asarray(out)}


def assert_outputs_close(got, ref, *, rtol: float, atol: float = 0.0,
                         context: str = "") -> None:
    """The wallclock value-correctness gate: every reference output
    must be reproduced within tolerance.

    Shared by the schedule-space executor backend (outputs are the
    token-chain environment) and the param-space kernel backend
    (outputs are whatever the kernel returns); ``context`` names the
    failing candidate in the assertion message.
    """
    ref_map = _as_output_map(ref)
    got_map = _as_output_map(got)
    missing = sorted(set(ref_map) - set(got_map))
    if missing:
        raise AssertionError(
            f"candidate is missing reference output(s) {missing}"
            f"{context}")
    for k, r in ref_map.items():
        np.testing.assert_allclose(
            got_map[k], r, rtol=rtol, atol=atol,
            err_msg=f"output {k!r} diverged{context}")


class ExecutorEvaluator(EvaluatorBase):
    """Evaluation backend measuring jitted token-chain runners.

    ``impls`` maps op names to :func:`repro.core.executor.op_impl`
    implementations; ``env`` is the initial value environment. Ops
    without an impl (start/end/pure-control) are skipped by the runner.
    ``check_values=False`` disables the output assertion (e.g. for
    intentionally stochastic kernels).
    """

    backend = "wallclock"

    def __init__(self, graph: Graph, machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0, *,
                 impls: Mapping[str, Callable] | None = None,
                 env: Mapping | None = None,
                 repeats: int = 5, warmup: int = 1,
                 check_values: bool = True, rtol: float = 1e-5,
                 **base_kwargs):
        if impls is None or env is None:
            raise ValueError(
                "wallclock backend needs impls= (op implementations) "
                "and env= (initial values); see engine/README.md")
        super().__init__(graph, machine, noise_sigma, noise_seed,
                         **base_kwargs)
        if self.graph is None:
            raise TypeError(
                "the executor wallclock backend renders schedules of a "
                f"Graph; design space {self.space.name!r} has no graph "
                "(parameter spaces evaluate through the param-space "
                "wallclock runner — attach a KernelRunner and use "
                "make_evaluator)")
        self.impls = dict(impls)
        self.env = dict(env)
        self.repeats = max(1, repeats)
        self.warmup = max(1, warmup)
        self.check_values = check_values
        self.rtol = rtol
        self.n_checked = 0
        self._reference: dict | None = None

    def _objective_key(self) -> str:
        """Measured wall-clock time is machine- and protocol-specific:
        never share store entries with the analytic family, nor with a
        differently-configured timing protocol. Distinct impl/env sets
        on the same graph should be disambiguated with ``store_tag=``.
        """
        return f"wallclock:repeats={self.repeats}:warmup={self.warmup}"

    # -- reference outputs (computed lazily, once) -------------------------
    def _reference_outputs(self) -> dict:
        if self._reference is None:
            from repro.core.executor import build_runner
            ref = build_runner(self.graph, reference_schedule(self.graph),
                               self.impls)(self.env)
            self._reference = {k: np.asarray(v) for k, v in ref.items()
                               if k not in self.env}
        return self._reference

    def _check(self, out: Mapping, schedule: Schedule) -> None:
        assert_outputs_close(
            {k: out[k] for k in self._reference_outputs()},
            self._reference_outputs(), rtol=self.rtol,
            context=(f" under schedule "
                     f"{[str(i) for i in schedule.items]} — sync "
                     "insertion failed to enforce a dependency"))
        self.n_checked += 1

    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        import jax

        from repro.core.executor import build_runner
        out: list[float] = []
        try:
            for sched in schedules:
                run = jax.jit(build_runner(self.graph, sched,
                                           self.impls))
                result = jax.block_until_ready(run(self.env))
                if self.check_values:
                    self._check(result, sched)
                for _ in range(self.warmup - 1):
                    jax.block_until_ready(run(self.env))
                times = []
                for _ in range(self.repeats):
                    t0 = time.perf_counter()
                    jax.block_until_ready(run(self.env))
                    times.append(time.perf_counter() - t0)
                out.append(statistics.median(times))
        finally:
            # Measurements here are expensive (jit compile + repeats);
            # if a later schedule fails the value gate, salvage the
            # completed ones into the memo cache and persistent store
            # so a retry doesn't re-pay them. The base class remembers
            # them as salvaged: their first post-salvage lookup counts
            # as a miss (the measurement was paid), not a free hit.
            if encoded is not None and len(out) < len(schedules):
                self._salvage_partial(encoded[:len(out)], out)
        return out


def demo_spmv_impls(graph: Graph, n: int = 16, seed: int = 0
                    ) -> tuple[dict, dict]:
    """(impls, env) realizing the coarse SpMV DAG with tiny dense ops.

    Small enough that a wall-clock smoke search finishes in seconds on
    CPU; the dataflow (pack -> send -> recv-wait -> remote multiply)
    matches the DAG, so the value-correctness gate is meaningful.
    """
    import jax.numpy as jnp

    from repro.core.executor import op_impl

    rng = np.random.default_rng(seed)
    AL = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    AR = jnp.asarray(rng.normal(size=(n, n)), jnp.float32)
    xL = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    impls = {
        "Pack": op_impl(lambda x: x * 1.0, ["xL"], ["sendbuf"]),
        "PostSend": op_impl(lambda b: b, ["sendbuf"], ["wire"]),
        "PostRecv": op_impl(lambda: jnp.zeros((n,), jnp.float32),
                            [], ["recvbuf"]),
        "WaitSend": op_impl(lambda w: w, ["wire"], ["sent"]),
        "WaitRecv": op_impl(lambda w, r: w + r, ["wire", "recvbuf"],
                            ["xR"]),
        "yL": op_impl(lambda x: AL @ x, ["xL"], ["yL"]),
        "yR": op_impl(lambda x: AR @ x, ["xR"], ["yR"]),
    }
    return impls, {"xL": xL}
