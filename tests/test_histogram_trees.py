"""Out-of-core histogram-folded CART training (the PR-9 tentpole).

Locks the exactness contract end to end: a tree trained from folded
per-feature x per-class count histograms — one blockwise pass per tree
level, never a materialized (rows x features) matrix — must be
**bit-identical** (splits, thresholds, tie-breaks, ``predict``) to the
in-memory vectorized splitter, on the exhaustive 280-schedule SpMV
space, on 2000-schedule halo3d corpora, and through the full
Algorithm-1 sweep; plus the mergeability laws (associative/commutative
histogram ``merge`` == single-stream ``add``), the subtraction trick
(``right = parent - left`` equals a fresh scan), block-size invariance,
and the :class:`~repro.driver.HistogramSink` ``distill`` path against
:class:`~repro.driver.DatasetSink`.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C
import repro.rules as R
import repro.search as S
from repro.core.dag import halo3d_dag
from repro.driver import (DatasetSink, HistogramSink, SearchDriver,
                          StreamingHistogram)
from repro.rules.trees import (ClassCountHistogram, HistogramGrower,
                               algorithm1_from_histograms,
                               fit_from_histograms)
from repro.space.params import demo_param_space


def tree_signature(tree):
    """(feature, threshold) preorder + leaf stats — full structure."""
    out = []

    def walk(nd):
        if nd.is_leaf:
            out.append(("leaf", nd.n_samples, nd.majority_class()))
            return
        out.append((nd.feature, nd.threshold))
        walk(nd.left)
        walk(nd.right)

    walk(tree.root)
    return out


def _blocks(X, block):
    """Re-callable block stream over a materialized matrix."""
    return lambda: (X[i:i + block] for i in range(0, len(X), block))


def random_dataset(rng, kind):
    n = int(rng.integers(8, 120))
    d = int(rng.integers(1, 10))
    if kind == 0:                       # the paper's 0/1 features
        X = rng.integers(0, 2, size=(n, d)).astype(float)
    elif kind == 1:                     # small-cardinality ordinals
        X = rng.integers(0, 4, size=(n, d)).astype(float)
    elif kind == 2:                     # continuous
        X = np.round(rng.random((n, d)), 3)
    else:                               # mixed + constant columns
        X = np.concatenate(
            [rng.integers(0, 2, size=(n, d)).astype(float),
             np.round(rng.random((n, 2)), 3), np.ones((n, 1))], axis=1)
    y = rng.integers(0, int(rng.integers(2, 5)), size=n)
    return X, y


# -- acceptance pins: bit-identity on the paper's corpora ---------------------

def test_histogram_tree_identical_on_exhaustive_spmv():
    """Acceptance pin: the histogram path reproduces the in-memory
    Algorithm-1 sweep bit for bit on the exhaustive 280-schedule SpMV
    space — identical trial schedule, leaf counts, tree structure,
    predictions, and training error."""
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    assert len(scheds) == 280
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    ref_trace = R.TreeSearchTrace([], [], [])
    ref = R.algorithm1(fm.X, lab.labels, trace=ref_trace)
    ooc_trace = R.TreeSearchTrace([], [], [])
    ooc = algorithm1_from_histograms(_blocks(fm.X, 64), lab.labels,
                                     trace=ooc_trace)
    assert tree_signature(ref) == tree_signature(ooc)
    np.testing.assert_array_equal(ref.predict(fm.X), ooc.predict(fm.X))
    # the sweep itself is identical: same trials, same leaf counts
    assert ref_trace.max_leaf_nodes == ooc_trace.max_leaf_nodes
    assert ref_trace.errors == ooc_trace.errors
    assert ref.n_leaves() == ooc.n_leaves()


def test_histogram_tree_identical_on_halo3d_2000():
    """Acceptance pin: bit-identity on a 2000-schedule halo3d corpus
    (the bench-scale dataset), including max_depth-capped fits."""
    g = halo3d_dag()
    res = S.run_search(g, S.RandomSearch(g, seed=0), budget=2000,
                       batch_size=64, backend="vectorized")
    fm, lab, _ = res.dataset()
    ref = R.DecisionTree(max_leaf_nodes=12, max_depth=6).fit(
        np.asarray(fm.X, dtype=np.float64), lab.labels)
    ooc = fit_from_histograms(_blocks(fm.X, 257), lab.labels,
                              max_leaf_nodes=12, max_depth=6)
    assert tree_signature(ref) == tree_signature(ooc)
    Xf = np.asarray(fm.X, dtype=np.float64)
    np.testing.assert_array_equal(ref.predict(Xf), ooc.predict(Xf))
    # a grower is reusable across the whole Algorithm-1 sweep
    ref_trace = R.TreeSearchTrace([], [], [])
    full_ref = R.algorithm1(Xf, lab.labels, trace=ref_trace)
    ooc_trace = R.TreeSearchTrace([], [], [])
    full_ooc = algorithm1_from_histograms(_blocks(fm.X, 257),
                                          lab.labels, trace=ooc_trace)
    assert tree_signature(full_ref) == tree_signature(full_ooc)
    assert ref_trace.max_leaf_nodes == ooc_trace.max_leaf_nodes
    assert full_ref.n_leaves() == full_ooc.n_leaves()


# -- the sink: streamed corpus == in-memory corpus ----------------------------

def test_histogram_sink_distill_matches_dataset_sink():
    """One driver run feeding both sinks: the out-of-core ``distill``
    must reproduce the dense report — same pruned feature list, same
    tree, same rulesets, same training error — without ever holding
    the feature matrix."""
    g = halo3d_dag()
    ds, hs = DatasetSink(g), HistogramSink(g, block_rows=97)
    SearchDriver(g, S.RandomSearch(g, seed=0), budget=600,
                 batch_size=64, backend="vectorized",
                 sinks=[ds, hs]).run()
    assert hs.n_rows == len(ds.schedules)
    assert hs.times == ds.times
    assert hs.feature_list() == ds.matrix().features
    rd, rh = ds.distill(), hs.distill()
    assert tree_signature(rd.tree) == tree_signature(rh.tree)
    assert rd.training_error == rh.training_error
    assert rd.n_schedules == rh.n_schedules
    assert [(r.class_label, r.rules, r.n_samples, r.pure)
            for r in rd.rulesets] \
        == [(r.class_label, r.rules, r.n_samples, r.pure)
            for r in rh.rulesets]
    assert rd.trace.max_leaf_nodes == rh.trace.max_leaf_nodes
    assert rd.trace.errors == rh.trace.errors
    # the report renders identically (feature names line up too)
    assert rd.render() == rh.render()
    # and the out-of-core report never materialized a row
    assert rh.feature_matrix.X.shape == (0, len(rh.feature_matrix.features))


def test_histogram_sink_merge_equals_sequential_consume():
    """Sharded hosts: merging two sinks equals one sink that consumed
    both runs in sequence — rows, times, doubling histogram, and the
    distilled report all agree."""
    g = halo3d_dag()
    h1, h2 = HistogramSink(g), HistogramSink(g)
    SearchDriver(g, S.RandomSearch(g, seed=1), budget=300,
                 batch_size=64, backend="vectorized", sinks=[h1]).run()
    SearchDriver(g, S.RandomSearch(g, seed=2), budget=300,
                 batch_size=64, backend="vectorized", sinks=[h2]).run()
    both = HistogramSink(g)
    SearchDriver(g, S.RandomSearch(g, seed=1), budget=300,
                 batch_size=64, backend="vectorized", sinks=[both]).run()
    SearchDriver(g, S.RandomSearch(g, seed=2), budget=300,
                 batch_size=64, backend="vectorized", sinks=[both]).run()
    h1.merge(h2)
    assert h1.n_rows == both.n_rows
    assert h1.times == both.times
    assert h1.histogram.hi == both.histogram.hi
    np.testing.assert_array_equal(h1.histogram.counts,
                                  both.histogram.counts)
    ra, rb = h1.distill(), both.distill()
    assert tree_signature(ra.tree) == tree_signature(rb.tree)
    assert ra.training_error == rb.training_error
    with pytest.raises(TypeError):
        h1.merge(object())


def test_histogram_sink_on_param_space():
    """The out-of-core path is space-generic: a kernel parameter grid
    (threshold features, value-index encodings) distills identically
    through the histogram sink."""
    sp = demo_param_space()
    ds, hs = DatasetSink(sp), HistogramSink(sp, block_rows=7)
    SearchDriver(sp, S.ExhaustiveSearch(sp), budget=None,
                 batch_size=8, sinks=[ds, hs]).run()
    assert hs.n_rows == len(ds.schedules)
    rd, rh = ds.distill(), hs.distill()
    assert tree_signature(rd.tree) == tree_signature(rh.tree)
    assert rd.training_error == rh.training_error
    assert rd.render() == rh.render()


def test_decode_batch_roundtrips_canonical_encodings():
    """decode_batch(encode_batch(c)) returns the canonical candidate:
    identical cache key, for schedules and parameter grids, from both
    the (B, 2, N) form and the flattened key bytes."""
    from repro.space.base import as_space
    g = C.spmv_dag()
    sp = as_space(g)
    scheds = list(C.enumerate_schedules(g, 2))[:40]
    keys, enc = sp.encode_batch(scheds)
    back = sp.decode_batch(enc)
    keys2, _ = sp.encode_batch(back)
    assert keys == keys2
    flat = np.stack([np.frombuffer(k, dtype=np.int32) for k in keys])
    keys3, _ = sp.encode_batch(sp.decode_batch(flat))
    assert keys == keys3

    demo = demo_param_space()
    cands = list(demo.enumerate_candidates())
    dkeys, denc = demo.encode_batch(cands)
    assert demo.decode_batch(denc) == cands
    with pytest.raises(ValueError, match="out of range"):
        demo.decode_batch(np.full((1, len(demo.dims)), 99,
                                  dtype=np.int32))


def test_distill_histograms_validates_row_count():
    g = halo3d_dag()
    hs = HistogramSink(g)
    SearchDriver(g, S.RandomSearch(g, seed=0), budget=64,
                 batch_size=16, backend="vectorized",
                 sinks=[hs]).run()
    hs.times = hs.times[:-1]            # corrupt the corpus
    with pytest.raises(ValueError, match="rows"):
        R.distill(hs, histograms=hs)


# -- satellite (3a): merge is associative/commutative == single stream --------

@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(st.floats(min_value=0.0, max_value=1e6),
                         min_size=0, max_size=30),
                min_size=2, max_size=6),
       st.integers(min_value=1, max_value=32))
def test_streaming_histogram_merge_property(batches, half_bins):
    """merge() == single-stream add, associative and commutative, even
    when the shards' ranges differ by several doublings."""
    single = StreamingHistogram(half_bins=half_bins)
    shards = []
    for batch in batches:
        v = np.asarray(batch, dtype=np.float64)
        single.add(v)
        h = StreamingHistogram(half_bins=half_bins)
        h.add(v)
        shards.append(h)

    def fold(hs):
        acc = StreamingHistogram(half_bins=half_bins)
        for h in hs:
            acc.merge(h)
        return acc

    left = fold(shards)
    right = fold(list(reversed(shards)))           # commutativity
    # associativity: merge a pre-merged pair into the rest
    pair = fold(shards[:2])
    nested = fold([pair] + shards[2:])
    for h in (left, right, nested):
        assert h.hi == single.hi
        np.testing.assert_array_equal(h.counts, single.counts)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_class_count_histogram_merge_property(seed):
    """ClassCountHistogram.merge == single-stream add, in any order,
    including shards whose value grids differ."""
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 6))
    K = int(rng.integers(2, 5))
    grids = [np.unique(np.round(rng.random(int(rng.integers(1, 5))), 2))
             for _ in range(d)]
    n_shards = int(rng.integers(2, 5))
    shards, single = [], ClassCountHistogram(grids, K)
    for _ in range(n_shards):
        m = int(rng.integers(0, 40))
        X = np.stack([g[rng.integers(0, g.size, m)] for g in grids],
                     axis=1) if m else np.zeros((0, d))
        y = rng.integers(0, K, m).astype(np.int32)
        single.add(X, y)
        # each shard only declares the values it actually saw (plus one
        # guaranteed bin), so shard grids genuinely differ
        sh_grids = [np.unique(X[:, j]) if m else grids[j][:1]
                    for j in range(d)]
        sh = ClassCountHistogram(sh_grids, K)
        sh.add(X, y)
        shards.append(sh)
    acc = shards[0]
    for sh in shards[1:]:
        acc = acc.merge(sh)
    rev = shards[-1]
    for sh in reversed(shards[:-1]):
        rev = rev.merge(sh)
    for merged in (acc, rev):
        # project the merged counts onto the full grids for comparison
        onto = ClassCountHistogram(grids, K).merge(merged)
        np.testing.assert_array_equal(onto.counts, single.counts)
    assert single.n == sum(sh.n for sh in shards)


# -- satellite (3b): subtraction == fresh scan --------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0, 1, 2, 3]))
def test_histogram_subtraction_equals_fresh_scan(seed, kind):
    """Every frontier histogram the grower holds — half of which were
    produced purely by ``parent - left`` subtraction — equals a fresh
    scan over the rows that actually reach that node."""
    rng = np.random.default_rng(seed)
    X, y = random_dataset(rng, kind)
    grower = HistogramGrower(_blocks(X, 13), y)
    tree = grower.fit(max_leaf_nodes=8)     # expands several levels

    def path_mask(nd, target, mask):
        if nd is target:
            return mask
        if nd.left is None:
            return None
        col = X[:, nd.feature] <= nd.threshold
        got = path_mask(nd.left, target, mask & col)
        if got is None:
            got = path_mask(nd.right, target, mask & ~col)
        return got

    for nd in grower._frontier:
        if nd.hist is None:
            continue
        mask = path_mask(grower.root, nd,
                         np.ones(len(X), dtype=bool))
        fresh = ClassCountHistogram(grower.values, grower.n_classes)
        fresh.add(X[mask], grower.y_enc[mask])
        np.testing.assert_array_equal(nd.hist.counts, fresh.counts)
        np.testing.assert_array_equal(nd.counts, fresh.class_counts())
    # the structure itself must match the in-memory reference
    ref = R.DecisionTree(max_leaf_nodes=8).fit(
        np.asarray(X, dtype=np.float64), y)
    assert tree_signature(ref) == tree_signature(tree)
    # subtract() refuses non-sub-histograms
    empty = ClassCountHistogram(grower.values, grower.n_classes)
    one = ClassCountHistogram(grower.values, grower.n_classes)
    one.add(X[:1], grower.y_enc[:1])
    with pytest.raises(ValueError, match="sub-histogram"):
        empty.subtract(one)


# -- satellite (3c): fit is invariant to block size ---------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([0, 1, 2, 3]),
       st.integers(min_value=1, max_value=200))
def test_histogram_fit_invariant_to_block_size(seed, kind, block):
    """1 row per block, the whole corpus in one block, or anything in
    between: identical trees, all equal to the in-memory splitter."""
    rng = np.random.default_rng(seed)
    X, y = random_dataset(rng, kind)
    mln = int(rng.integers(2, 10))
    ref = R.DecisionTree(max_leaf_nodes=mln).fit(
        np.asarray(X, dtype=np.float64), y)
    want = tree_signature(ref)
    for b in {1, block, len(X)}:
        ooc = fit_from_histograms(_blocks(X, b), y, max_leaf_nodes=mln)
        assert tree_signature(ooc) == want, b
        assert ooc.training_error(np.asarray(X, dtype=np.float64), y) \
            == ref.training_error(np.asarray(X, dtype=np.float64), y)
