"""Memory-efficient GQA attention (XLA path).

Streaming (flash-style) softmax over KV blocks in pure JAX: memory per
step is O(block_q * block_k) instead of O(S^2), which is what makes the
prefill_32k and train_4k cells compile within HBM. The Pallas TPU kernel
in ``repro/kernels/flash_attention`` implements the same contraction for
real-TPU execution; models default to this XLA path so the 512-device
CPU dry-run lowers without an interpreter graph.

Supports: causal & sliding-window masks, cross-attention, KV-cache
decode, optional logit softcap, QKV biases (qwen2.5).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import rope
from repro.models.params import Spec

NEG_INF = -1e30


def attention_specs(cfg: ModelConfig) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads_padded, cfg.n_kv_heads
    # KV projections use a distinct logical axis for their input dim so
    # per-arch rules can switch them to row-parallel when kv_heads does
    # not divide the model axis (see launch.inputs.rules_for).
    out = {
        "wq": Spec((d, hq, dh), ("d_model", "heads", "head_dim")),
        "wk": Spec((d, hkv, dh), ("d_model_kv", "kv_heads", "head_dim")),
        "wv": Spec((d, hkv, dh), ("d_model_kv", "kv_heads", "head_dim")),
        "wo": Spec((hq, dh, d), ("heads", "head_dim", "d_model")),
    }
    if cfg.qkv_bias:
        out["bq"] = Spec((hq, dh), ("heads", "head_dim"), init="zeros")
        out["bk"] = Spec((hkv, dh), ("kv_heads", "head_dim"), init="zeros")
        out["bv"] = Spec((hkv, dh), ("kv_heads", "head_dim"), init="zeros")
    return out


def slot_is_real(cfg: ModelConfig) -> list[bool]:
    """Validity per padded q-head slot (see ModelConfig.head_layout).

    Slots are arranged as K stored-KV groups of g_p; stored copy
    c = (slot_group % r) covers real heads [c*g_p, min((c+1)*g_p, g))
    of its true KV head."""
    k, g_p, hq_p = cfg.head_layout()
    r = k // cfg.n_kv_heads
    g = cfg.n_heads // cfg.n_kv_heads
    out = []
    for h in range(hq_p):
        s, i = divmod(h, g_p)
        c = s % r
        out.append(c * g_p + i < g)
    return out


def slot_to_real(cfg: ModelConfig) -> list[int | None]:
    """Real head index per slot (None for dummy slots) — tests use this
    to check padded == unpadded exactness."""
    k, g_p, hq_p = cfg.head_layout()
    r = k // cfg.n_kv_heads
    g = cfg.n_heads // cfg.n_kv_heads
    out = []
    for h in range(hq_p):
        s, i = divmod(h, g_p)
        j, c = divmod(s, r)
        real = j * g + c * g_p + i
        out.append(real if c * g_p + i < g else None)
    return out


def head_mask(cfg: ModelConfig) -> jax.Array | None:
    """1 for real q-head slots, 0 for padding slots."""
    if cfg.n_heads_padded == cfg.n_heads and \
            cfg.head_layout()[0] == cfg.n_kv_heads:
        return None
    return jnp.asarray(slot_is_real(cfg))


def repeat_kv(cfg: ModelConfig, kv: jax.Array) -> jax.Array:
    """Duplicate KV heads to the stored-KV width K = r * hkv.

    Activation-level (and cache-level) duplication: the weights stay
    un-duplicated (exact GQA semantics; duplicated activations receive
    summed gradients). 2x KV bytes for r=2, in exchange for an evenly
    sharded stored-KV dim — the vLLM-style TP answer to hkv < tp."""
    k = cfg.head_layout()[0]
    r = k // cfg.n_kv_heads
    if r == 1:
        return kv
    idx = jnp.asarray([t // r for t in range(k)])
    return jnp.take(kv, idx, axis=2)


def project_qkv(p: dict, xq: jax.Array, xkv: jax.Array, cfg: ModelConfig):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def out_proj(p: dict, o: jax.Array, cfg: ModelConfig) -> jax.Array:
    hm = head_mask(cfg)
    if hm is not None:
        # Zero padding heads: exact n_heads semantics (and zero grads
        # into the dummy slices of wq/wo).
        o = o * hm[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_k", "softcap"))
def streaming_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_positions: jax.Array,
                        kv_positions: jax.Array,
                        kv_valid: jax.Array,
                        *, causal: bool = True,
                        window: int | None = None,
                        block_k: int = 1024,
                        softcap: float | None = None) -> jax.Array:
    """Online-softmax attention over KV blocks.

    q: (B, Sq, Hq, Dh);  k, v: (B, T, K, Dh) where K is the stored-KV
    width (after repeat_kv) and Hq = g_p * K.
    q_positions: (Sq,), kv_positions: (T,), kv_valid: (T,) bool.
    """
    b, sq, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    hkv_eff, g = hkv, hq // hkv
    scale = dh ** -0.5

    pad = (-t) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad))
        kv_valid = jnp.pad(kv_valid, (0, pad))
    nk = (t + pad) // block_k

    ha = "kv_stored"
    qh = (q * scale).astype(jnp.float32).reshape(b, sq, hkv_eff, g, dh)
    qh = qh.transpose(0, 2, 3, 1, 4)                # (B,Hkv,G,Sq,Dh)
    qh = constrain(qh, ("batch", ha, None, None, None))
    kb = k.reshape(b, nk, block_k, hkv, dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, block_k, hkv, dh).transpose(1, 0, 3, 2, 4)
    kb = constrain(kb, (None, "batch", ha, None, None))
    vb = constrain(vb, (None, "batch", ha, None, None))
    pos_b = kv_positions.reshape(nk, block_k)
    val_b = kv_valid.reshape(nk, block_k)

    def step(carry, blk):
        m, l, acc = carry
        kk, vv, kp, kval = blk                      # (B,K,bk,Dh)...
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qh,
                       kk.astype(jnp.float32))
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = kval[None, :]                        # (1, bk)
        if causal:
            mask = mask & (kp[None, :] <= q_positions[:, None])
        if window is not None:
            mask = mask & (kp[None, :] >
                           q_positions[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # Fully-masked blocks: exp(-inf - -inf) == 1; zero them explicitly.
        p = p * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vv.astype(jnp.float32))
        m_new = constrain(m_new, ("batch", ha, None, None))
        l_new = constrain(l_new, ("batch", ha, None, None))
        acc_new = constrain(acc_new, ("batch", ha, None, None, None))
        return (m_new, l_new, acc_new), None

    m0 = constrain(jnp.full((b, hkv_eff, g, sq), NEG_INF, jnp.float32),
                   ("batch", ha, None, None))
    l0 = constrain(jnp.zeros((b, hkv_eff, g, sq), jnp.float32),
                   ("batch", ha, None, None))
    a0 = constrain(jnp.zeros((b, hkv_eff, g, sq, dh), jnp.float32),
                   ("batch", ha, None, None, None))
    # Nested remat: recompute block scores in the backward pass instead
    # of saving the (B,H,Sq,block_k) score tensors per block (the
    # flash-attention memory posture, expressed through autodiff).
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kb, vb, pos_b, val_b))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


@dataclasses.dataclass
class KVCache:
    """Preallocated decode cache for one attention layer stack.

    k, v: (L, B, T_max, Hkv, Dh). Position bookkeeping lives with the
    caller (a single scalar since batched decode is position-aligned).
    """

    k: jax.Array
    v: jax.Array

    @staticmethod
    def zeros(n_layers: int, batch: int, t_max: int, cfg: ModelConfig,
              dtype) -> "KVCache":
        shape = (n_layers, batch, t_max, cfg.n_kv_heads, cfg.head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v"], meta_fields=[])


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, *, causal: bool = True,
                 memory: jax.Array | None = None,
                 memory_valid: jax.Array | None = None,
                 block_k: int = 1024) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross)."""
    xkv = memory if memory is not None else x
    q, k, v = project_qkv(p, x, xkv, cfg)
    if memory is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        kv_pos = positions
        kv_val = jnp.ones(xkv.shape[1], bool)
    else:
        kv_pos = jnp.arange(xkv.shape[1])
        kv_val = memory_valid if memory_valid is not None \
            else jnp.ones(xkv.shape[1], bool)
        causal = False
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    o = streaming_attention(
        q, repeat_kv(cfg, k), repeat_kv(cfg, v), positions, kv_pos,
        kv_val, causal=causal, window=cfg.attn_window, block_k=block_k,
        softcap=cfg.attn_logit_softcap)
    o = constrain(o, ("batch", "seq", "heads", "head_dim"))
    return out_proj(p, o, cfg)


def _decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      pos: jax.Array, kv_pos: jax.Array,
                      *, window: int | None,
                      softcap: float | None) -> jax.Array:
    """Direct (scan-free) attention for Sq == 1.

    The streaming path's reshape/transpose of the cache into scan
    operands copies the whole cache per layer — tens of GB at decode
    shapes. For one query the scores tensor is only (B, Hq, T) f32, so
    plain masked softmax is both smaller and collective-free.
    """
    b, _, hq, dh = q.shape
    t, kk = k.shape[1], k.shape[2]
    g = hq // kk
    qh = (q[:, 0].reshape(b, kk, g, dh) * dh ** -0.5).astype(jnp.float32)
    qh = constrain(qh, ("batch", "kv_stored", None, None))
    s = jnp.einsum("bkgd,btkd->bkgt", qh, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = kv_pos <= pos
    if window is not None:
        mask = mask & (kv_pos > pos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", pr, v.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


def attn_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                pos: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                *, block_k: int = 1024):
    """Single-token decode. x: (B, 1, D); cache_*: (B, T, K, Dh).

    Returns (out (B, 1, D), new_cache_k, new_cache_v).
    """
    q, k, v = project_qkv(p, x, x, cfg)
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)
    # The cache stores the duplicated (stored-KV width) heads so it
    # shards evenly on the model axis.
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, repeat_kv(cfg, k).astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, repeat_kv(cfg, v).astype(cache_v.dtype), (0, pos, 0, 0))
    t = cache_k.shape[1]
    k_att, v_att = cache_k, cache_v
    kv_pos = jnp.arange(t)
    if cfg.attn_window is not None and t > 2 * cfg.attn_window:
        # Long-context windowed decode: only the trailing window can
        # attend — slice it out instead of scanning the whole cache.
        w = cfg.attn_window
        start = jnp.clip(pos + 1 - w, 0, t - w)
        k_att = jax.lax.dynamic_slice_in_dim(cache_k, start, w, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(cache_v, start, w, axis=1)
        kv_pos = start + jnp.arange(w)
    o = _decode_attention(q, k_att, v_att, pos, kv_pos,
                          window=cfg.attn_window,
                          softcap=cfg.attn_logit_softcap)
    return out_proj(p, o, cfg), cache_k, cache_v
