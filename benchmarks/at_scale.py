"""At-scale search benchmarks on spaces exhaustive sweeps cannot touch.

``spmv_dag_fine`` (>5e5 implementations) and ``halo3d_dag`` are far
beyond exhaustive enumeration; here the greedy→MCTS→surrogate
portfolio races plain MCTS under an *equal discrete-event-simulation
budget* (``run_search(sim_budget=...)``, batch_size=1 for an exact
cap). Rows report best makespans, the portfolio-vs-MCTS ratio, the
surrogate's screening quality (candidates screened per simulation
spent, Spearman rank correlation of predicted vs simulated times), the
portfolio evaluator's ``stats()`` cache-traffic summary, and — via
``repro.rules.distill`` — the design rules the portfolio's corpus
supports (classes, rulesets, tree error).
"""
from __future__ import annotations

import time

import repro.rules as R
import repro.search as S
from repro.core.dag import halo3d_dag, spmv_dag_fine


def _race(name: str, graph, sim_budget: int, seed: int = 0) -> list[str]:
    t0 = time.perf_counter()
    res_m = S.run_search(graph, S.MCTSSearch(graph, 2, seed=seed),
                         budget=None, sim_budget=sim_budget, batch_size=1)
    wall_m = (time.perf_counter() - t0) / max(1, res_m.cache_misses) * 1e6

    # seed_proposals=0: greedy seeding pays prefix simulations the
    # sim_budget meter cannot see, which would make the race unfair.
    port = S.PortfolioSearch(graph, 2, seed=seed, seed_proposals=0)
    ev_p = S.make_evaluator(graph, "sim")
    t0 = time.perf_counter()
    res_p = S.run_search(graph, port, budget=None,
                         sim_budget=sim_budget, batch_size=1,
                         evaluator=ev_p)
    wall_p = (time.perf_counter() - t0) / max(1, res_p.cache_misses) * 1e6

    best_m, best_p = res_m.best()[1], res_p.best()[1]
    q = port.screening_quality()
    screened_per_sim = q["n_screened"] / max(1, res_p.cache_misses)
    st = ev_p.stats()
    t0 = time.perf_counter()
    rep = R.distill(res_p)
    wall_r = (time.perf_counter() - t0) * 1e6
    rs = rep.summary()
    return [
        f"at_scale_{name}_rules,{wall_r:.2f},"
        f"classes={rs['n_classes']}/rulesets={rs['n_rulesets']}/"
        f"err={rs['training_error']:.3f}",
        f"at_scale_{name}_evaluator,{wall_p:.2f},"
        f"backend={st['backend']}/memory_hits={st['memory_hits']}/"
        f"store_hits={st['store_hits']}/"
        f"misses={st['misses']}/size={st['size']}/"
        f"hit_rate={st['hit_rate']:.2f}",
        f"at_scale_{name}_sims,{wall_p:.2f},"
        f"{res_p.cache_misses}_of_{sim_budget}",
        f"at_scale_{name}_mcts_best_us,{wall_m:.2f},{best_m * 1e6:.2f}",
        f"at_scale_{name}_portfolio_best_us,{wall_p:.2f},"
        f"{best_p * 1e6:.2f}",
        f"at_scale_{name}_portfolio_vs_mcts,{wall_p:.2f},"
        f"{best_p / best_m:.4f}",
        f"at_scale_{name}_screened_per_sim,{wall_p:.2f},"
        f"{screened_per_sim:.1f}",
        f"at_scale_{name}_surrogate_spearman,{wall_p:.2f},"
        f"{q['spearman']:.3f}",
        f"at_scale_{name}_surrogate_rel_err,{wall_p:.2f},"
        f"{q['mean_rel_err']:.3f}",
    ]


def at_scale_benches() -> list[str]:
    rows = []
    rows += _race("spmv_fine", spmv_dag_fine(), sim_budget=400)
    rows += _race("halo3d", halo3d_dag(), sim_budget=300)
    return rows
