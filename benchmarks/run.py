"""Benchmark harness — one function per paper table/figure, plus kernel,
substrate, featurization, evaluation-engine, tree-kernel/surrogate, and
at-scale search benches.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the same rows as machine-readable JSON
(``[{"name":..., "us_per_call":..., "derived":...}, ...]``) so the
perf trajectory can accumulate across PRs, e.g.::

    PYTHONPATH=src python benchmarks/run.py --json BENCH_4.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Allow ``python benchmarks/run.py`` (script dir on sys.path, repo root
# not): the ``benchmarks`` package lives one level up.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.at_scale import at_scale_benches
from benchmarks.autotune_bench import autotune_benches
from benchmarks.driver_bench import driver_benches
from benchmarks.engine_bench import engine_benches
from benchmarks.featurize_bench import featurize_benches
from benchmarks.kernels_bench import (kernel_benches, model_benches,
                                      search_eval_benches)
from benchmarks.paper import (fig1_spread, fig4_labels, fig5_tree,
                              granularity_ablation, noise_robustness,
                              stepdag_overlap, table5_accuracy,
                              tables678_rules)
from benchmarks.trees_bench import trees_benches

BENCH_FNS = (fig1_spread, fig4_labels, fig5_tree, table5_accuracy,
             tables678_rules, stepdag_overlap, granularity_ablation,
             noise_robustness, featurize_benches, trees_benches,
             engine_benches, autotune_benches, driver_benches,
             at_scale_benches, search_eval_benches, kernel_benches,
             model_benches)


def parse_row(row: str) -> dict:
    """``name,us_per_call,derived`` CSV line -> JSON-ready dict.

    ``derived`` may itself contain commas (class-size lists etc.), so
    only the first two fields are split off.
    """
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON list to PATH")
    args = ap.parse_args()

    rows: list[str] = []
    print("name,us_per_call,derived")
    for fn in BENCH_FNS:
        for row in fn():
            print(row, flush=True)
            rows.append(row)

    if args.json:
        records = [parse_row(row) for row in rows]
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} rows to {args.json}", flush=True)


if __name__ == "__main__":
    main()
