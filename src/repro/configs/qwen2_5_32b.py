"""qwen2.5-32b [dense]: GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064, mlp="swiglu", qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="qwen2.5-32b-reduced", family="dense",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1,
    d_ff=192, vocab=512, mlp="swiglu", qkv_bias=True,
)
