"""Compiled-HLO analyzer: loop-aware flops / collective-bytes accounting.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
under-reports scanned-layer-stack programs by ~n_layers x. This analyzer
parses ``compiled.as_text()`` (the per-partition, post-SPMD module) and:

  * recovers while-loop trip counts automatically from the loop
    condition's ``compare(iter, constant(N))`` pattern (lax.scan shape),
  * attributes every instruction a multiplier = product of enclosing
    loop trips (via the computation call graph: body=/condition=/
    to_apply=/calls= references),
  * sums dot-general flops (2 x |out| x contraction) and collective
    operand bytes (all-gather / all-reduce / reduce-scatter / all-to-all
    / collective-permute), each scaled by its multiplier.

Counts are per partition (the module is the per-device program), which
is exactly what the roofline terms need (seconds on one chip).
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
# Header params may contain nested tuple types: match greedily up to ->.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_CALLREF_RE = re.compile(
    r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
               "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    out = []
    for _dt, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclasses.dataclass
class Instruction:
    name: str
    text: str          # full RHS
    opcode: str
    type_str: str


@dataclasses.dataclass
class Computation:
    name: str
    instructions: dict[str, Instruction]
    is_entry: bool


def parse_module(hlo_text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), {},
                                  line.strip().startswith("ENTRY"))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # rhs = "<type> <opcode>(...), attrs"
        tm = re.match(r"((?:\([^)]*\)|\S+))\s+([\w\-]+)\(", rhs)
        if not tm:
            continue
        cur.instructions[name] = Instruction(
            name=name, text=rhs, opcode=tm.group(2),
            type_str=tm.group(1))
    return comps


def _resolve_const(comp: Computation, name: str,
                   hops: int = 4) -> int | None:
    """Follow copies/bitcasts to an s32 constant definition."""
    for _ in range(hops):
        ins = comp.instructions.get(name)
        if ins is None:
            return None
        cm = re.match(r"constant\((\d+)\)", ins.text.split(" ", 1)[1]
                      if " " in ins.text else "")
        cm = re.search(r"^\S+\s+constant\((\d+)\)", ins.text)
        if cm:
            return int(cm.group(1))
        nxt = re.match(r"\S+\s+(?:copy|bitcast|convert)\(%([\w.\-]+)\)",
                       ins.text)
        if not nxt:
            return None
        name = nxt.group(1)
    return None


def _trip_count(comps: dict[str, Computation], cond_name: str,
                while_ins: "Instruction", caller: Computation) -> int:
    """Recover a scan loop's trip count.

    lax.scan's condition is ``compare(iter, N), direction=LT``; N is
    either a constant inside the condition, or a loop-invariant carry
    element (the "wide" form) whose value is a constant in the caller's
    init tuple. Both are resolved; fallback = largest s32 constant seen
    in the condition (or 1).
    """
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    # Gather candidate bound operands from LT compares.
    for ins in cond.instructions.values():
        if not ins.opcode == "compare" or "direction=LT" not in ins.text:
            continue
        ops = re.findall(r"%([\w.\-]+)", ins.text.split("compare", 1)[1])
        for o in ops[:2]:
            c = _resolve_const(cond, o)
            if c is not None and c > 1:
                return c
            # get-tuple-element(param, index=i) -> caller init tuple.
            gte = cond.instructions.get(o)
            if gte is None or gte.opcode != "get-tuple-element":
                continue
            im = re.search(r"index=(\d+)", gte.text)
            if not im:
                continue
            idx = int(im.group(1))
            init = re.search(r"while\(%([\w.\-]+)\)", while_ins.text)
            if not init:
                continue
            tup = caller.instructions.get(init.group(1))
            if tup is None or tup.opcode != "tuple":
                continue
            elems = re.findall(r"%([\w.\-]+)",
                               tup.text.split("tuple", 1)[1])
            if idx < len(elems):
                c = _resolve_const(caller, elems[idx])
                if c is not None and c > 1:
                    return c
    # Fallback heuristic: any s32 constant in the condition body.
    best = 1
    for ins in cond.instructions.values():
        cm = re.search(r"constant\((\d+)\)", ins.text)
        if cm and ins.type_str.startswith("s32"):
            best = max(best, int(cm.group(1)))
    return best


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Effective execution count per computation."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = {name: 0.0 for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # Propagate in passes (call graph is a DAG; few levels deep).
    for _ in range(32):
        changed = False
        for comp in comps.values():
            m = mult.get(comp.name, 0.0)
            if m == 0.0:
                continue
            for ins in comp.instructions.values():
                if ins.opcode == "while":
                    body = re.search(r"body=%?([\w.\-]+)", ins.text)
                    cond = re.search(r"condition=%?([\w.\-]+)", ins.text)
                    if body and cond:
                        trips = _trip_count(comps, cond.group(1),
                                            ins, comp)
                        new = m * trips
                        if mult.get(body.group(1), 0.0) < new:
                            mult[body.group(1)] = new
                            changed = True
                        if mult.get(cond.group(1), 0.0) < new:
                            mult[cond.group(1)] = new
                            changed = True
                else:
                    for ref in _CALLREF_RE.findall(ins.text):
                        if mult.get(ref, 0.0) < m:
                            mult[ref] = m
                            changed = True
        if not changed:
            break
    return mult


def _dot_flops(ins: Instruction,
               comp: Computation) -> float:
    """2 x |output| x contraction size for a dot-general."""
    out_dims = _shape_dims(ins.type_str)
    out_elems = 1
    for d in (out_dims[0] if out_dims else []):
        out_elems *= d
    # lhs operand shape. Operands may carry inline types
    # ("dot(f32[64,64]{1,0} %lhs, ...)" in newer XLA dumps) or not
    # ("dot(%lhs, %rhs)"); the first %-reference in the RHS is the lhs
    # either way.
    ops = re.findall(r"%([\w.\-]+)", ins.text)
    contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.text)
    if not ops or not contract:
        return 0.0
    lhs = comp.instructions.get(ops[0])
    if lhs is None:
        return 0.0
    lhs_dims_list = _shape_dims(lhs.type_str)
    lhs_dims = lhs_dims_list[0] if lhs_dims_list else []
    csize = 1
    cdims = contract.group(1)
    if cdims:
        for ci in cdims.split(","):
            idx = int(ci)
            if idx < len(lhs_dims):
                csize *= lhs_dims[idx]
    return 2.0 * out_elems * csize


@dataclasses.dataclass
class HloAnalysis:
    dot_flops: float                 # loop-corrected, per partition
    collective_bytes: dict[str, float]  # per collective kind
    collective_count: dict[str, int]
    loop_trips: list[int]
    # f32 collective bytes in bf16-compute programs: XLA-CPU computes
    # dots in f32 and all-reduces the f32 partials; a TPU lowering
    # reduces in bf16 (half the bytes). Tracked so the roofline can
    # report a TPU-adjusted collective term.
    collective_bytes_f32: float = 0.0
    # XLA-CPU wraps bf16 compute in whole-buffer f32 converts (no native
    # bf16); these shadow buffers inflate memory_analysis vs a real-TPU
    # lowering. Sum of large (>=64 MB) bf16->f32 convert outputs:
    cpu_upcast_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo_text: str) -> HloAnalysis:
    comps = parse_module(hlo_text)
    mult = _multipliers(comps)
    flops = 0.0
    cbytes: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    ccount: dict[str, int] = {k: 0 for k in COLLECTIVES}
    trips: list[int] = []
    upcast = 0.0
    f32bytes = 0.0
    for comp in comps.values():
        for ins in comp.instructions.values():
            if not ins.type_str.startswith("f32"):
                continue
            if ins.opcode == "convert" or (
                    ins.opcode == "fusion" and "convert" in ins.name):
                nbytes = _shape_bytes(ins.type_str)
                if nbytes >= 64e6:
                    # First %-ref in the RHS is the operand, with or
                    # without inline operand types (see _dot_flops).
                    ops = re.findall(r"%([\w.\-]+)", ins.text)
                    src = comp.instructions.get(ops[0]) if ops \
                        else None
                    if src is None or src.type_str.startswith("bf16") \
                            or src.opcode == "parameter":
                        upcast += nbytes
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            m = 1.0 if comp.is_entry else 0.0
        if m == 0.0:
            continue
        for ins in comp.instructions.values():
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, comp)
            elif ins.opcode == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.text)
                if cond:
                    trips.append(_trip_count(comps, cond.group(1),
                                             ins, comp))
            else:
                for kind in COLLECTIVES:
                    if ins.opcode.startswith(kind):
                        # Operand bytes: prefer operand shapes (the
                        # result of all-gather counts the gathered
                        # size); use operand instruction types.
                        ops = re.findall(r"%([\w.\-]+)", ins.text)
                        ob = 0
                        for o in ops:
                            src = comp.instructions.get(o)
                            if src is not None:
                                ob += _shape_bytes(src.type_str)
                        if ob == 0:  # fallback: result size
                            ob = _shape_bytes(ins.type_str)
                        cbytes[kind] += m * ob
                        ccount[kind] += 1
                        if ins.type_str.startswith("f32") or \
                                ins.type_str.startswith("(f32"):
                            f32bytes += m * ob
                        break
    return HloAnalysis(dot_flops=flops, collective_bytes=cbytes,
                       collective_count=ccount, loop_trips=sorted(trips),
                       cpu_upcast_bytes=upcast,
                       collective_bytes_f32=f32bytes)
