"""The telemetry core: spans, counters, gauges, and the registry.

The reproduction is a *measurement-driven* pipeline — search rounds,
evaluator batches, store traffic, distillation stages — yet until this
module its own runtime was opaque: timing lived in ad-hoc ``stats()``
dicts and private per-stage walls. :class:`Telemetry` is the one
process-wide place all of that lands:

* **Spans** — hierarchical begin/end intervals on the monotonic clock
  (``with obs.span("driver.round", round=i) as sp: ...``), nested via a
  thread-local stack, with arbitrary key/value attributes attached at
  open time or later through :meth:`Span.set`. Finished spans stream to
  every attached exporter (:mod:`repro.obs.exporters`) and fold into a
  per-name (count, total seconds) aggregate for :meth:`Telemetry.
  summary`.
* **Counters / gauges** — typed named values (`counter("engine.misses")
  .add(n)`, ``gauge("driver.best").set(t)``); counter/gauge updates are
  also streamed as Chrome-trace ``"C"`` events so Perfetto renders them
  as tracks under the span timeline.

**Telemetry is a pure observer.** Nothing in this module is ever read
back by the instrumented code: timestamps never feed RNGs, cache keys,
or tie-breaks, so a search with an exporter attached is byte-identical
to one without (locked by tests/test_obs.py). The *disabled* registry
(the process default) reduces every instrumentation point to one
attribute check plus a no-op singleton — well under 1% of a
discrete-event simulation — so instrumented hot paths cost nothing
until someone attaches a real :class:`Telemetry`.

Usage::

    from repro import obs

    tel = obs.Telemetry(exporters=[obs.PerfettoExporter("out.json")])
    with obs.use(tel):                       # or obs.set_current(tel)
        run_search(...)
    tel.close()                              # flush exporters
    print(tel.summary())                     # human table
"""
from __future__ import annotations

import contextlib
import os
import threading
import time
from typing import TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.obs.exporters import Exporter


class Counter:
    """Monotonically increasing named value (events, bytes, hits)."""

    __slots__ = ("name", "value", "_tel")

    def __init__(self, name: str, tel: "Telemetry"):
        self.name = name
        self.value = 0.0
        self._tel = tel

    def add(self, n: float = 1.0) -> None:
        self.value += n
        self._tel._emit_value(self.name, self.value)


class Gauge:
    """Last-write-wins named value (best-so-far, pool size)."""

    __slots__ = ("name", "value", "_tel")

    def __init__(self, name: str, tel: "Telemetry"):
        self.name = name
        self.value = 0.0
        self._tel = tel

    def set(self, v: float) -> None:
        self.value = float(v)
        self._tel._emit_value(self.name, self.value)


class Span:
    """One begin/end interval on the monotonic clock.

    Context-manager only: ``__enter__`` stamps the begin and emits a
    ``"B"`` event; ``__exit__`` stamps the end, emits the matching
    ``"E"`` event (attributes attached to the end event, where
    late-``set`` values are visible), and folds the wall into the
    registry's per-name aggregate. Exceptions propagate untouched.
    """

    __slots__ = ("name", "attrs", "_tel", "_t0")

    def __init__(self, name: str, tel: "Telemetry", attrs: dict):
        self.name = name
        self.attrs = attrs
        self._tel = tel
        self._t0 = 0

    def set(self, **attrs) -> None:
        """Attach attributes discovered mid-span (e.g. batch meters)."""
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter_ns()
        self._tel._begin(self)
        return self

    def __exit__(self, *exc) -> None:
        self._tel._end(self, time.perf_counter_ns())


class _NullSpan:
    """The disabled singleton: every instrumentation point degrades to
    one method call on this object."""

    __slots__ = ()

    def set(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


class _NullValue:
    """Disabled counter/gauge: ``add``/``set`` are no-ops."""

    __slots__ = ()
    value = 0.0

    def add(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass


_NULL_SPAN = _NullSpan()
_NULL_VALUE = _NullValue()


class Telemetry:
    """Process-wide registry: spans + counters + gauges + exporters.

    ``exporters`` is any iterable of objects with an
    ``export(event: dict)`` method and a ``close()``
    (:mod:`repro.obs.exporters` ships JSONL and Perfetto/Chrome-trace
    implementations; an empty list keeps everything in-memory for the
    :meth:`summary` table and the ``spans_by_name`` aggregate, which is
    how tests and the CI warm-start gate read it).

    Timestamps are ``time.perf_counter_ns`` offsets from registry
    construction, exported in microseconds — monotone within a process,
    meaningless across processes (worker pools report through their
    parent's meters, never their own registry).
    """

    enabled = True

    def __init__(self, exporters: "list[Exporter] | tuple" = ()):
        self.exporters = list(exporters)
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._span_agg: dict[str, list] = {}     # name -> [count, total_s]
        self._t0 = time.perf_counter_ns()
        self._pid = os.getpid()
        self._local = threading.local()
        self._lock = threading.Lock()

    # -- the instrumentation API ------------------------------------------
    def span(self, name: str, **attrs) -> Span:
        return Span(name, self, attrs)

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name, self)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name, self)
        return g

    def event(self, name: str, **args) -> None:
        """A zero-duration instant event (round markers, truncations)."""
        self._export({"name": name, "ph": "i", "ts": self._ts_us(),
                      "pid": self._pid,
                      "tid": threading.get_ident() & 0xFFFFFFFF,
                      "s": "t", "args": args})

    # -- span plumbing -----------------------------------------------------
    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _ts_us(self, t_ns: int | None = None) -> float:
        if t_ns is None:
            t_ns = time.perf_counter_ns()
        return (t_ns - self._t0) / 1e3

    def _begin(self, span: Span) -> None:
        self._stack().append(span)
        self._export({"name": span.name, "ph": "B",
                      "ts": self._ts_us(span._t0), "pid": self._pid,
                      "tid": threading.get_ident() & 0xFFFFFFFF,
                      "args": dict(span.attrs)})

    def _end(self, span: Span, t1_ns: int) -> None:
        st = self._stack()
        if st and st[-1] is span:
            st.pop()
        dur_s = (t1_ns - span._t0) / 1e9
        with self._lock:
            agg = self._span_agg.setdefault(span.name, [0, 0.0])
            agg[0] += 1
            agg[1] += dur_s
        self._export({"name": span.name, "ph": "E",
                      "ts": self._ts_us(t1_ns), "pid": self._pid,
                      "tid": threading.get_ident() & 0xFFFFFFFF,
                      "args": dict(span.attrs)})

    def _emit_value(self, name: str, value: float) -> None:
        self._export({"name": name, "ph": "C", "ts": self._ts_us(),
                      "pid": self._pid, "tid": 0,
                      "args": {"value": value}})

    def _export(self, event: dict) -> None:
        for ex in self.exporters:
            ex.export(event)

    # -- read-side ---------------------------------------------------------
    def spans_by_name(self) -> dict[str, dict]:
        """Finished-span aggregate: name -> {count, total_s}."""
        with self._lock:
            return {name: {"count": agg[0], "total_s": agg[1]}
                    for name, agg in self._span_agg.items()}

    def counters(self) -> dict[str, float]:
        return {name: c.value for name, c in self._counters.items()}

    def gauges(self) -> dict[str, float]:
        return {name: g.value for name, g in self._gauges.items()}

    def summary(self) -> str:
        """The human table: spans (count/total/mean), counters, gauges."""
        lines = ["telemetry summary",
                 f"{'span':<28}{'count':>8}{'total_ms':>12}{'mean_us':>12}"]
        spans = self.spans_by_name()
        for name in sorted(spans):
            s = spans[name]
            mean_us = s["total_s"] / s["count"] * 1e6 if s["count"] else 0.0
            lines.append(f"{name:<28}{s['count']:>8}"
                         f"{s['total_s'] * 1e3:>12.2f}{mean_us:>12.1f}")
        if self._counters:
            lines.append(f"{'counter':<40}{'value':>20}")
            for name in sorted(self._counters):
                v = self._counters[name].value
                v = int(v) if float(v).is_integer() else v
                lines.append(f"{name:<40}{v:>20}")
        if self._gauges:
            lines.append(f"{'gauge':<40}{'value':>20}")
            for name in sorted(self._gauges):
                lines.append(f"{name:<40}{self._gauges[name].value:>20.6g}")
        return "\n".join(lines)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Flush and close every exporter; idempotent."""
        for ex in self.exporters:
            ex.close()
        self.exporters = []

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _DisabledTelemetry(Telemetry):
    """The process default: every call returns a no-op singleton."""

    enabled = False

    def __init__(self):
        super().__init__()

    def span(self, name: str, **attrs):
        return _NULL_SPAN

    def counter(self, name: str):
        return _NULL_VALUE

    def gauge(self, name: str):
        return _NULL_VALUE

    def event(self, name: str, **args) -> None:
        pass


DISABLED = _DisabledTelemetry()
_current: Telemetry = DISABLED


def current() -> Telemetry:
    """The active registry (the disabled singleton by default)."""
    return _current


def set_current(tel: Telemetry | None) -> Telemetry:
    """Install ``tel`` process-wide; returns the previous registry.
    ``None`` restores the disabled default."""
    global _current
    prev = _current
    _current = DISABLED if tel is None else tel
    return prev


@contextlib.contextmanager
def use(tel: Telemetry | None):
    """Scoped :func:`set_current` (the test-friendly form)."""
    prev = set_current(tel)
    try:
        yield tel
    finally:
        set_current(prev)


# Module-level shorthands — what instrumented code calls. Each is one
# global read + one method call when telemetry is disabled.
def span(name: str, **attrs):
    return _current.span(name, **attrs)


def counter(name: str):
    return _current.counter(name)


def gauge(name: str):
    return _current.gauge(name)


def event(name: str, **args) -> None:
    _current.event(name, **args)


def enabled() -> bool:
    return _current.enabled
