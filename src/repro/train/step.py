"""pjit train step: loss -> grads -> AdamW, with optional microbatch
gradient accumulation (compute/comm overlap lever) and logical-axis
shardings for every (arch x mesh) cell."""
from __future__ import annotations

import functools
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models.model import LM
from repro.optim.adamw import AdamW, apply_updates


def make_train_step(model: LM, opt: AdamW, microbatches: int = 1,
                    rwkv_chunk: int | None = None):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, rwkv_chunk=rwkv_chunk)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches,
                    g_acc, grads)
                return (g_acc, l_acc + loss / microbatches), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                acc_body, (g0, 0.0), micro)
            metrics = {"ce": loss, "z_loss": jnp.zeros(()),
                       "aux": jnp.zeros(())}
        params, opt_state = opt.step(grads, opt_state, params)
        metrics = dict(metrics, loss=loss,
                       step=opt_state["count"].astype(jnp.float32))
        return params, opt_state, metrics

    return train_step


def train_shardings(model: LM, mesh: Mesh,
                    rules: Mapping[str, Any] | None = None):
    """(params, opt_state, batch) shardings for jit in_shardings."""
    axes = model.param_axes()
    shapes = model.abstract_params()
    p_shard = shd.tree_shardings(axes, mesh, rules, shapes)
    opt_shard = {"mu": p_shard, "nu": p_shard,
                 "count": NamedSharding(mesh, P())}
    bspec = shd.batch_spec(mesh, extra_dims=1, rules=rules)
    b_shard = {"tokens": NamedSharding(mesh, bspec),
               "labels": NamedSharding(mesh, bspec)}
    if model.cfg.frontend is not None:
        b_shard["frontend"] = NamedSharding(
            mesh, shd.batch_spec(mesh, extra_dims=2, rules=rules))
    return p_shard, opt_shard, b_shard


def jit_train_step(model: LM, opt: AdamW, mesh: Mesh,
                   rules: Mapping[str, Any] | None = None,
                   microbatches: int = 1,
                   rwkv_chunk: int | None = None):
    step = make_train_step(model, opt, microbatches=microbatches,
                           rwkv_chunk=rwkv_chunk)
    p_sh, o_sh, b_sh = train_shardings(model, mesh, rules)
    return jax.jit(step,
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None),
                   donate_argnums=(0, 1)), (p_sh, o_sh, b_sh)
