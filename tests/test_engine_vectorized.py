"""engine.vectorized: bit-identical to core.costmodel.simulate.

The contract is exact float equality (==, not isclose): the batch
simulator must execute the same IEEE adds/maxes per element as the
serial discrete-event loop. Locked three ways — exhaustively on the
paper's coarse SpMV space, and by randomized property tests on the
fine-grained SpMV and halo3d spaces (uniform random canonical
schedules at 2 and 3 streams).
"""
import random

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C
import repro.engine as E
from repro.core.costmodel import Machine
from repro.core.dag import halo3d_dag, spmv_dag_fine
from repro.search.strategy import random_schedule


@pytest.fixture(scope="module")
def spmv_space():
    g = C.spmv_dag()
    return g, list(C.enumerate_schedules(g, 2))


def test_exhaustive_spmv_bit_identical(spmv_space):
    """The whole 280-schedule paper space, == on floats."""
    g, scheds = spmv_space
    ev = E.make_evaluator(g, "vectorized")
    assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]
    assert ev.cache_misses == len(scheds)


def test_exhaustive_spmv_bit_identical_custom_machine(spmv_space):
    g, scheds = spmv_space
    m = Machine(flops_per_s=100e12, hbm_bytes_per_s=500e9,
                launch_overhead_s=7e-6, sync_op_s=0.9e-6)
    ev = E.make_evaluator(g, "vectorized", machine=m)
    assert ev.evaluate(scheds) == [C.makespan(g, s, m) for s in scheds]


def test_simulate_batch_function_matches_simulate(spmv_space):
    """The raw batch simulator (no evaluator cache in front)."""
    g, scheds = spmv_space
    from repro.engine import GraphTables, simulate_batch
    from repro.core.costmodel import op_durations
    m = Machine()
    tables = GraphTables(g, m, op_durations(g, m))
    out = simulate_batch(tables, scheds)
    assert out.tolist() == [C.makespan(g, s, m) for s in scheds]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3))
def test_property_fine_grained_bit_identical(seed, n_streams):
    g = spmv_dag_fine()
    rng = random.Random(seed)
    scheds = [random_schedule(g, n_streams, rng) for _ in range(8)]
    ev = E.make_evaluator(g, "vectorized")
    assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 3))
def test_property_halo3d_bit_identical(seed, n_streams):
    g = halo3d_dag()
    rng = random.Random(seed)
    scheds = [random_schedule(g, n_streams, rng) for _ in range(6)]
    ev = E.make_evaluator(g, "vectorized")
    assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]


def test_non_canonical_input_hits_canonical_twin(spmv_space):
    """Stream-relabeled input must hit the canonical cache entry and
    produce the identical float (the simulator is bijection-invariant)."""
    g, scheds = spmv_space
    two = next(s for s in scheds if len(set(s.streams().values())) == 2)
    relabeled = C.Schedule(tuple(
        C.BoundOp(i.name, 1 - i.stream if i.stream is not None else None)
        for i in two.items))
    ev = E.make_evaluator(g, "vectorized")
    t0, t1 = ev.evaluate([two, relabeled])
    assert t0 == t1 == C.makespan(g, relabeled)
    assert (ev.cache_hits, ev.cache_misses) == (1, 1)


def test_vectorized_agrees_inside_run_search(spmv_space):
    """run_search(backend='vectorized') == run_search(backend='sim'),
    byte for byte, at batch_size > 1."""
    import repro.search as S
    g, _ = spmv_space
    results = {}
    for backend in ("sim", "vectorized"):
        res = S.run_search(g, S.MCTSSearch(g, 2, seed=3), budget=120,
                           batch_size=16, backend=backend)
        results[backend] = res
    a, b = results["sim"], results["vectorized"]
    assert a.times == b.times
    assert [s.key() for s in a.schedules] == [s.key() for s in b.schedules]
    assert (a.cache_hits, a.cache_misses) == (b.cache_hits, b.cache_misses)


def test_stepdag_supported():
    """The train-step DAG (GPU collectives, no CPU comm roles) encodes
    and simulates bit-identically too."""
    from repro.core.stepdag import StepCosts, train_step_dag
    g = train_step_dag(3, StepCosts(fwd_flops=1e12, bwd_flops=2e12,
                                    fwd_bytes=1e9, bwd_bytes=2e9,
                                    grad_bytes=5e8))
    rng = random.Random(0)
    scheds = [random_schedule(g, 2, rng) for _ in range(20)]
    ev = E.make_evaluator(g, "vectorized")
    assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]


def test_unsupported_rendezvous_graph_raises():
    """A WaitRecv whose posts are not DAG ancestors (no rendezvous
    edges) is rejected at construction, not silently mis-simulated."""
    from repro.core.dag import CommRole, Graph, Op, OpKind
    g = Graph()
    g.add_op(Op("PostRecv", OpKind.CPU, comm_bytes=8.0,
                comm_role=CommRole.POST_RECV))
    g.add_op(Op("WaitRecv", OpKind.CPU, comm_role=CommRole.WAIT_RECV))
    # No PostRecv -> WaitRecv edge: the post is not an ancestor.
    g.finalize()
    with pytest.raises(ValueError, match="ancestor"):
        E.make_evaluator(g, "vectorized")
