"""Evaluation as a service: the RPC wire protocol + the ``rpc`` backend.

The paper's bottleneck is measurement — MCTS explores far more
implementations than one host can evaluate — and the repo was one RPC
layer away from a fleet: :mod:`repro.engine.pool` already ships
canonical-unique misses as compact ``(k, 2, N)`` int32 encodings with
the cache / meters / noise kept parent-side. This module puts that
exact payload on a TCP socket so the "workers" can be evaluator
*hosts* anywhere (:mod:`repro.engine.server` is the other half), while
everything search-visible stays in the client:

* **Wire format.** Length-prefixed, CRC-framed binary messages reusing
  the store's record framing (:mod:`repro.engine.store`)::

      frame:   u32 payload_len | payload | u32 crc32(payload)
      payload: u8 msg_type | body

  Message bodies (little-endian): ``HELLO`` carries the protocol magic
  + version + the client's 16-byte ``store_fingerprint``; the server
  answers ``WELCOME`` (JSON info) or ``REFUSE`` (reason) — a server
  only ever evaluates for clients whose graph/machine/objective
  fingerprint matches its own, so results can never silently alias.
  ``EVAL`` is ``u32 shard_id | u8 ndim | u32 dims[ndim] | int32 data``
  — the canonical encoding rows exactly as :meth:`~repro.space.base.
  DesignSpace.encode_batch` produced them; ``RESULT`` is
  ``u32 shard_id | f64 times[k]``. Corrupt frames raise
  :class:`RpcProtocolError` and count as a host failure, never as data.

* **Pipelined dispatch.** :class:`RpcEvaluator` splits each miss batch
  into contiguous shards and keeps up to ``max_inflight`` shards in
  flight *per connection* (requests are sent back-to-back before the
  first response is read), across all hosts at once. Responses are
  matched by shard index, and shards partition the batch in
  first-appearance order — so the assembled result list, and therefore
  the ``(features, labels, times)`` dataset and budget accounting, is
  **bit-identical** to the serial backend no matter how many hosts
  raced or in what order they answered.

* **Fault tolerance.** Each shard dispatch runs under a ``deadline``;
  a timeout, connection drop, or protocol error re-queues the host's
  un-answered shards (bounded by ``retries`` re-dispatches per shard,
  exponential ``backoff`` per host), an idle host *hedges* straggler
  shards that are still in flight elsewhere (first result wins — both
  computed the same deterministic base time), and when every host is
  down the remaining shards degrade gracefully to local serial
  evaluation (``local_fallback=True``), so a search never dies with
  its fleet.

* **Observability.** ``rpc.send`` / ``rpc.recv`` / ``rpc.retry``
  spans and per-host byte + latency counters land in :mod:`repro.obs`,
  and :meth:`RpcEvaluator.rpc_stats` exposes the same numbers as a
  dict — together with the evaluator's three-way
  ``{memory_hits, store_hits, misses}`` meter this is the service's
  billing / QoS signal.

The server half (:mod:`repro.engine.server`) hosts any existing
backend (``sim`` / ``vectorized`` / a worker pool) behind the same
handshake, and every host can share one :class:`~repro.engine.store.
EvalStore` — O_APPEND whole-record writes are concurrent-writer safe.
"""
from __future__ import annotations

import json
import socket
import struct
import threading
import time
import zlib
from collections import deque
from typing import Sequence

import numpy as np

from repro import obs
from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.engine.base import EvaluatorBase
from repro.engine.store import FINGERPRINT_SIZE

RPC_MAGIC = b"REPRO-EVALRPC-v1\n"
PROTOCOL_VERSION = 1

MSG_HELLO = 1       # client -> server: magic | u16 version | fingerprint
MSG_WELCOME = 2     # server -> client: utf-8 JSON server info
MSG_REFUSE = 3      # server -> client: utf-8 reason (handshake rejected)
MSG_EVAL = 4        # client -> server: u32 shard | u8 ndim | dims | int32
MSG_RESULT = 5      # server -> client: u32 shard | f64 times
MSG_ERROR = 6       # server -> client: u32 shard | utf-8 message

_LEN = struct.Struct("<I")
_U16 = struct.Struct("<H")
_U32 = struct.Struct("<I")
# A frame longer than this is garbage, not a batch (the biggest real
# shard is a few MB of int32 encodings).
MAX_FRAME = 1 << 30


class RpcError(RuntimeError):
    """Base class for evaluation-service failures."""


class RpcProtocolError(RpcError):
    """Malformed frame: bad length, CRC mismatch, unknown message."""


class RpcHandshakeError(RpcError):
    """The server refused the fingerprint handshake — the client and
    server disagree about graph / machine / objective. This is a
    configuration error, never retried."""


# -- framing ------------------------------------------------------------------

def _recv_exact(sock: socket.socket, n: int) -> bytes:
    """Read exactly ``n`` bytes or raise ``ConnectionError`` on EOF."""
    chunks = []
    got = 0
    while got < n:
        b = sock.recv(n - got)
        if not b:
            raise ConnectionError("peer closed mid-frame")
        chunks.append(b)
        got += len(b)
    return b"".join(chunks)


def send_frame(sock: socket.socket, payload: bytes) -> int:
    """Write one CRC-framed message; returns bytes put on the wire."""
    buf = _LEN.pack(len(payload)) + payload + _LEN.pack(zlib.crc32(payload))
    sock.sendall(buf)
    return len(buf)


def recv_frame(sock: socket.socket) -> tuple[int, bytes]:
    """Read one framed message -> ``(msg_type, body)``; CRC-checked."""
    (plen,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if not 1 <= plen <= MAX_FRAME:
        raise RpcProtocolError(f"implausible frame length {plen}")
    payload = _recv_exact(sock, plen)
    (crc,) = _LEN.unpack(_recv_exact(sock, _LEN.size))
    if zlib.crc32(payload) != crc:
        raise RpcProtocolError("frame CRC mismatch")
    return payload[0], payload[1:]


# -- message encode / decode --------------------------------------------------

def encode_hello(fingerprint: bytes) -> bytes:
    if len(fingerprint) != FINGERPRINT_SIZE:
        raise ValueError(f"fingerprint must be {FINGERPRINT_SIZE} bytes")
    return (bytes([MSG_HELLO]) + RPC_MAGIC
            + _U16.pack(PROTOCOL_VERSION) + fingerprint)


def decode_hello(body: bytes) -> bytes:
    """-> the client's fingerprint; raises on bad magic / version."""
    m = len(RPC_MAGIC)
    if body[:m] != RPC_MAGIC:
        raise RpcProtocolError(f"bad hello magic {body[:8]!r}")
    (version,) = _U16.unpack_from(body, m)
    if version != PROTOCOL_VERSION:
        raise RpcProtocolError(f"unsupported protocol version {version}")
    fp = body[m + _U16.size:]
    if len(fp) != FINGERPRINT_SIZE:
        raise RpcProtocolError(f"hello fingerprint is {len(fp)} bytes")
    return fp


def encode_welcome(info: dict) -> bytes:
    return bytes([MSG_WELCOME]) + json.dumps(info).encode()


def encode_refuse(reason: str) -> bytes:
    return bytes([MSG_REFUSE]) + reason.encode()


def encode_eval(shard_id: int, enc: np.ndarray) -> bytes:
    enc = np.ascontiguousarray(enc, dtype="<i4")
    dims = enc.shape
    return (bytes([MSG_EVAL]) + _U32.pack(shard_id) + bytes([len(dims)])
            + b"".join(_U32.pack(d) for d in dims) + enc.tobytes())


def decode_eval(body: bytes) -> tuple[int, np.ndarray]:
    (shard_id,) = _U32.unpack_from(body, 0)
    ndim = body[_U32.size]
    off = _U32.size + 1
    dims = []
    for _ in range(ndim):
        (d,) = _U32.unpack_from(body, off)
        dims.append(d)
        off += _U32.size
    n_vals = int(np.prod(dims, dtype=np.int64)) if dims else 0
    if len(body) - off != 4 * n_vals:
        raise RpcProtocolError(
            f"eval body carries {len(body) - off} data bytes for "
            f"shape {tuple(dims)}")
    enc = np.frombuffer(body, dtype="<i4", count=n_vals,
                        offset=off).reshape(dims)
    return shard_id, enc


def encode_result(shard_id: int, times: Sequence[float]) -> bytes:
    arr = np.ascontiguousarray(times, dtype="<f8")
    return bytes([MSG_RESULT]) + _U32.pack(shard_id) + arr.tobytes()


def decode_result(body: bytes) -> tuple[int, np.ndarray]:
    (shard_id,) = _U32.unpack_from(body, 0)
    if (len(body) - _U32.size) % 8:
        raise RpcProtocolError("result body is not whole float64s")
    times = np.frombuffer(body, dtype="<f8", offset=_U32.size)
    return shard_id, times


def encode_error(shard_id: int, message: str) -> bytes:
    return bytes([MSG_ERROR]) + _U32.pack(shard_id) + message.encode()


def decode_error(body: bytes) -> tuple[int, str]:
    (shard_id,) = _U32.unpack_from(body, 0)
    return shard_id, body[_U32.size:].decode(errors="replace")


def parse_host(spec) -> tuple[str, int]:
    """``"host:port"`` (or an ``(host, port)`` pair) -> ``(host, port)``."""
    if isinstance(spec, (tuple, list)) and len(spec) == 2:
        return str(spec[0]), int(spec[1])
    host, _, port = str(spec).rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"host spec {spec!r} is not 'host:port'")
    return host, int(port)


# -- client-side dispatch machinery -------------------------------------------

class _Host:
    """One evaluator host: address, persistent connection, QoS meters."""

    def __init__(self, spec):
        self.addr = parse_host(spec)
        self.name = f"{self.addr[0]}:{self.addr[1]}"
        self.sock: socket.socket | None = None
        self.alive = True
        self.failures = 0        # consecutive failures (reset on success)
        # per-host QoS / billing meters (mirrored into repro.obs):
        self.shards_done = 0
        self.hedged = 0
        self.retries = 0
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.send_seconds = 0.0
        self.recv_seconds = 0.0

    def stats(self) -> dict:
        return {
            "alive": self.alive,
            "shards_done": self.shards_done,
            "hedged": self.hedged,
            "retries": self.retries,
            "bytes_sent": self.bytes_sent,
            "bytes_recv": self.bytes_recv,
            "send_seconds": self.send_seconds,
            "recv_seconds": self.recv_seconds,
        }

    def drop(self) -> None:
        if self.sock is not None:
            try:
                self.sock.close()
            except OSError:
                pass
            self.sock = None


class _ShardTable:
    """Shared bookkeeping for one miss batch's shards.

    ``pending`` holds shard ids awaiting a first (or re-) dispatch;
    ``inflight`` maps a shard to the hosts currently computing it
    (more than one when hedged); ``results`` collects first-result-wins
    times; ``failed`` holds shards whose retry budget ran out (they go
    to the local fallback). All transitions happen under one lock so
    worker threads never double-count an attempt or lose a release.
    """

    def __init__(self, n_shards: int, max_attempts: int):
        self.n = n_shards
        self.max_attempts = max_attempts
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)
        self.pending: deque[int] = deque(range(n_shards))
        self.attempts = [0] * n_shards
        self.inflight: dict[int, set[str]] = {}
        self.results: dict[int, np.ndarray] = {}
        self.failed: set[int] = set()

    def settled(self) -> bool:
        with self.lock:
            return len(self.results) + len(self.failed) >= self.n

    def claim(self, host: str, want: int,
              hedge: bool) -> tuple[list[int], bool]:
        """Take up to ``want`` pending shards for ``host``; with an
        empty queue and ``hedge``, steal one straggler still in flight
        elsewhere. Returns ``(shard_ids, was_hedged)``."""
        with self.cond:
            sids: list[int] = []
            while self.pending and len(sids) < want:
                sid = self.pending.popleft()
                if sid in self.results or sid in self.failed:
                    continue
                self.attempts[sid] += 1
                self.inflight.setdefault(sid, set()).add(host)
                sids.append(sid)
            if sids:
                return sids, False
            if hedge:
                for sid, owners in self.inflight.items():
                    if (sid not in self.results and sid not in self.failed
                            and host not in owners
                            and self.attempts[sid] < self.max_attempts):
                        self.attempts[sid] += 1
                        owners.add(host)
                        return [sid], True
            return [], False

    def complete(self, host: str, sid: int, times: np.ndarray) -> None:
        with self.cond:
            owners = self.inflight.get(sid)
            if owners is not None:
                owners.discard(host)
                if not owners:
                    self.inflight.pop(sid, None)
            # First result wins; a hedged duplicate computed the same
            # deterministic base times, so dropping it changes nothing.
            if sid not in self.results:
                self.results[sid] = times
            self.cond.notify_all()

    def release(self, host: str, sids: Sequence[int]) -> None:
        """Give back shards a failed host never answered: re-queue each
        (unless another host still carries it, or the retry budget is
        spent — then it lands in ``failed`` for the local fallback)."""
        with self.cond:
            for sid in sids:
                owners = self.inflight.get(sid)
                if owners is not None:
                    owners.discard(host)
                if sid in self.results or sid in self.failed:
                    continue
                if owners:           # hedge partner still computing it
                    continue
                self.inflight.pop(sid, None)
                if self.attempts[sid] >= self.max_attempts:
                    self.failed.add(sid)
                else:
                    self.pending.append(sid)
            self.cond.notify_all()

    def wait_for_change(self, timeout: float) -> None:
        with self.cond:
            if len(self.results) + len(self.failed) < self.n:
                self.cond.wait(timeout)


class RpcEvaluator(EvaluatorBase):
    """The ``rpc`` backend: shard miss batches across evaluator hosts.

    ``hosts`` is a list of ``"host:port"`` strings (or ``(host, port)``
    pairs) running :mod:`repro.engine.server`. The client keeps one
    persistent connection per host, pipelines up to ``max_inflight``
    shards per connection, retries failed dispatches (``retries`` times
    per shard, exponential ``backoff`` per host, ``deadline`` seconds
    per in-flight read), hedges stragglers onto idle hosts, and — with
    ``local_fallback`` (the default) — evaluates any shard the fleet
    could not serve with the space's analytic model locally, so the
    search completes even with every host down. Results are assembled
    by shard index, preserving first-appearance order: an ``rpc``
    search is byte-identical to ``sim`` regardless of host count,
    failures, or hedging (locked by tests/test_engine_rpc.py).
    """

    backend = "rpc"

    def __init__(self, graph: "Graph", machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0,
                 hosts: Sequence = (), max_inflight: int = 4,
                 min_shard: int = 8, retries: int = 2,
                 deadline: float = 30.0, backoff: float = 0.05,
                 connect_timeout: float = 5.0, hedge: bool = True,
                 local_fallback: bool = True, **base_kwargs):
        super().__init__(graph, machine, noise_sigma, noise_seed,
                         **base_kwargs)
        self.hosts = [_Host(h) for h in hosts]
        seen: set[str] = set()
        for h in self.hosts:
            if h.name in seen:
                raise ValueError(f"duplicate host {h.name!r}")
            seen.add(h.name)
        self.max_inflight = max(1, max_inflight)
        self.min_shard = max(1, min_shard)
        self.retries = max(0, retries)
        self.deadline = deadline
        self.backoff = backoff
        self.connect_timeout = connect_timeout
        self.hedge = hedge
        self.local_fallback = local_fallback
        self.local_evals = 0     # shard rows served by the fallback
        self._handshake_error: RpcHandshakeError | None = None

    # -- connections --------------------------------------------------------
    def _ensure_conn(self, host: _Host) -> socket.socket:
        """The host's persistent connection, performing the fingerprint
        handshake on first use. ``OSError`` means the host is (for now)
        unreachable; :class:`RpcHandshakeError` means it is
        *misconfigured* and must not be retried."""
        if host.sock is not None:
            return host.sock
        sock = socket.create_connection(host.addr,
                                        timeout=self.connect_timeout)
        try:
            sock.settimeout(self.deadline)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            send_frame(sock, encode_hello(self.store_fingerprint))
            mtype, body = recv_frame(sock)
            if mtype == MSG_REFUSE:
                raise RpcHandshakeError(
                    f"server {host.name} refused: "
                    f"{body.decode(errors='replace')}")
            if mtype != MSG_WELCOME:
                raise RpcProtocolError(
                    f"expected WELCOME from {host.name}, got {mtype}")
        except BaseException:
            sock.close()
            raise
        host.sock = sock
        return sock

    # -- the dispatch loop ---------------------------------------------------
    def _host_worker(self, host: _Host, table: _ShardTable,
                     shards: list[np.ndarray]) -> None:
        while not table.settled():
            sids, hedged = table.claim(host.name, self.max_inflight,
                                       self.hedge)
            if not sids:
                table.wait_for_change(0.02)
                continue
            try:
                sock = self._ensure_conn(host)
            except RpcHandshakeError as e:
                self._handshake_error = e
                host.alive = False
                table.release(host.name, sids)
                return
            except (OSError, RpcError):
                host.failures += 1
                table.release(host.name, sids)
                if host.failures > self.retries:
                    host.alive = False
                    return
                with obs.span("rpc.retry", host=host.name, phase="connect",
                              failures=host.failures):
                    time.sleep(self.backoff * (2 ** (host.failures - 1)))
                continue
            if hedged:
                host.hedged += len(sids)
                obs.counter("rpc.hedges").add(len(sids))
            outstanding = list(sids)
            try:
                # Pipelined dispatch: every claimed shard goes on the
                # wire before the first response is read.
                for sid in sids:
                    payload = encode_eval(sid, shards[sid])
                    t0 = time.perf_counter()
                    with obs.span("rpc.send", host=host.name, shard=sid,
                                  n=len(shards[sid])):
                        nb = send_frame(sock, payload)
                    host.send_seconds += time.perf_counter() - t0
                    host.bytes_sent += nb
                    obs.counter(f"rpc.bytes_sent[{host.name}]").add(nb)
                while outstanding:
                    with table.lock:
                        live = [s for s in outstanding
                                if s not in table.results]
                    if not live:
                        # Everything left was hedge-completed elsewhere;
                        # abandon the connection rather than wait out a
                        # straggler (stale responses die with the
                        # socket — shard ids never cross batches).
                        host.drop()
                        outstanding = []
                        break
                    t0 = time.perf_counter()
                    with obs.span("rpc.recv", host=host.name) as sp:
                        mtype, body = recv_frame(sock)
                        sp.set(bytes=len(body))
                    host.recv_seconds += time.perf_counter() - t0
                    host.bytes_recv += len(body) + 2 * _LEN.size + 1
                    obs.counter(f"rpc.bytes_recv[{host.name}]").add(
                        len(body) + 2 * _LEN.size + 1)
                    if mtype == MSG_ERROR:
                        sid, msg = decode_error(body)
                        raise RpcError(
                            f"server {host.name} failed shard {sid}: "
                            f"{msg}")
                    if mtype != MSG_RESULT:
                        raise RpcProtocolError(
                            f"unexpected message type {mtype}")
                    sid, times = decode_result(body)
                    if sid in outstanding:
                        outstanding.remove(sid)
                        if len(times) != len(shards[sid]):
                            raise RpcProtocolError(
                                f"shard {sid}: {len(times)} times for "
                                f"{len(shards[sid])} rows")
                        host.shards_done += 1
                        table.complete(host.name, sid, times)
                    # else: a response for a shard this worker released
                    # in an earlier life of the connection — impossible
                    # (failures drop the socket), but harmless to skip.
            except (OSError, ConnectionError, RpcProtocolError,
                    RpcError):
                host.drop()
                host.failures += 1
                host.retries += 1
                obs.counter("rpc.retries").add(1)
                table.release(host.name, outstanding)
                if host.failures > self.retries:
                    host.alive = False
                    return
                with obs.span("rpc.retry", host=host.name, phase="io",
                              shards=len(outstanding),
                              failures=host.failures):
                    time.sleep(self.backoff * (2 ** (host.failures - 1)))
            else:
                host.failures = 0

    def _measure_local(self, schedules: Sequence[Schedule]) -> list[float]:
        return [self.space.analytic_cost(s, self.machine, self._durations)
                for s in schedules]

    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        if self._handshake_error is not None:
            raise self._handshake_error
        n = len(schedules)
        alive = [h for h in self.hosts if h.alive]
        if not alive or encoded is None:
            if self.hosts and not self.local_fallback:
                raise RpcError("every evaluation host is down and "
                               "local_fallback is disabled")
            self.local_evals += n
            return self._measure_local(schedules)

        # Contiguous shards in first-appearance order; enough of them
        # to keep every connection's pipeline full, but never smaller
        # than min_shard (framing would cost more than simulation).
        n_shards = max(1, min(n // self.min_shard,
                              len(alive) * self.max_inflight * 2))
        bounds = [n * k // n_shards for k in range(n_shards + 1)]
        shards = [encoded[bounds[k]:bounds[k + 1]]
                  for k in range(n_shards)]

        table = _ShardTable(n_shards, max_attempts=self.retries + 1)
        workers = [threading.Thread(target=self._host_worker,
                                    args=(h, table, shards), daemon=True)
                   for h in alive]
        for w in workers:
            w.start()
        for w in workers:
            w.join()
        if self._handshake_error is not None and len(table.results) < \
                n_shards:
            raise self._handshake_error

        missing = [sid for sid in range(n_shards)
                   if sid not in table.results]
        if missing:
            if not self.local_fallback:
                raise RpcError(
                    f"{len(missing)} shard(s) unserved after retries "
                    "and local_fallback is disabled")
            obs.event("rpc.local_fallback", shards=len(missing))
            for sid in missing:
                rows = self._measure_local(
                    schedules[bounds[sid]:bounds[sid + 1]])
                self.local_evals += len(rows)
                table.results[sid] = np.asarray(rows, dtype=np.float64)

        out: list[float] = []
        for sid in range(n_shards):
            out.extend(float(t) for t in table.results[sid])
        return out

    # -- QoS / lifecycle -----------------------------------------------------
    def rpc_stats(self) -> dict:
        """Per-host service meters: shards / bytes / walls / retries /
        hedges, plus the rows the local fallback absorbed. Pair with
        :meth:`stats` (hit/miss traffic) for the full billing signal."""
        return {
            "hosts": {h.name: h.stats() for h in self.hosts},
            "local_evals": self.local_evals,
        }

    def close(self) -> None:
        for h in self.hosts:
            h.drop()
        super().close()
