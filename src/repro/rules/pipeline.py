"""The missing end-to-end API: search result -> rendered design rules.

Before this module every consumer (benchmarks/paper.py, the smoke
pass, examples/schedule_search.py) hand-wired the same five steps:
label the times, featurize the schedules, run Algorithm 1, extract the
rulesets, render/annotate them. :func:`distill` is that pipeline as
one call::

    res = run_search(graph, strategy, ...)
    report = distill(res)                      # -> RuleReport
    print(report.render())
    report.write("experiments/rules.md")       # explicit output path

with the paper's evaluation hooks as keyword arguments: a pluggable
``labeler`` (anything mapping times -> :class:`Labeling`), a
``canonical`` report or ruleset list to annotate against (§V
over/under-constraint marks), and a ``full_space`` of (schedules,
times) for the Table-V class-range accuracy — optionally widened by
``range_widen`` for noise-dosed measurements.

``distill`` is deterministic and duck-typed: it needs only
``.schedules``, ``.times`` and a design space (``.space`` /
``.design_space()`` when present, else ``.graph``) from the search
result, so any corpus (an exhaustive sweep, an MCTS subset, replayed
logs, a kernel parameter sweep) can be distilled without importing
:mod:`repro.search`. Featurization goes through the space — pairwise
order/stream features for schedule spaces, threshold features
(``block_q >= 64``) for kernel parameter spaces — so the same
Algorithm-1 tree distills design rules for either.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
from typing import Callable, Sequence, TYPE_CHECKING

import numpy as np

from repro import obs
from repro.core.features import FeatureMatrix
from repro.rules.labels import Labeling, label_times
from repro.rules.rulesets import (RuleSet, annotate_vs_canonical,
                                  class_range_accuracy, extract_rulesets,
                                  render_rules_table, rules_by_class)
from repro.rules.trees import (DecisionTree, HistogramGrower,
                               TreeSearchTrace, algorithm1,
                               algorithm1_from_histograms)
from repro.space.base import DesignSpace, as_space

if TYPE_CHECKING:  # pragma: no cover - typing only, no runtime dep
    from repro.core.dag import Graph, Schedule
    from repro.search.pipeline import SearchResult


def _space_of(result) -> DesignSpace:
    """The corpus's design space, however the result spells it.

    ``SearchResult`` carries ``design_space()``; other duck-typed
    corpora may expose a ``.space`` attribute or just a ``.graph``
    (normalized through :func:`~repro.space.base.as_space`).
    """
    ds = getattr(result, "design_space", None)
    if callable(ds):
        return ds()
    sp = getattr(result, "space", None)
    if isinstance(sp, DesignSpace):
        return sp
    return as_space(result.graph)


@dataclasses.dataclass
class RuleReport:
    """Everything the labels -> tree -> rules pipeline produced."""

    graph: "Graph | None"          # None for graph-less (parameter) spaces
    feature_matrix: FeatureMatrix
    labeling: Labeling
    tree: DecisionTree
    trace: TreeSearchTrace
    rulesets: list[RuleSet]
    n_schedules: int
    training_error: float
    class_range_acc: float | None = None   # Table V, when full_space given
    annotated: bool = False                # §V marks vs a canonical report
    stage_seconds: dict = dataclasses.field(default_factory=dict)
    """Wall seconds per pipeline stage (label/featurize/tree/rules/
    accuracy) — so benchmark rows can keep attributing time to the
    stage they are about."""

    def grouped(self) -> dict[int, list[RuleSet]]:
        return rules_by_class(self.rulesets)

    def summary(self) -> dict:
        """Flat stats dict (benchmark rows, smoke assertions)."""
        out = {
            "n_schedules": self.n_schedules,
            "n_classes": self.labeling.n_classes,
            "n_features": len(self.feature_matrix.features),
            "n_rulesets": len(self.rulesets),
            "n_leaves": self.tree.n_leaves(),
            "tree_depth": self.tree.depth(),
            "training_error": self.training_error,
            "algorithm1_trials": len(self.trace.max_leaf_nodes),
        }
        if self.annotated:
            out["n_overconstrained"] = sum(
                bool(rs.extraneous) for rs in self.rulesets)
            out["n_underconstrained"] = sum(
                rs.insufficient for rs in self.rulesets)
        if self.class_range_acc is not None:
            out["class_range_acc"] = self.class_range_acc
        return out

    def render(self, top_k: int = 3) -> str:
        """Markdown report: corpus stats, class ranges, rule tables."""
        s = self.summary()
        lines = [
            "# design-rule report",
            "",
            f"- schedules: {s['n_schedules']} "
            f"({s['n_features']} features, "
            f"{s['n_classes']} performance classes)",
            f"- tree: {s['n_leaves']} leaves, depth {s['tree_depth']}, "
            f"training error {s['training_error']:.4f} "
            f"({s['algorithm1_trials']} Algorithm-1 trials)",
        ]
        if self.class_range_acc is not None:
            lines.append(f"- class-range accuracy (full space): "
                         f"{self.class_range_acc:.3f}")
        if self.annotated:
            lines.append(
                f"- vs canonical rules: "
                f"{s['n_overconstrained']} overconstrained, "
                f"{s['n_underconstrained']} underconstrained rulesets")
        lines.append("")
        for c, (lo, hi) in enumerate(self.labeling.class_ranges()):
            lines.append(f"- class {c + 1} time range: "
                         f"[{lo * 1e6:.2f}, {hi * 1e6:.2f}] us")
        lines.append("")
        lines.append(render_rules_table(self.grouped(), top_k=top_k))
        return "\n".join(lines) + "\n"

    def write(self, path, top_k: int = 3) -> pathlib.Path:
        """Render to an explicit path (no hidden side-effect writes)."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.render(top_k=top_k))
        return path


def distill(result: "SearchResult",
            labeler: Callable[[np.ndarray], Labeling] = label_times,
            canonical: "RuleReport | list[RuleSet] | None" = None,
            full_space: "tuple[Sequence[Schedule], np.ndarray] | None"
            = None,
            range_widen: float = 0.0,
            initial_leaves: int | None = None,
            features: FeatureMatrix | None = None,
            histograms=None) -> RuleReport:
    """Label -> featurize -> Algorithm 1 -> rulesets, as one call.

    ``labeler`` maps the observed times to a :class:`Labeling`
    (defaults to the paper's §IV-A convolution labeling; pass e.g.
    ``functools.partial(label_times, prominence_percentile=95)`` or any
    custom labeler). ``canonical`` annotates the extracted rulesets
    against a reference report's rulesets (§V). ``full_space`` is a
    (schedules, times) pair covering the whole design space; when
    given, the Table-V class-range accuracy is computed by classifying
    the full space in this report's feature basis, with each class's
    (lo, hi) time range widened to (lo*(1-w), hi*(1+w)) for
    ``range_widen=w`` (noise-dosed measurements).

    ``features`` is the streaming-corpus hook: a pre-built
    :class:`FeatureMatrix` for ``result.schedules`` (row i =
    schedule i), e.g. the incrementally folded matrix of a
    :class:`repro.driver.DatasetSink`. When given, the featurize stage
    is skipped entirely — the sync-expansion work was already paid
    when the schedules streamed in. Only that stage is saved: the
    label, tree, and rules stages still scale with the whole corpus.

    ``histograms`` is the out-of-core hook: a streamed-corpus handle
    (a :class:`repro.driver.HistogramSink`, or anything exposing
    ``n_rows`` / ``feature_list()`` / ``value_grids()`` /
    ``blocks()``) whose feature matrix is *never* materialized. The
    tree stage then runs Algorithm 1 through
    :class:`repro.rules.trees.HistogramGrower` — one blockwise pass
    per tree level, O(features x bins) extra memory — and produces the
    same tree, rulesets, and training error bit for bit as the dense
    path (locked by test); the report's ``feature_matrix`` carries the
    pruned feature list over a 0-row ``X``. Mutually exclusive with
    ``features`` and ``full_space``.
    """
    if histograms is not None and features is not None:
        raise ValueError(
            "pass features= (dense streamed matrix) or histograms= "
            "(out-of-core), not both")
    if histograms is not None and full_space is not None:
        raise ValueError(
            "full_space= accuracy needs the in-memory feature path; "
            "it cannot be combined with histograms=")
    stage_seconds: dict[str, float] = {}
    scheds = getattr(result, "schedules", None)
    n_rows = len(scheds) if scheds is not None else len(result.times)
    distill_span = obs.span("rules.distill", n_schedules=n_rows)
    distill_span.__enter__()

    def staged(name, fn):
        # Each stage is both a rules.<stage> telemetry span and a
        # stage_seconds entry (the pre-obs consumers — benchmark rows,
        # the streaming-distill test — read the dict).
        with obs.span(f"rules.{name}"):
            t0 = time.perf_counter()
            out = fn()
            stage_seconds[name] = time.perf_counter() - t0
        return out

    try:
        return _distill_staged(result, labeler, canonical, full_space,
                               range_widen, initial_leaves, features,
                               histograms, staged, stage_seconds)
    finally:
        distill_span.__exit__(None, None, None)


def _distill_staged(result, labeler, canonical, full_space, range_widen,
                    initial_leaves, features, histograms, staged,
                    stage_seconds):
    times = np.asarray(result.times, dtype=np.float64)
    labeling = staged("label", lambda: labeler(times))
    grower = None
    if histograms is not None:
        if histograms.n_rows != len(times):
            raise ValueError(
                f"histogram corpus has {histograms.n_rows} rows but "
                f"the result has {len(times)} times — the streamed "
                "corpus must cover exactly the result's observations")
        # The pruned feature list is the histogram path's "featurize":
        # discovery is a blockwise min/max fold, never a matrix.
        feats = staged("featurize", histograms.feature_list)
        fm = FeatureMatrix(feats,
                           np.zeros((0, len(feats)), dtype=np.int8))
    elif features is not None:
        if features.X.shape[0] != len(result.schedules):
            raise ValueError(
                f"features has {features.X.shape[0]} rows but the "
                f"corpus has {len(result.schedules)} schedules — the "
                "matrix must cover exactly the result's schedule list")
        fm = features
    else:
        sp = _space_of(result)
        fm = staged("featurize",
                    lambda: sp.featurize(list(result.schedules)))
    trace = TreeSearchTrace([], [], [])
    if histograms is not None:
        def fit_tree():
            nonlocal grower
            grower = HistogramGrower(histograms.blocks, labeling.labels,
                                     values=histograms.value_grids())
            return algorithm1_from_histograms(
                histograms.blocks, labeling.labels, trace=trace,
                initial_leaves=initial_leaves, grower=grower)
        tree = staged("tree", fit_tree)
    else:
        tree = staged("tree",
                      lambda: algorithm1(fm.X, labeling.labels,
                                         trace=trace,
                                         initial_leaves=initial_leaves))
    rulesets = staged("rules",
                      lambda: extract_rulesets(tree, fm.features))

    annotated = canonical is not None
    if annotated:
        canon_sets = canonical.rulesets \
            if isinstance(canonical, RuleReport) else canonical
        annotate_vs_canonical(rulesets, canon_sets)

    acc = None
    if full_space is not None:
        space_schedules, space_times = full_space

        def accuracy():
            ranges = [(lo * (1.0 - range_widen),
                       hi * (1.0 + range_widen))
                      for lo, hi in labeling.class_ranges()]
            Xf = _space_of(result).apply_features(
                list(space_schedules), fm.features)
            return class_range_accuracy(tree, Xf, space_times, ranges)

        acc = staged("accuracy", accuracy)

    if histograms is not None:
        n_schedules = histograms.n_rows
        training_error = grower.training_error(tree)
    else:
        n_schedules = len(result.schedules)
        training_error = tree.training_error(fm.X, labeling.labels)
    return RuleReport(
        graph=getattr(result, "graph", None),
        feature_matrix=fm, labeling=labeling,
        tree=tree, trace=trace, rulesets=rulesets,
        n_schedules=n_schedules,
        training_error=training_error,
        class_range_acc=acc, annotated=annotated,
        stage_seconds=stage_seconds)
