"""Compressed data-parallel gradient synchronization.

Int8 per-tensor quantization with error feedback (1-bit-Adam-style EF):
each shard quantizes (gradient + carried residual), the quantized values
are mean-reduced over the data axis, and the local quantization residual
is carried into the next step. Halves-to-quarters the DP sync bytes at
<1% relative error on the synced mean (tests/test_distributed.py).

All functions operate on pytrees and are shard_map/pmap-compatible
(reductions use ``jax.lax.psum`` over a named axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def psum_mean(tree, axis_name: str):
    """Exact mean-reduction of a gradient pytree over ``axis_name``."""
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, tree)


def init_ef(params):
    """Zero-initialized error-feedback state, one residual per leaf."""
    return jax.tree.map(jnp.zeros_like, params)


def _quantize(v: jax.Array) -> jax.Array:
    """Symmetric per-tensor int8 quantize-dequantize."""
    scale = jnp.max(jnp.abs(v)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(v / scale), -127.0, 127.0)
    return (q * scale).astype(v.dtype)


def compressed_psum_mean(grads, ef, axis_name: str):
    """Mean-reduce ``grads`` over ``axis_name`` with int8 compression.

    Returns ``(synced, new_ef)``: the dequantized mean and the updated
    error-feedback residuals (what quantization dropped locally this
    step, re-injected into the next call's input).
    """
    compensated = jax.tree.map(lambda g, e: g + e, grads, ef)
    deq = jax.tree.map(_quantize, compensated)
    new_ef = jax.tree.map(lambda v, d: v - d, compensated, deq)
    synced = psum_mean(deq, axis_name)
    return synced, new_ef
