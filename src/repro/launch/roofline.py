"""Assemble the roofline table from dry-run JSON records.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
Writes experiments/roofline_table.md and prints it.
"""
from __future__ import annotations

import argparse
import json
import pathlib

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments"


def tpu_estimate(rec: dict) -> float:
    m = rec["memory_analysis"]
    base = m["argument_size_in_bytes"] + m["output_size_in_bytes"] - \
        m["alias_size_in_bytes"]
    full = base + m["temp_size_in_bytes"]
    return max(base, full - rec.get("cpu_upcast_bytes", 0))


def load(mesh: str, variants: bool = False) -> list[dict]:
    """Baseline cells (arch__shape.json); hillclimb variants carry an
    extra __tag suffix and are listed separately."""
    recs = []
    for f in sorted((OUT_DIR / "dryrun" / mesh).glob("*.json")):
        is_variant = f.stem.count("__") > 1
        if is_variant != variants:
            continue
        rec = json.loads(f.read_text())
        if variants:
            rec["tag"] = f.stem.split("__", 2)[2]
        recs.append(rec)
    return recs


def render(recs: list[dict], mesh: str) -> str:
    rows = [
        f"### Roofline — {mesh} "
        f"({recs[0]['chips'] if recs else '?'} chips)",
        "",
        "| arch | shape | kind | GB/dev (tpu-est) | compute_s | "
        "memory_s | collective_s | dominant | MODEL/HLO | roofline "
        "frac | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        rl = r["roofline"]
        note = {
            "compute": "more useful flops/chip (bigger per-chip tile, "
                       "less dispatch/remat overhead)",
            "memory": "cut HBM traffic (fuse, bf16 state, smaller "
                      "temps)",
            "collective": "overlap or shrink collectives (schedule "
                          "search, bf16 sync, fewer reshards)",
        }[rl["dominant"]]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['per_device_bytes'] / 1e9:.2f} "
            f"({tpu_estimate(r) / 1e9:.2f}) "
            f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
            f"| {rl['collective_s']:.3g} | {rl['dominant']} "
            f"| {rl['model_flops_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {note} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args()
    recs = load(args.mesh)
    table = render(recs, args.mesh)
    var = load(args.mesh, variants=True)
    if var:
        table += ("\n\n### Hillclimb variants (§Perf)\n\n"
                  "| arch | shape | variant | compute_s | "
                  "collective_s (tpu-adj) | roofline frac |\n"
                  "|---|---|---|---|---|---|\n")
        for r in var:
            rl = r["roofline"]
            table += (f"| {r['arch']} | {r['shape']} | {r['tag']} "
                      f"| {rl['compute_s']:.3g} "
                      f"| {rl.get('collective_s_tpu') or rl['collective_s']:.3g} "
                      f"| {rl['roofline_fraction']:.3f} |\n")
    out = OUT_DIR / f"roofline_table_{args.mesh}.md"
    out.write_text(table + "\n")
    print(table)


if __name__ == "__main__":
    main()
