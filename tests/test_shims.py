"""Compatibility shims: deprecation warnings + exact object identity.

The extraction PRs (engine, rules, driver) left five shim modules
behind. Before the next extraction can delete them, two things must
hold for each: importing the shim module warns ``DeprecationWarning``
(so downstream users migrate), and every public name on the shim *is*
(``is``, not ``==``) the object at its new home (so deleting the shim
after a sed of the import paths cannot change behavior).
"""
import importlib
import sys
import warnings

import pytest

# shim module -> (new home, public names re-exported by the shim)
SHIMS = {
    "repro.core.labels": ("repro.rules.labels", [
        "Labeling", "find_peaks", "label_times", "peak_prominences",
        "peak_prominences_loop", "step_convolve"]),
    "repro.core.dtree": ("repro.rules.trees", [
        "DecisionTree", "Presort", "RegressionTree", "TreeNode",
        "TreeSearchTrace", "algorithm1"]),
    "repro.core.rules": ("repro.rules.rulesets", [
        "Rule", "RuleSet", "annotate_vs_canonical",
        "class_range_accuracy", "class_range_accuracy_loop",
        "extract_rulesets", "render_rules_table", "rules_by_class"]),
    "repro.search.evaluator": ("repro.engine.base", [
        "BatchEvaluator", "EvaluatorBase", "canonical_key"]),
    # The legacy wrapper module: its lazily re-exported names must
    # resolve to the real repro.search.mcts objects (MCTS/MCTSResult
    # themselves live in the shim and go away with it).
    "repro.core.mcts": ("repro.search.mcts", ["EXPLORATION_C", "Node"]),
}


def _fresh_import(name):
    sys.modules.pop(name, None)
    return importlib.import_module(name)


@pytest.mark.parametrize("shim", sorted(SHIMS))
def test_shim_import_warns_deprecation(shim):
    with pytest.warns(DeprecationWarning, match=shim):
        _fresh_import(shim)


@pytest.mark.parametrize("shim", sorted(SHIMS))
def test_shim_names_resolve_to_new_module_objects(shim):
    new_home, names = SHIMS[shim]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim_mod = _fresh_import(shim)
        new_mod = importlib.import_module(new_home)
    for name in names:
        assert getattr(shim_mod, name) is getattr(new_mod, name), \
            f"{shim}.{name} is not {new_home}.{name}"


def test_shim_all_is_covered():
    """Every name a re-export shim advertises in __all__ is checked
    above — nothing can drift in unnoticed (repro.core.mcts excluded:
    its __all__ also carries the legacy wrapper itself)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        for shim, (_, names) in SHIMS.items():
            if shim == "repro.core.mcts":
                continue
            mod = _fresh_import(shim)
            assert sorted(mod.__all__) == sorted(names), shim


def test_package_import_does_not_warn():
    """``import repro.core`` / ``import repro.search`` must stay
    warning-free: the packages re-export from the new homes, only the
    old module paths are deprecated."""
    for name in ("repro.core", "repro.search", "repro.rules",
                 "repro.engine", "repro.driver"):
        sys.modules.pop(name, None)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        importlib.import_module("repro.core")
        importlib.import_module("repro.search")
    # the lazy legacy wrapper still resolves (and warns) on access
    for name in ("repro.core", "repro.core.mcts"):
        sys.modules.pop(name, None)
    core = importlib.import_module("repro.core")
    with pytest.warns(DeprecationWarning, match="repro.core.mcts"):
        assert core.MCTS is sys.modules["repro.core.mcts"].MCTS