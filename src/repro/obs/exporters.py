"""Telemetry exporters: where the event stream lands.

Three formats, one contract — ``export(event: dict)`` per event plus a
``close()`` flush. Events are the Chrome trace-event shape the
registry emits (:mod:`repro.obs.telemetry`): ``ph`` is ``"B"``/``"E"``
(span begin/end), ``"C"`` (counter/gauge sample), or ``"i"`` (instant);
``ts`` is microseconds on the process-monotonic clock.

:class:`PerfettoExporter`
    Chrome trace-event JSON (``{"traceEvents": [...]}``) loadable
    straight into https://ui.perfetto.dev — spans nest per thread
    track, counters render as value tracks. Buffered in memory, written
    atomically at :meth:`close`.
:class:`JsonlExporter`
    One JSON object per line, streamed as events happen — the
    grep/pandas-friendly event log, and the crash-tolerant one (a
    killed run keeps every line already flushed).
:class:`MemoryExporter`
    In-process event list, for tests and programmatic consumers.
"""
from __future__ import annotations

import json
import os
from typing import Protocol, runtime_checkable


@runtime_checkable
class Exporter(Protocol):
    """Consumer of telemetry events."""

    def export(self, event: dict) -> None: ...

    def close(self) -> None: ...


class MemoryExporter:
    """Keep every event in a list (tests, programmatic readers)."""

    def __init__(self):
        self.events: list[dict] = []

    def export(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlExporter:
    """Stream events as JSON lines to ``path`` (appending never; a new
    run truncates — one file is one run's event log)."""

    def __init__(self, path: "str | os.PathLike"):
        self.path = os.fspath(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "w")

    def export(self, event: dict) -> None:
        if self._f is not None:
            self._f.write(json.dumps(event) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class PerfettoExporter:
    """Chrome trace-event / Perfetto JSON.

    Events buffer in memory and are written as one
    ``{"traceEvents": [...], "displayTimeUnit": "ms"}`` document at
    :meth:`close` (atomic rename, so a crashed run leaves no
    half-written trace — use :class:`JsonlExporter` alongside when
    crash-time events matter more than loadability).
    """

    def __init__(self, path: "str | os.PathLike"):
        self.path = os.fspath(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._events: "list[dict] | None" = []

    def export(self, event: dict) -> None:
        if self._events is not None:
            self._events.append(event)

    def close(self) -> None:
        if self._events is None:
            return
        doc = {"traceEvents": self._events, "displayTimeUnit": "ms"}
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, self.path)
        self._events = None


def load_trace(path: "str | os.PathLike") -> list[dict]:
    """Read a trace back as an event list — both exporter formats.

    Accepts the Perfetto document shape (``{"traceEvents": [...]}``), a
    bare JSON array, or JSONL. The schema-sanity test and the CI gate
    read traces through this, so the check and the writer can never
    drift apart silently.
    """
    with open(os.fspath(path)) as f:
        text = f.read()
    stripped = text.lstrip()
    if stripped.startswith("{") or stripped.startswith("["):
        doc = json.loads(text)
        return doc["traceEvents"] if isinstance(doc, dict) else doc
    return [json.loads(line) for line in text.splitlines() if line]
