"""The paper's contribution: MCTS design-space search + decision-tree
design rules for asynchronous compute/communication programs.

Pipeline (paper Fig. 2):

    Graph (dag.py)  ->  MCTS (mcts.py) / exhaustive (enumerate.py)
        -> measured times (costmodel.py analytic | executor.py wall-clock)
        -> class labels (repro.rules.labels, shim: labels.py)
        -> feature vectors (features.py)
        -> decision tree (repro.rules.trees, shim: dtree.py)
        -> design rules (repro.rules.rulesets, shim: rules.py)

The labels -> tree -> rules stack lives in :mod:`repro.rules` (one
call: :func:`repro.rules.distill`); this package re-exports it through
shims for compatibility. The shim *modules* (labels.py, dtree.py,
rules.py, mcts.py) emit :class:`DeprecationWarning` on import, so this
``__init__`` re-exports the moved names straight from their new homes
— ``import repro.core`` stays warning-free; only touching the old
module paths (or the legacy ``MCTS`` wrapper, loaded lazily below)
warns.
"""
from repro.core.dag import (BoundOp, CommRole, Graph, Op, OpKind, Schedule,
                            canonicalize_streams, spmv_dag,
                            validate_schedule)
from repro.core.sync import ExpandedItem, expand, expanded_names
from repro.core.enumerate import count_schedules, enumerate_schedules
from repro.core.costmodel import Machine, SimResult, makespan, simulate
from repro.rules.labels import Labeling, label_times
from repro.core.features import (DegenerateFeatureSpaceError, Feature,
                                 FeatureBasis, FeatureMatrix,
                                 apply_features, featurize, featurize_like)
from repro.rules.trees import DecisionTree, TreeSearchTrace, algorithm1
from repro.rules.rulesets import (Rule, RuleSet, annotate_vs_canonical,
                                  class_range_accuracy, extract_rulesets,
                                  render_rules_table, rules_by_class)
from repro.core.executor import build_runner, jit_runner, op_impl
from repro.core.stepdag import StepCosts, train_step_dag, with_comm_durations


def __getattr__(name: str):
    # The legacy MCTS wrapper lives in the deprecated repro.core.mcts
    # module; loading it eagerly would make every ``import repro.core``
    # warn. Resolved on first attribute access instead.
    if name in ("MCTS", "MCTSResult"):
        import repro.core.mcts as _mcts
        return getattr(_mcts, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BoundOp", "CommRole", "Graph", "Op", "OpKind", "Schedule",
    "canonicalize_streams", "spmv_dag", "validate_schedule",
    "ExpandedItem", "expand", "expanded_names",
    "count_schedules", "enumerate_schedules",
    "Machine", "SimResult", "makespan", "simulate",
    "MCTS", "MCTSResult",
    "Labeling", "label_times",
    "DegenerateFeatureSpaceError", "Feature", "FeatureBasis",
    "FeatureMatrix", "apply_features", "featurize", "featurize_like",
    "DecisionTree", "TreeSearchTrace", "algorithm1",
    "Rule", "RuleSet", "annotate_vs_canonical", "class_range_accuracy",
    "extract_rulesets", "render_rules_table", "rules_by_class",
    "build_runner", "jit_runner", "op_impl",
    "StepCosts", "train_step_dag", "with_comm_durations",
]
