"""Monte-Carlo tree search over the implementation space (paper §III-C).

Tree nodes are schedule prefixes P_k. The four phases:

  selection      recursively maximize (exploration + exploitation):
                   exploration  = c * sqrt(ln N / n),  c = sqrt(2)
                                  (-inf once the child subtree is fully
                                   explored)
                   exploitation = (t_max^c - t_min^c) / (t_max^p - t_min^p)
                                  when both child and parent have >= 2
                                  rollouts, else 1
                 i.e. favor children whose subtree *covers* more of the
                 parent's observed time range — regions where decisions
                 matter — not children that are merely fast. Recursion
                 stops at any node with a zero-rollout child.
  expansion      materialize one zero-rollout child of the selected node
                 (children are the DAG-eligible next ops; GPU ops are bound
                 to a stream, with stream-bijection duplicates pruned via
                 canonical first-use labeling).
  rollout        complete the prefix uniformly at random, benchmark the
                 resulting program, and add the rollout path to the tree.
  backprop       update t_min/t_max on every node along the path.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable

from repro.core.dag import BoundOp, Graph, OpKind, Schedule

EXPLORATION_C = math.sqrt(2.0)


class Node:
    __slots__ = ("item", "parent", "children", "n_rollouts",
                 "t_min", "t_max", "fully_explored", "_expandable")

    def __init__(self, item: BoundOp | None, parent: "Node | None"):
        self.item = item
        self.parent = parent
        self.children: dict[tuple, Node] = {}
        self.n_rollouts = 0
        self.t_min = math.inf
        self.t_max = -math.inf
        self.fully_explored = False
        self._expandable: list[BoundOp] | None = None  # lazily computed

    def prefix(self) -> list[BoundOp]:
        out: list[BoundOp] = []
        node = self
        while node.parent is not None:
            out.append(node.item)
            node = node.parent
        out.reverse()
        return out


def _child_options(graph: Graph, prefix: list[BoundOp],
                   n_streams: int) -> list[BoundOp]:
    """Eligible next items from a prefix, stream-bijection pruned."""
    scheduled = {b.name for b in prefix}
    used = sorted({b.stream for b in prefix if b.stream is not None})
    options: list[BoundOp] = []
    for name in graph.eligible(scheduled):
        if graph.ops[name].kind is OpKind.GPU:
            for s in used:
                options.append(BoundOp(name, s))
            if len(used) < n_streams:
                options.append(BoundOp(name, len(used)))
        else:
            options.append(BoundOp(name))
    return options


@dataclasses.dataclass
class MCTSResult:
    schedules: list[Schedule]
    times: list[float]
    root: Node


class MCTS:
    """Paper-faithful MCTS. ``objective`` maps a Schedule to a time."""

    def __init__(self, graph: Graph, n_streams: int,
                 objective: Callable[[Schedule], float],
                 seed: int = 0):
        self.graph = graph
        self.n_streams = n_streams
        self.objective = objective
        self.rng = random.Random(seed)
        self.root = Node(None, None)
        self.schedules: list[Schedule] = []
        self.times: list[float] = []
        self._seen: set[tuple] = set()

    # -- phase 1: selection ------------------------------------------------
    def _value(self, parent: Node, child: Node) -> float:
        if child.fully_explored:
            explore = -math.inf
        elif child.n_rollouts == 0:
            explore = math.inf
        else:
            explore = EXPLORATION_C * math.sqrt(
                math.log(parent.n_rollouts) / child.n_rollouts)
        if child.n_rollouts >= 2 and parent.n_rollouts >= 2 and \
                parent.t_max > parent.t_min:
            exploit = (child.t_max - child.t_min) / \
                (parent.t_max - parent.t_min)
        else:
            exploit = 1.0
        return explore + exploit

    def _select(self) -> Node:
        node = self.root
        while True:
            opts = self._expandable(node)
            # Terminate at any node that still has an unmaterialized or
            # zero-rollout child.
            if any(key not in node.children or
                   node.children[key].n_rollouts == 0
                   for key in ((o.name, o.stream) for o in opts)):
                return node
            if not node.children:
                return node  # complete leaf (shouldn't be selected; guard)
            node = max(node.children.values(),
                       key=lambda ch: self._value(node, ch))

    def _expandable(self, node: Node) -> list[BoundOp]:
        if node._expandable is None:
            node._expandable = _child_options(
                self.graph, node.prefix(), self.n_streams)
        return node._expandable

    # -- phase 2: expansion --------------------------------------------------
    def _expand(self, node: Node) -> Node:
        opts = self._expandable(node)
        fresh = [o for o in opts
                 if (o.name, o.stream) not in node.children or
                 node.children[(o.name, o.stream)].n_rollouts == 0]
        if not fresh:  # fully rolled-out interior node: descend randomly
            return node
        choice = self.rng.choice(fresh)
        key = (choice.name, choice.stream)
        if key not in node.children:
            node.children[key] = Node(choice, node)
        return node.children[key]

    # -- phase 3: rollout ----------------------------------------------------
    def _rollout(self, node: Node) -> tuple[Node, Schedule]:
        """Complete the prefix randomly, materializing path nodes."""
        cur = node
        while True:
            opts = self._expandable(cur)
            if not opts:
                break
            choice = self.rng.choice(opts)
            key = (choice.name, choice.stream)
            if key not in cur.children:
                cur.children[key] = Node(choice, cur)
            cur = cur.children[key]
        return cur, Schedule(tuple(cur.prefix()))

    # -- phase 4: backpropagation ---------------------------------------------
    def _backprop(self, leaf: Node, t: float) -> None:
        node: Node | None = leaf
        while node is not None:
            node.n_rollouts += 1
            node.t_min = min(node.t_min, t)
            node.t_max = max(node.t_max, t)
            node = node.parent
        # Mark fully-explored subtrees bottom-up.
        node = leaf
        node.fully_explored = True  # complete program leaf
        node = node.parent
        while node is not None:
            opts = self._expandable(node)
            node.fully_explored = (
                len(node.children) == len(opts) and
                all(c.fully_explored for c in node.children.values()))
            if not node.fully_explored:
                break
            node = node.parent

    # -- driver ----------------------------------------------------------------
    def run(self, iterations: int) -> MCTSResult:
        for _ in range(iterations):
            if self.root.fully_explored:
                break
            node = self._select()
            node = self._expand(node)
            leaf, schedule = self._rollout(node)
            t = self.objective(schedule)
            key = schedule.key()
            if key not in self._seen:
                self._seen.add(key)
                self.schedules.append(schedule)
                self.times.append(t)
            self._backprop(leaf, t)
        return MCTSResult(self.schedules, self.times, self.root)
