"""The acquisition-aware search driver (repro.driver).

Locks the three contracts the driver refactor introduced:

* ``run_search`` is a *bit-compatible* wrapper over
  :class:`~repro.driver.SearchDriver`: byte-identical
  (features, labels, times) and identical budget/cache accounting vs
  an embedded copy of the pre-refactor loop, for every analytic
  backend (and structurally for wallclock);
* acquisition screening is deterministic: same seed + same corpus
  choose the same batch on every analytic backend (the driver-round
  extension of the evaluator noise-permutation test);
* sinks stream the same dataset the batch pipeline materializes.
"""
import random

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro.core.dag import halo3d_dag
from repro.driver import (DatasetSink, SearchDriver, StreamingHistogram,
                          TraceSink, make_acquisition, make_sink,
                          predict_with_std)
from repro.rules.trees import forest_leaf_values
from repro.search.pipeline import SearchResult
from repro.search.strategy import PoolSearchStrategy


def _reference_run_search(graph, strategy, budget=2000, batch_size=1,
                          evaluator=None, backend=None,
                          sim_budget=None, stall_limit=1000):
    """Verbatim copy of the pre-driver ``run_search`` loop (PR 2-4).

    The oracle the thin wrapper is locked against: any divergence in
    proposal sequence, evaluator traffic, dedup, or accounting between
    this and ``S.run_search`` is a regression.
    """
    owns = evaluator is None
    ev = evaluator if evaluator is not None else \
        E.make_evaluator(graph, backend or "sim")
    hits0, misses0 = ev.cache_hits, ev.cache_misses
    schedules, times = [], []
    seen = set()
    n_proposed = 0
    stalled = 0
    try:
        while ((budget is None or n_proposed < budget) and
               (sim_budget is None
                or ev.cache_misses - misses0 < sim_budget)):
            ask = batch_size if budget is None else \
                min(batch_size, budget - n_proposed)
            batch = strategy.propose(ask)[:ask]
            if not batch:
                break
            n_proposed += len(batch)
            batch_misses0 = ev.cache_misses
            for schedule, (key, t) in zip(batch,
                                          ev.evaluate_keyed(batch)):
                strategy.observe(schedule, t)
                if key not in seen:
                    seen.add(key)
                    schedules.append(schedule)
                    times.append(t)
            if sim_budget is not None or budget is None:
                if ev.cache_misses == batch_misses0:
                    stalled += len(batch)
                    if stalled >= stall_limit:
                        break
                else:
                    stalled = 0
    finally:
        if owns:
            ev.close()
    return SearchResult(graph=graph, schedules=schedules, times=times,
                        n_proposed=n_proposed,
                        cache_hits=ev.cache_hits - hits0,
                        cache_misses=ev.cache_misses - misses0)


def _assert_results_identical(a, b):
    assert a.n_proposed == b.n_proposed
    assert a.cache_hits == b.cache_hits
    assert a.cache_misses == b.cache_misses
    assert a.times == b.times                     # exact float equality
    assert [s.items for s in a.schedules] == [s.items for s in b.schedules]
    fa, la, ta = a.dataset()
    fb, lb, tb = b.dataset()
    assert fa.features == fb.features
    assert fa.X.tobytes() == fb.X.tobytes()       # byte-identical
    np.testing.assert_array_equal(la.labels, lb.labels)
    assert ta.tobytes() == tb.tobytes()


# -- the thin wrapper is bit-compatible with the pre-refactor loop ----------

@pytest.mark.parametrize("backend", ["sim", "vectorized", "pool"])
def test_run_search_byte_identical_to_reference_loop(backend):
    g = C.spmv_dag()
    kwargs = {"n_workers": 2} if backend == "pool" else {}
    for make_strategy, run_kw in [
        (lambda: S.MCTSSearch(g, 2, seed=3),
         dict(budget=90, batch_size=4)),
        (lambda: S.RandomSearch(g, 2, seed=1),
         dict(budget=None, sim_budget=25, batch_size=1)),
        (lambda: S.SurrogateGuided(g, 2, seed=0, warmup=16),
         dict(budget=96, batch_size=8)),
    ]:
        ref = _reference_run_search(
            g, make_strategy(),
            evaluator=E.make_evaluator(g, backend, **kwargs), **run_kw)
        new = S.run_search(g, make_strategy(), backend=backend,
                           backend_kwargs=kwargs or None, **run_kw)
        _assert_results_identical(ref, new)


def test_run_search_wallclock_structurally_identical():
    """Wallclock measurements are not replayable across evaluators, so
    the lock is structural: against a *shared* (pre-warmed) evaluator
    the wrapper must propose the identical schedule sequence and read
    back the identical memoized times as the reference loop."""
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    impls, env = E.demo_spmv_impls(g, n=8)
    ev = E.make_evaluator(g, "wallclock", impls=impls, env=env,
                          repeats=1)
    try:
        ref = _reference_run_search(g, S.MCTSSearch(g, 2, seed=5),
                                    budget=10, batch_size=2,
                                    evaluator=ev)
        assert ref.cache_misses > 0
        new = S.run_search(g, S.MCTSSearch(g, 2, seed=5), budget=10,
                           batch_size=2, evaluator=ev)
        assert new.times == ref.times             # pure memo replay
        assert [s.items for s in new.schedules] == \
            [s.items for s in ref.schedules]
        assert new.n_proposed == ref.n_proposed
        assert new.cache_misses == 0              # nothing re-measured
        assert new.cache_hits == ref.cache_hits + ref.cache_misses
    finally:
        ev.close()


def test_run_search_argument_validation_preserved():
    g = C.spmv_dag()
    ev = S.BatchEvaluator(g)
    with pytest.raises(ValueError, match="machine="):
        S.run_search(g, S.RandomSearch(g, 2), evaluator=ev,
                     machine=C.Machine())
    with pytest.raises(ValueError, match="backend"):
        S.run_search(g, S.RandomSearch(g, 2), evaluator=ev,
                     backend="sim")
    with pytest.raises(ValueError, match="acquisition"):
        SearchDriver(g, S.RandomSearch(g, 2),
                     acquisition_kwargs={"beta": 1.0})


def test_driver_is_single_use():
    g = C.spmv_dag()
    drv = SearchDriver(g, S.RandomSearch(g, 2, seed=0), budget=10)
    drv.run()
    with pytest.raises(RuntimeError, match="single-use"):
        drv.run()


def test_driver_acquisition_reaches_portfolio_exploitation_phase():
    """PortfolioSearch delegates the pool protocol to its surrogate
    phase: with argmin_topk the driver-screened run is identical to
    the plain one, and an uncertainty acquisition actually screens."""
    def make_port():
        return S.PortfolioSearch(C.spmv_dag(), 2, seed=0,
                                 seed_proposals=0, mcts_proposals=8,
                                 warmup=12)

    g = C.spmv_dag()
    a, b = make_port(), make_port()
    assert isinstance(a, PoolSearchStrategy)
    res_a = S.run_search(g, a, budget=60, batch_size=4)
    res_b = SearchDriver(g, b, budget=60, batch_size=4,
                         acquisition="argmin_topk").run()
    _assert_results_identical(res_a, res_b)
    assert b.surrogate.n_screened == a.surrogate.n_screened > 0

    c = make_port()
    SearchDriver(g, c, budget=60, batch_size=4, acquisition="ucb",
                 acquisition_kwargs={"beta": 1.0}).run()
    assert c.surrogate.n_screened > 0      # override reached the phase


def test_driver_clamps_over_returning_screen():
    """A screen() that ignores its budget must not overshoot — the
    pool path applies the same clamp as the propose() path."""
    g = C.spmv_dag()

    class Greedy10x(S.SurrogateGuided):
        def screen(self, pool, budget, acquisition):
            return list(pool)              # returns the WHOLE pool

    strat = Greedy10x(g, 2, seed=0, warmup=8)
    res = SearchDriver(g, strat, budget=40, batch_size=4,
                       acquisition="argmin_topk").run()
    assert res.n_proposed == 40
    assert res.cache_hits + res.cache_misses == 40


def test_dataset_sink_dedups_across_driver_runs():
    """One sink fed by two runs over a shared memoized evaluator holds
    each canonical implementation exactly once (the per-run fresh mask
    alone would re-fold run 1's schedules in run 2)."""
    g = C.spmv_dag()
    sink = DatasetSink(g)
    with E.make_evaluator(g, "sim") as ev:
        r1 = SearchDriver(g, S.RandomSearch(g, 2, seed=0), budget=30,
                          evaluator=ev, sinks=[sink]).run()
        SearchDriver(g, S.RandomSearch(g, 2, seed=0), budget=30,
                     evaluator=ev, sinks=[sink]).run()
        r3 = SearchDriver(g, S.RandomSearch(g, 2, seed=1), budget=30,
                          evaluator=ev, sinks=[sink]).run()
    keys = [E.canonical_key(s) for s in sink.schedules]
    assert len(keys) == len(set(keys))     # no duplicate rows
    assert len(sink.schedules) == len(sink.times) == sink.histogram.n
    # run 1's corpus is a prefix; run 3 only appended novel schedules
    assert sink.schedules[:len(r1.schedules)] == r1.schedules
    assert len(sink.schedules) <= len(r1.schedules) + len(r3.schedules)


def test_driver_argmin_topk_reproduces_strategy_screening():
    """The driver's external argmin_topk screening IS the strategy's
    built-in screening: identical results, RNG state, and logs."""
    g = C.spmv_dag()
    a = S.SurrogateGuided(g, 2, seed=0, warmup=16)
    b = S.SurrogateGuided(g, 2, seed=0, warmup=16)
    assert isinstance(a, PoolSearchStrategy)
    res_a = S.run_search(g, a, budget=80, batch_size=4)
    res_b = SearchDriver(g, b, budget=80, batch_size=4,
                         acquisition="argmin_topk").run()
    _assert_results_identical(res_a, res_b)
    assert a.screen_log == b.screen_log
    assert a.n_screened == b.n_screened


# -- acquisition determinism across backends (satellite) --------------------

@pytest.mark.parametrize("acq,kw,noise", [
    ("ucb", {"beta": 1.0}, 0.0),
    ("expected_improvement", {}, 0.0),
    # the noise-permutation guarantee extended to driver rounds: noise
    # is seeded per (canonical key, draw index), so even a *noisy*
    # objective trains byte-identical surrogates on every backend
    ("ucb", {"beta": 1.0}, 0.05),
])
def test_acquisition_chooses_identical_batches_across_backends(
        acq, kw, noise):
    """Same seed + same corpus => identical chosen batch, every round,
    on every analytic backend (extends the evaluator noise-permutation
    guarantee to the full driver round loop: observed times are
    byte-identical across backends, so surrogate fits, acquisition
    scores, and the stable top-k must be too)."""
    streams = {}
    for backend in ("sim", "vectorized", "pool"):
        g = C.spmv_dag()
        strat = S.SurrogateGuided(g, 2, seed=0, warmup=16,
                                  surrogate="boost",
                                  surrogate_kwargs={"n_estimators": 20})
        trace = TraceSink()
        kwargs = {"n_workers": 2} if backend == "pool" else {}
        if noise:
            kwargs.update(noise_sigma=noise, noise_seed=7)
        res = SearchDriver(g, strat, budget=72, batch_size=4,
                           backend=backend,
                           backend_kwargs=kwargs or None,
                           acquisition=acq, acquisition_kwargs=kw,
                           sinks=[trace]).run()
        streams[backend] = (trace.key_stream(), tuple(res.times))
    assert streams["sim"] == streams["vectorized"] == streams["pool"]


# -- acquisition functions ---------------------------------------------------

@pytest.fixture(scope="module")
def boosted_corpus():
    g = halo3d_dag()
    rng = random.Random(0)
    train = [S.random_schedule(g, 2, rng) for _ in range(150)]
    with E.make_evaluator(g, "vectorized") as ev:
        times = ev.evaluate(train)
    sur = S.GradientBoostedSurrogate(g, n_estimators=40)
    for s, t in zip(train, times):
        sur.observe(s, t)
    pool = [S.random_schedule(g, 2, rng) for _ in range(80)]
    return g, sur, pool


def test_predict_with_std_mean_matches_predict(boosted_corpus):
    _, sur, pool = boosted_corpus
    mu, sd = sur.predict_with_std(pool)
    np.testing.assert_array_equal(mu, sur.predict(pool))
    assert sd.shape == mu.shape
    assert np.all(sd >= 0.0)
    assert np.any(sd > 0.0)          # a real ensemble disagrees somewhere
    assert sur.n_trees >= 2


def test_predict_with_std_degenerate_is_zero():
    g = C.spmv_dag()
    sur = S.GradientBoostedSurrogate(g, refit_every=1)
    s = S.random_schedule(g, 2, random.Random(0))
    mu, sd = sur.predict_with_std([s])
    assert mu.tolist() == [0.0] and sd.tolist() == [0.0]
    # generic helper: surrogates without predict_with_std get sd = 0
    ridge = S.RidgeSurrogate(g)
    mu2, sd2 = predict_with_std(ridge, [s])
    assert sd2.tolist() == [0.0]


def test_forest_leaf_values_matches_per_tree_predict(boosted_corpus):
    g, sur, pool = boosted_corpus
    from repro.core.features import apply_features
    X = apply_features(g, pool, sur._features).astype(np.float64)
    H = forest_leaf_values(sur._trees, X)
    assert H.shape == (sur.n_trees, len(pool))
    for t, tree in enumerate(sur._trees):
        np.testing.assert_array_equal(H[t], tree.predict(X))
    with pytest.raises(ValueError, match="at least one tree"):
        forest_leaf_values([], X)


def test_ucb_beta_zero_is_argmin_ordering(boosted_corpus):
    _, sur, pool = boosted_corpus
    s_ucb, mu_ucb = make_acquisition("ucb", beta=0.0)(sur, pool)
    s_arg, mu_arg = make_acquisition("argmin_topk")(sur, pool)
    np.testing.assert_array_equal(s_ucb, s_arg)
    np.testing.assert_array_equal(mu_ucb, mu_arg)
    # positive beta rewards uncertainty: scores can only drop
    s_b, _ = make_acquisition("ucb", beta=2.0)(sur, pool)
    assert np.all(s_b <= s_arg + 1e-15)


def test_expected_improvement_prefers_low_mean_and_uncertainty():
    class Stub:
        def __init__(self, mu, sd):
            self._mu = np.asarray(mu, float)
            self._sd = np.asarray(sd, float)

        def predict(self, pool):
            return self._mu

        def predict_with_std(self, pool):
            return self._mu, self._sd

    ei = make_acquisition("expected_improvement")
    pool = [None] * 3
    # equal sd: lower mean wins (scores are lower-is-better)
    s, mu = ei(Stub([1.0, 2.0, 3.0], [0.5, 0.5, 0.5]), pool, best=2.5)
    assert s[0] < s[1] < s[2]
    np.testing.assert_array_equal(mu, [1.0, 2.0, 3.0])
    # equal mean: higher sd wins
    s, _ = ei(Stub([2.0, 2.0, 2.0], [0.1, 0.5, 1.0]), pool, best=2.0)
    assert s[2] < s[1] < s[0]
    # no incumbent / no uncertainty: falls back to mean ordering
    s, _ = ei(Stub([3.0, 1.0, 2.0], [1.0, 1.0, 1.0]), pool, best=None)
    np.testing.assert_array_equal(s, [3.0, 1.0, 2.0])
    s, _ = ei(Stub([3.0, 1.0, 2.0], [0.0, 0.0, 0.0]), pool, best=2.0)
    np.testing.assert_array_equal(s, [3.0, 1.0, 2.0])
    with pytest.raises(ValueError, match="unknown acquisition"):
        make_acquisition("nope")


def test_expected_improvement_zero_ei_tail_ranks_by_mean():
    """Mixed pool: candidates whose EI is exactly zero (deterministic,
    past the incumbent) must rank by predicted time behind every
    positive-EI candidate — not by accidental pool order."""
    class Stub:
        def predict_with_std(self, pool):
            #           EI > 0     ── zero-EI tail (sd=0, mu>=best) ──
            return (np.array([2.0, 5.0, 3.0, 4.0]),
                    np.array([0.5, 0.0, 0.0, 0.0]))

        def predict(self, pool):
            return self.predict_with_std(pool)[0]

    ei = make_acquisition("expected_improvement")
    s, mu = ei(Stub(), [None] * 4, best=2.5)
    order = np.argsort(s, kind="stable").tolist()
    assert order == [0, 2, 3, 1]          # EI winner, then by mu
    np.testing.assert_array_equal(mu, [2.0, 5.0, 3.0, 4.0])


# -- sinks -------------------------------------------------------------------

def test_dataset_sink_streams_byte_identical_dataset():
    g = C.spmv_dag()
    sink = make_sink("dataset", g)
    res = SearchDriver(g, S.MCTSSearch(g, 2, seed=0), budget=120,
                       batch_size=8, sinks=[sink]).run()
    assert sink.n_consumed == res.n_proposed
    assert [s.items for s in sink.schedules] == \
        [s.items for s in res.schedules]
    fm_s, lab_s, t_s = sink.dataset()
    fm_r, lab_r, t_r = res.dataset()
    assert fm_s.features == fm_r.features
    assert fm_s.X.tobytes() == fm_r.X.tobytes()
    np.testing.assert_array_equal(lab_s.labels, lab_r.labels)
    assert t_s.tobytes() == t_r.tobytes()
    # histogram folded every fresh observation
    assert sink.histogram.n == len(res.schedules)


def test_dataset_sink_matrix_cache_invalidated_by_consume():
    """matrix() prunes once per corpus length: repeated calls return
    the same object, a consume that adds rows drops the cache, and the
    cached matrix stays byte-identical to a fresh pruning pass."""
    g = C.spmv_dag()
    sink = DatasetSink(g)
    SearchDriver(g, S.RandomSearch(g, 2, seed=0), budget=40,
                 batch_size=8, sinks=[sink]).run()
    fm = sink.matrix()
    assert sink.matrix() is fm                 # cached, not re-pruned
    assert sink.dataset()[0] is fm
    SearchDriver(g, S.RandomSearch(g, 2, seed=1), budget=40,
                 batch_size=8, sinks=[sink]).run()
    fm2 = sink.matrix()
    assert fm2 is not fm                       # new rows invalidated it
    assert fm2.X.shape[0] == len(sink.schedules)
    fresh = sink.basis.matrix()
    assert fm2.features == fresh.features
    assert fm2.X.tobytes() == fresh.X.tobytes()


def test_dataset_sink_distill_skips_featurize():
    import repro.rules as R
    g = C.spmv_dag()
    sink = DatasetSink(g)
    res = SearchDriver(g, S.MCTSSearch(g, 2, seed=0), budget=100,
                       sinks=[sink]).run()
    rep_stream = sink.distill()
    rep_batch = R.distill(res)
    assert "featurize" not in rep_stream.stage_seconds
    assert "featurize" in rep_batch.stage_seconds
    assert rep_stream.training_error == rep_batch.training_error
    assert rep_stream.labeling.n_classes == rep_batch.labeling.n_classes
    assert len(rep_stream.rulesets) == len(rep_batch.rulesets)
    # row-count mismatch is rejected, not silently mis-distilled
    with pytest.raises(ValueError, match="rows"):
        R.distill(res, features=C.featurize(g, res.schedules[:-1]))


def test_streaming_histogram_matches_numpy():
    rng = np.random.default_rng(0)
    h = StreamingHistogram(half_bins=32)
    vals = []
    for scale in (1.0, 5.0, 40.0):      # forces two range doublings
        batch = rng.uniform(0.0, scale, 100)
        h.add(batch)
        vals.extend(batch.tolist())
    want, _ = np.histogram(vals, bins=h.edges())
    np.testing.assert_array_equal(h.counts, want)
    assert h.n == len(vals)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # tier-1 container
    from _hypothesis_fallback import given, settings, strategies as st


@settings(max_examples=40, deadline=None)
@given(st.lists(st.lists(st.floats(min_value=0.0, max_value=1e6),
                         min_size=0, max_size=40),
                min_size=1, max_size=8),
       st.integers(min_value=1, max_value=64))
def test_streaming_histogram_property(batches, half_bins):
    """Property lock for the doubling fold: (1) every range doubling
    preserves total counts exactly — observations are merged, never
    dropped; (2) after any batch sequence, counts equal np.histogram
    of the folded data on the histogram's own edges()."""
    h = StreamingHistogram(half_bins=half_bins)
    seen = []
    for batch in batches:
        n_before = h.n
        h.add(np.asarray(batch, dtype=np.float64))
        seen.extend(batch)
        assert h.n == n_before + len(batch)     # doubling loses nothing
    if not seen:
        return
    assert h.counts.size == 2 * half_bins       # footprint is constant
    edges = h.edges()
    assert edges[0] == 0.0 and edges[-1] == h.hi
    assert max(seen) < h.hi or max(seen) == 0.0
    want, _ = np.histogram(seen, bins=edges)
    np.testing.assert_array_equal(h.counts, want)


# -- SearchResult.best() tie handling (satellite) ----------------------------

def test_best_breaks_ties_by_canonical_encoding():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))[:6]
    t = [2.0, 1.0, 1.0, 3.0, 1.0, 4.0]
    tied = [scheds[i] for i in (1, 2, 4)]
    want = min(tied, key=lambda s: tuple(
        (n, -1 if st is None else st) for n, st in E.canonical_key(s)))
    for order in ([0, 1, 2, 3, 4, 5], [5, 4, 3, 2, 1, 0],
                  [2, 4, 1, 0, 3, 5]):
        res = SearchResult(graph=g, schedules=[scheds[i] for i in order],
                           times=[t[i] for i in order], n_proposed=6,
                           cache_hits=0, cache_misses=6)
        best_s, best_t = res.best()
        assert best_t == 1.0
        assert best_s.items == want.items, order
    with pytest.raises(ValueError, match="empty"):
        SearchResult(graph=g, schedules=[], times=[], n_proposed=0,
                     cache_hits=0, cache_misses=0).best()
