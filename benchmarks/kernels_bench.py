"""Kernel microbenchmarks (interpret-mode wall clock on CPU; the
numbers calibrate relative costs, not TPU throughput)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.bench import measure
from repro.kernels.pack import ops as pack_ops
from repro.kernels.spmv import ops as spmv_ops
from repro.spmv.matrix import band_matrix


def kernel_benches() -> list[str]:
    rows = []
    A = band_matrix(n=4096, nnz=32768, half_bandwidth=1024, seed=0)
    x = np.random.default_rng(0).standard_normal(4096).astype(np.float32)
    va, ca, xa = (jnp.asarray(A.vals), jnp.asarray(A.cols),
                  jnp.asarray(x))

    t = measure(lambda: spmv_ops.ell_matvec(va, ca, xa).block_until_ready())
    rows.append(f"kernel_ell_matvec_4k,{t * 1e6:.1f},interpret")
    t = measure(lambda: spmv_ops.ell_matvec_ref(va, ca, xa)
                .block_until_ready())
    rows.append(f"kernel_ell_matvec_ref_4k,{t * 1e6:.1f},oracle")

    idx = jnp.asarray(
        np.random.default_rng(1).integers(0, 4096, 1024).astype(np.int32))
    t = measure(lambda: pack_ops.pack(xa, idx).block_until_ready())
    rows.append(f"kernel_pack_1k,{t * 1e6:.1f},interpret")
    t = measure(lambda: pack_ops.pack_ref(xa, idx).block_until_ready())
    rows.append(f"kernel_pack_ref_1k,{t * 1e6:.1f},oracle")
    return rows


def model_benches() -> list[str]:
    """Reduced-arch step wall-clock: train + decode per arch family."""
    import jax
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, batch_for
    from repro.models.model import LM
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step

    rows = []
    for arch in ("smollm-360m", "deepseek-moe-16b", "rwkv6-3b",
                 "jamba-v0.1-52b"):
        cfg = get_reduced(arch)
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = AdamW(learning_rate=1e-3)
        ostate = opt.init(params)
        dcfg = DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab)
        step = jax.jit(make_train_step(m, opt))
        batch = batch_for(dcfg, 0, cfg)

        def run():
            out = step(params, ostate, batch)
            jax.block_until_ready(out[2]["loss"])

        t = measure(run)
        rows.append(f"train_step_{arch},{t * 1e6:.1f},reduced-cfg")
    return rows
