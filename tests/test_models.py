"""Architecture zoo: per-arch smoke tests + serving/alternate-path
equivalences (the brief's reduced-config smoke requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.shapes import SHAPES, applicable, cells
from repro.models import attention as A
from repro.models.model import LM

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    out = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab),
           "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if cfg.frontend is not None:
        out["frontend"] = jax.random.normal(
            KEY, (b, cfg.frontend.n_positions, cfg.frontend.d_frontend),
            jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: shapes + no
    NaNs (the assigned-architecture smoke test)."""
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step

    cfg = get_reduced(arch)
    m = LM(cfg)
    params = m.init(KEY)
    batch = _batch(cfg)
    logits, aux = m.forward(params, batch)
    assert logits.shape == (2, 16, m.cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(m, opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_reduced(arch)
    m = LM(cfg)
    params = m.init(KEY)
    B, S = 2, 12
    n_front = cfg.frontend.n_positions if cfg.family == "vlm" else 0
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.frontend is not None:
        batch["frontend"] = jax.random.normal(
            KEY, (B, cfg.frontend.n_positions, cfg.frontend.d_frontend),
            jnp.float32)
    lf, _ = m.forward(params, batch)
    npre = S - 3
    pre = dict(batch, tokens=tokens[:, :npre])
    lg, caches = m.prefill(params, pre, S + n_front + 8)
    errs = [float(jnp.abs(lg[:, 0] - lf[:, npre - 1]).max())]
    for i in range(3):
        lg, caches = m.decode_step(
            params, tokens[:, npre + i:npre + i + 1],
            jnp.asarray(npre + i + n_front), caches)
        errs.append(float(jnp.abs(lg[:, 0] - lf[:, npre + i]).max()))
    assert max(errs) == 0.0


def test_full_configs_match_brief():
    """The exact architecture hyperparameters from the assignment."""
    expect = {
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab) == (L, d, h, kv, ff, v), arch
    assert get_config("deepseek-moe-16b").moe.n_experts == 64
    assert get_config("deepseek-moe-16b").moe.top_k == 6
    assert get_config("deepseek-moe-16b").moe.n_shared == 2
    assert get_config("jamba-v0.1-52b").moe.n_experts == 16
    assert get_config("jamba-v0.1-52b").moe.top_k == 2
    assert get_config("jamba-v0.1-52b").pattern.count("attn") == 1
    assert len(get_config("jamba-v0.1-52b").pattern) == 8
    assert get_config("qwen2.5-32b").qkv_bias
    assert get_config("nemotron-4-15b").mlp == "relu2"


def test_shape_cells_and_skips():
    """40 assigned cells; long_500k only for sub-quadratic archs."""
    assert len(ARCHS) * len(SHAPES) == 40
    runnable = cells(ARCHS)
    assert len(runnable) == 32  # 8 pure-attention archs skip long_500k
    assert not applicable("qwen2.5-32b", "long_500k")
    assert applicable("rwkv6-3b", "long_500k")
    assert applicable("jamba-v0.1-52b", "long_500k")
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288


def test_head_padding_layout_exact():
    """Padded/duplicated GQA layout == plain layout, weights mapped via
    slot_to_real."""
    cfg0 = dataclasses.replace(get_reduced("qwen2.5-32b"),
                               dtype="float32")  # 5 q heads, 1 kv head
    m0 = LM(cfg0)
    p0 = m0.init(KEY)
    cfg1 = dataclasses.replace(cfg0, head_pad_to=2)
    assert cfg1.head_layout() == (2, 3, 6)
    m1 = LM(cfg1)
    p1 = m1.init(jax.random.PRNGKey(1))
    s2r = A.slot_to_real(cfg1)

    import jax.tree_util as jtu
    flat0 = {jtu.keystr(k): v
             for k, v in jtu.tree_flatten_with_path(p0)[0]}
    flat1 = jtu.tree_flatten_with_path(p1)[0]
    leaves = []
    for k, v in flat1:
        ks = jtu.keystr(k)
        src = flat0[ks]
        if v.shape == src.shape:
            leaves.append(src)
            continue
        new = jnp.zeros_like(v)
        for slot, real in enumerate(s2r):
            if real is None:
                continue
            if ks.endswith("['wq']") or ks.endswith("['bq']"):
                new = new.at[:, ..., slot, :].set(src[:, ..., real, :])
            else:  # wo
                new = new.at[:, slot].set(src[:, real])
        leaves.append(new)
    p1 = jtu.tree_unflatten(jtu.tree_flatten_with_path(p1)[1], leaves)
    batch = {"tokens": jax.random.randint(KEY, (2, 12), 0, cfg0.vocab)}
    l0, _ = m0.forward(p0, batch)
    l1, _ = m1.forward(p1, batch)
    assert float(jnp.abs(l0 - l1).max()) == 0.0


def test_rwkv_chunked_equals_sequential():
    cfg = dataclasses.replace(get_reduced("rwkv6-3b"), dtype="float32")
    m = LM(cfg)
    params = m.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab)}
    l_seq, _ = m.forward(params, batch)
    l_chk, _ = m.forward(params, batch, rwkv_chunk=8)
    np.testing.assert_allclose(np.asarray(l_seq), np.asarray(l_chk),
                               atol=2e-5)


def test_moe_gather_equals_einsum_dispatch():
    cfg = dataclasses.replace(get_reduced("deepseek-moe-16b"),
                              dtype="float32")
    m1 = LM(cfg)
    params = m1.init(KEY)
    cfg2 = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="gather"))
    m2 = LM(cfg2)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab)}
    l1, _ = m1.forward(params, batch)
    l2, _ = m2.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-5)


def test_windowed_attention_masks_past():
    """With window w, logits must not depend on tokens further back."""
    cfg = dataclasses.replace(get_reduced("granite-3-8b"),
                              dtype="float32", attn_window=4)
    m = LM(cfg)
    params = m.init(KEY)
    t1 = jax.random.randint(KEY, (1, 16), 0, cfg.vocab)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab)
    l1, _ = m.forward(params, {"tokens": t1})
    l2, _ = m.forward(params, {"tokens": t2})
    # position 15 attends only to 12..15 -> unaffected by token 0
    np.testing.assert_allclose(np.asarray(l1[0, -1]),
                               np.asarray(l2[0, -1]), atol=1e-6)
    assert float(jnp.abs(l1[0, 0] - l2[0, 0]).max()) > 0  # sanity
