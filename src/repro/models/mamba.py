"""Mamba (S6) selective state-space block — Jamba's recurrent layer.

    x -> in_proj -> (xp, z);  xp -> causal depthwise conv -> SiLU
    xp -> (dt, B, C);  dt = softplus(dt_proj(dt_r))
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t * xp_t      (per channel)
    y_t = (h_t . C_t) + D * xp_t;   out = out_proj(y * SiLU(z))

Sequential lax.scan over time (exact). Decode carries (conv_state, h):
O(1) per token — with Jamba's windowed attention this is what makes the
long_500k cell runnable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.params import Spec


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, (cfg.d_model + 15) // 16)


def mamba_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dr = _dt_rank(cfg)
    return {
        "in_proj": Spec((d, 2 * di), ("d_model", "d_inner")),
        "conv_w": Spec((cfg.mamba_d_conv, di), (None, "d_inner"),
                       scale=0.5),
        "conv_b": Spec((di,), ("d_inner",), init="zeros"),
        "x_proj": Spec((di, dr + 2 * n), ("d_inner", None)),
        "dt_proj": Spec((dr, di), (None, "d_inner")),
        "dt_bias": Spec((di,), ("d_inner",), init="zeros"),
        "a_log": Spec((di, n), ("d_inner", None), init="zeros"),
        "d_skip": Spec((di,), ("d_inner",), init="ones"),
        "out_proj": Spec((di, d), ("d_inner", "d_model")),
    }


def _conv(p: dict, xp: jax.Array, conv_state: jax.Array):
    """Causal depthwise conv over time. xp: (B, S, di).

    conv_state: (B, d_conv-1, di) — trailing inputs from the previous
    segment. Returns (convolved, new_state).
    """
    dc = p["conv_w"].shape[0]
    hist = jnp.concatenate([conv_state.astype(xp.dtype), xp], axis=1)
    w = p["conv_w"].astype(xp.dtype)
    out = sum(hist[:, i:i + xp.shape[1]] * w[i]
              for i in range(dc))
    out = out + p["conv_b"].astype(xp.dtype)
    return jax.nn.silu(out), hist[:, -(dc - 1):]


def mamba_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  state: tuple[jax.Array, jax.Array] | None = None):
    """x: (B, S, d); state = (conv_state, h) or None -> zeros.

    Returns (y (B, S, d), new_state).
    """
    b, s, d = x.shape
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dr = _dt_rank(cfg)
    dc = cfg.mamba_d_conv
    if state is None:
        conv_state = jnp.zeros((b, dc - 1, di), x.dtype)
        h0 = jnp.zeros((b, di, n), jnp.float32)
    else:
        conv_state, h0 = state

    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)
    xp, z = jnp.split(xz, 2, axis=-1)
    xp = constrain(xp, ("batch", "seq", "d_inner"))
    z = constrain(z, ("batch", "seq", "d_inner"))
    xp, conv_state = _conv(p, xp, conv_state)

    dbc = xp @ p["x_proj"].astype(dt_)
    dt_r, bmat, cmat = jnp.split(dbc, [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(
        dt_r @ p["dt_proj"].astype(dt_) +
        p["dt_bias"].astype(dt_)).astype(jnp.float32)       # (B,S,di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (di,N)

    xpT = xp.astype(jnp.float32).transpose(1, 0, 2)         # (S,B,di)
    dtT = dt.transpose(1, 0, 2)
    bT = bmat.astype(jnp.float32).transpose(1, 0, 2)        # (S,B,N)
    cT = cmat.astype(jnp.float32).transpose(1, 0, 2)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a)                    # (B,di,N)
        h_new = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        yt = jnp.einsum("bdn,bn->bd", h_new, ct)
        return h_new, yt

    # Two-level scan: the outer loop saves the SSM state once per chunk
    # and the checkpointed inner chunk is recomputed in the backward
    # pass — differentiating a flat length-S scan would save the (B,
    # d_inner, N) state at EVERY step (tens of GB per layer at 4k).
    chunk = 1
    for cand in (128, 64, 32, 16, 8, 4, 2):
        if s % cand == 0:
            chunk = cand
            break
    xs = (xpT, dtT, bT, cT)
    if chunk > 1 and s > chunk:
        nc = s // chunk
        xs_c = jax.tree.map(
            lambda a_: a_.reshape(nc, chunk, *a_.shape[1:]), xs)

        @jax.checkpoint
        def chunk_body(h, blk):
            return jax.lax.scan(step, h, blk)

        h_final, y = jax.lax.scan(chunk_body, h0, xs_c)
        y = y.reshape(s, b, di)
    else:
        h_final, y = jax.lax.scan(step, h0, xs)
    y = y.transpose(1, 0, 2).astype(dt_)                    # (B,S,di)
    y = y + xp * p["d_skip"].astype(dt_)
    y = y * jax.nn.silu(z)
    out = constrain(y @ p["out_proj"].astype(dt_),
                    ("batch", "seq", "d_model"))
    return out, (conv_state, h_final)


def mamba_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                 state: tuple[jax.Array, jax.Array]):
    return mamba_forward(p, x, cfg, state=state)
