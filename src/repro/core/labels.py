"""Compatibility shim: labeling now lives in :mod:`repro.rules.labels`.

The §IV-A performance-class labeling moved into the rules distillation
subsystem — :mod:`repro.rules` — where it shares the labels -> trees ->
rulesets pipeline (:func:`repro.rules.distill`) with the vectorized
tree trainer and the design-rule renderer. Import from
:mod:`repro.rules` (or keep importing from here / :mod:`repro.core`;
both stay supported).
"""
from repro.rules.labels import (Labeling, find_peaks, label_times,
                                peak_prominences, peak_prominences_loop,
                                step_convolve)

__all__ = ["Labeling", "find_peaks", "label_times", "peak_prominences",
           "peak_prominences_loop", "step_convolve"]
