"""30-second end-to-end smoke pass: search -> labels -> tree -> rules.

Runs the full paper pipeline through the unified search subsystem on
the SpMV DAG with a small MCTS budget. Used two ways:

  * ``PYTHONPATH=src python benchmarks/smoke.py`` prints the summary;
  * ``pytest -m smoke`` runs it as a marked test
    (tests/test_smoke.py), so CI can gate on the hot path cheaply.
"""
from __future__ import annotations

import time

import repro.core as C
import repro.search as S


def run_smoke(budget: int = 200, seed: int = 0) -> dict:
    """One end-to-end search->rules pass; returns a summary dict."""
    t0 = time.perf_counter()
    g = C.spmv_dag()
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=seed), budget=budget)
    fm, lab, times = res.dataset()
    tree = C.algorithm1(fm.X, lab.labels)
    rulesets = C.extract_rulesets(tree, fm.features)
    best, best_t = res.best()
    return {
        "n_evaluations": res.n_proposed,
        "n_schedules": len(res.schedules),
        "cache_hits": res.cache_hits,
        "best_us": best_t * 1e6,
        "spread": float(times.max() / times.min()),
        "n_classes": lab.n_classes,
        "n_features": len(fm.features),
        "n_rulesets": len(rulesets),
        "training_error": tree.training_error(fm.X, lab.labels),
        "best_order": " ".join(str(i) for i in best.items
                               if i.name not in ("start", "end")),
        "wall_s": time.perf_counter() - t0,
    }


def main() -> None:
    out = run_smoke()
    for k, v in out.items():
        print(f"smoke_{k}: {v}")


if __name__ == "__main__":
    main()
