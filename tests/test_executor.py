"""JAX token-chained executor: schedule invariance property."""
import random

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C


@pytest.fixture(scope="module")
def setup():
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    scheds = list(C.enumerate_schedules(g, 2))
    rng = np.random.default_rng(0)
    AL = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    AR = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
    xL = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    impls = {
        "Pack": C.op_impl(lambda x: x * 1.0, ["xL"], ["sendbuf"]),
        "PostSend": C.op_impl(lambda b: b, ["sendbuf"], ["wire"]),
        "PostRecv": C.op_impl(lambda: jnp.zeros((8,), jnp.float32),
                              [], ["recvbuf"]),
        "WaitSend": C.op_impl(lambda w: w, ["wire"], ["sent"]),
        "WaitRecv": C.op_impl(lambda w, r: w + r, ["wire", "recvbuf"],
                              ["xR"]),
        "yL": C.op_impl(lambda x: AL @ x, ["xL"], ["yL"]),
        "yR": C.op_impl(lambda x: AR @ x, ["xR"], ["yR"]),
    }
    env0 = {"xL": xL}
    ref_run = C.build_runner(g, scheds[0], impls)
    ref = np.asarray(ref_run(env0)["yL"] + ref_run(env0)["yR"])
    return g, scheds, impls, env0, ref


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_schedule_invariance(setup, seed):
    """Every valid (order x stream) implementation computes the same
    values — the sync insertion must be sufficient for correctness."""
    g, scheds, impls, env0, ref = setup
    s = random.Random(seed).choice(scheds)
    out = C.build_runner(g, s, impls)(env0)
    np.testing.assert_allclose(np.asarray(out["yL"] + out["yR"]), ref,
                               rtol=1e-6)


def test_executor_jit_compiles(setup):
    g, scheds, impls, env0, ref = setup
    run = C.jit_runner(g, scheds[-1], impls)
    out = run(env0)
    np.testing.assert_allclose(np.asarray(out["yL"] + out["yR"]), ref,
                               rtol=1e-6)
