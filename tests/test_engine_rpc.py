"""engine.rpc: fleet evaluation is byte-identical to serial.

The ``rpc`` backend ships canonical-unique miss batches to evaluator
hosts as contiguous shards in first-appearance order, with the memo
cache, three-way hit/miss meters, and (canonical key, draw index)
noise all kept client-side — so a fleet-evaluated search must
reproduce the serial backend exactly: same (features, labels, times),
same ``sim_budget`` accounting, for any host count, with hedged
re-dispatch, and across injected host deaths. That identity — plus
the wire framing, the fingerprint handshake, and the local-fallback
degradation — is what this file locks.
"""
import random
import socket

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro.core.dag import halo3d_dag, spmv_dag_fine
from repro.engine import rpc
from repro.engine.server import EvalServer
from repro.search.strategy import random_schedule

# Every in-process server / client pair lives on the loopback device;
# budgets are small because the suite runs on one-CPU CI boxes.


def _servers(space, n, backend="sim", **kw):
    return [EvalServer(space, backend=backend, **kw).start()
            for _ in range(n)]


def _close_all(servers):
    for s in servers:
        s.close()


# -- wire format --------------------------------------------------------------

def test_frame_roundtrip_and_crc():
    a, b = socket.socketpair()
    try:
        payload = bytes([rpc.MSG_WELCOME]) + b"{}"
        rpc.send_frame(a, payload)
        assert rpc.recv_frame(b) == (rpc.MSG_WELCOME, b"{}")
        # Flip one payload byte in an otherwise well-formed frame: the
        # CRC must catch it (corrupt frames are host failures, never
        # silently-wrong data).
        buf = bytearray(rpc._LEN.pack(len(payload)) + payload
                        + rpc._LEN.pack(__import__("zlib").crc32(payload)))
        buf[5] ^= 0xFF
        a.sendall(bytes(buf))
        with pytest.raises(rpc.RpcProtocolError, match="CRC"):
            rpc.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_frame_rejects_implausible_length():
    a, b = socket.socketpair()
    try:
        a.sendall(rpc._LEN.pack(rpc.MAX_FRAME + 1))
        with pytest.raises(rpc.RpcProtocolError, match="length"):
            rpc.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_message_codecs_roundtrip():
    fp = bytes(range(16))
    assert rpc.decode_hello(rpc.encode_hello(fp)[1:]) == fp
    with pytest.raises(rpc.RpcProtocolError, match="magic"):
        rpc.decode_hello(b"NOT-THE-MAGIC----" + bytes(18))

    enc = np.arange(24, dtype=np.int32).reshape(3, 2, 4)
    sid, back = rpc.decode_eval(rpc.encode_eval(7, enc)[1:])
    assert sid == 7 and back.dtype == np.dtype("<i4")
    assert np.array_equal(back, enc)

    times = [1.5, 2.25, 3.125]
    sid, got = rpc.decode_result(rpc.encode_result(9, times)[1:])
    assert sid == 9 and got.tolist() == times

    sid, msg = rpc.decode_error(rpc.encode_error(3, "boom")[1:])
    assert (sid, msg) == (3, "boom")


def test_parse_host():
    assert rpc.parse_host("127.0.0.1:9876") == ("127.0.0.1", 9876)
    assert rpc.parse_host(("h", 1)) == ("h", 1)
    with pytest.raises(ValueError):
        rpc.parse_host("no-port")


# -- bit-identity vs the serial backend ---------------------------------------

@pytest.mark.parametrize("n_servers", [1, 2, 3])
def test_rpc_bit_identical_to_serial(n_servers):
    g = halo3d_dag()
    servers = _servers(g, n_servers)
    rng = random.Random(7)
    scheds = [random_schedule(g, 2, rng) for _ in range(48)]
    try:
        with E.make_evaluator(g, "rpc", hosts=[s.addr for s in servers],
                              min_shard=1, max_inflight=2) as ev:
            assert ev.evaluate(scheds) == [C.makespan(g, s)
                                           for s in scheds]
            assert ev.local_evals == 0
            assert sum(h["shards_done"] for h in
                       ev.rpc_stats()["hosts"].values()) > 0
    finally:
        _close_all(servers)


def test_rpc_accounting_matches_serial():
    g = spmv_dag_fine()
    servers = _servers(g, 2)
    rng = random.Random(8)
    scheds = [random_schedule(g, 2, rng) for _ in range(40)]
    batch = scheds + scheds[:10]          # duplicates -> memory hits
    ser = E.make_evaluator(g, "sim")
    try:
        with E.make_evaluator(g, "rpc", hosts=[s.addr for s in servers],
                              min_shard=1) as ev:
            assert ev.evaluate(batch) == ser.evaluate(batch)
            assert (ev.cache_hits, ev.cache_misses) == \
                (ser.cache_hits, ser.cache_misses)
            assert ev.stats()["backend"] == "rpc"
            assert len(ev) == len(ser)
    finally:
        _close_all(servers)


@pytest.mark.parametrize("make_strategy", [
    lambda g: S.MCTSSearch(g, 2, seed=5),
    lambda g: S.RandomSearch(g, 2, seed=5),
], ids=["mcts", "random"])
def test_run_search_rpc_byte_identical_dataset(make_strategy):
    """The acceptance lock: run_search(backend='rpc') returns
    byte-identical (features, labels, times) and budget accounting to
    the serial backend at equal sim_budget, on halo3d."""
    g = halo3d_dag()
    servers = _servers(g, 2)
    hosts = [s.addr for s in servers]
    datasets = {}
    try:
        for backend, kwargs in (
                ("sim", {}),
                ("rpc", {"hosts": hosts, "min_shard": 1})):
            res = S.run_search(g, make_strategy(g), budget=None,
                               sim_budget=60, batch_size=8,
                               backend=backend, backend_kwargs=kwargs)
            datasets[backend] = (res, *res.dataset())
    finally:
        _close_all(servers)
    res_a, fm_a, lab_a, t_a = datasets["sim"]
    res_b, fm_b, lab_b, t_b = datasets["rpc"]
    assert t_a.tobytes() == t_b.tobytes()
    assert fm_a.X.tobytes() == fm_b.X.tobytes()
    assert fm_a.names() == fm_b.names()
    assert np.array_equal(lab_a.labels, lab_b.labels)
    assert (res_a.cache_hits, res_a.cache_misses) == \
        (res_b.cache_hits, res_b.cache_misses)


def test_rpc_noise_identical_to_serial_noise():
    """(canonical key, draw index) noise stays client-side: only base
    times cross the wire, so noisy fleet == noisy serial exactly."""
    g = C.spmv_dag()
    servers = _servers(g, 2)
    rng = random.Random(3)
    scheds = [random_schedule(g, 2, rng) for _ in range(24)]
    try:
        with E.make_evaluator(g, "rpc", hosts=[s.addr for s in servers],
                              min_shard=1, noise_sigma=0.05,
                              noise_seed=11) as ev:
            noisy_rpc = ev.evaluate(scheds)
    finally:
        _close_all(servers)
    ser = E.make_evaluator(g, "sim", noise_sigma=0.05, noise_seed=11)
    assert noisy_rpc == ser.evaluate(scheds)


# -- fault tolerance ----------------------------------------------------------

class _KillerStrategy:
    """Wraps a strategy; closes one server after ``after`` proposals —
    the "host dies mid-search" event, injected deterministically."""

    def __init__(self, inner, server, after):
        self.inner = inner
        self.server = server
        self.after = after
        self.calls = 0

    def propose(self, budget):
        self.calls += 1
        if self.calls == self.after:
            self.server.close()
        return self.inner.propose(budget)

    def observe(self, schedule, time):
        self.inner.observe(schedule, time)


def test_rpc_server_killed_mid_search_identical():
    """Kill one of two servers between rounds: the run completes (the
    survivor absorbs re-queued shards) with results byte-identical to
    serial, and the dead host is marked."""
    g = halo3d_dag()
    servers = _servers(g, 2)
    try:
        ref = S.run_search(g, S.MCTSSearch(g, 2, seed=5), budget=None,
                           sim_budget=60, batch_size=8, backend="sim")
        ev = E.make_evaluator(g, "rpc", hosts=[s.addr for s in servers],
                              min_shard=1, retries=1, backoff=0.01)
        res = S.run_search(
            g, _KillerStrategy(S.MCTSSearch(g, 2, seed=5),
                               servers[0], after=3),
            budget=None, sim_budget=60, batch_size=8, evaluator=ev)
        assert res.times_array().tobytes() == \
            ref.times_array().tobytes()
        assert (res.cache_hits, res.cache_misses) == \
            (ref.cache_hits, ref.cache_misses)
        stats = ev.rpc_stats()["hosts"]
        assert stats[servers[0].addr]["alive"] is False
        assert stats[servers[1].addr]["alive"] is True
        ev.close()
    finally:
        _close_all(servers)


def test_rpc_all_hosts_down_local_fallback():
    g = halo3d_dag()
    server = EvalServer(g).start()
    addr = server.addr
    server.close()                        # fleet is dead before use
    rng = random.Random(9)
    scheds = [random_schedule(g, 2, rng) for _ in range(16)]
    with E.make_evaluator(g, "rpc", hosts=[addr], min_shard=1,
                          retries=1, backoff=0.01,
                          connect_timeout=2.0) as ev:
        assert ev.evaluate(scheds) == [C.makespan(g, s) for s in scheds]
        assert ev.local_evals == len(scheds)
        assert ev.rpc_stats()["local_evals"] == len(scheds)


def test_rpc_all_hosts_down_no_fallback_raises():
    g = spmv_dag_fine()
    server = EvalServer(g).start()
    addr = server.addr
    server.close()
    rng = random.Random(10)
    scheds = [random_schedule(g, 2, rng) for _ in range(8)]
    with E.make_evaluator(g, "rpc", hosts=[addr], min_shard=1,
                          retries=0, backoff=0.01, connect_timeout=2.0,
                          local_fallback=False) as ev:
        with pytest.raises(E.RpcError):
            ev.evaluate(scheds)


def test_rpc_fingerprint_mismatch_refused():
    """A server for a different space must refuse the handshake — a
    configuration error surfaced loudly, never silently-wrong data."""
    g_client = halo3d_dag()
    server = EvalServer(spmv_dag_fine()).start()
    rng = random.Random(11)
    scheds = [random_schedule(g_client, 2, rng) for _ in range(8)]
    try:
        with E.make_evaluator(g_client, "rpc", hosts=[server.addr],
                              min_shard=1) as ev:
            with pytest.raises(E.RpcHandshakeError, match="refused"):
                ev.evaluate(scheds)
        assert server.n_refused == 1
    finally:
        server.close()


def test_rpc_hedges_straggler_to_idle_host():
    """One deliberately slow host: the fast host drains the queue, then
    hedges the straggler's in-flight shards — results stay identical
    and the batch completes at the fast host's pace."""
    g = spmv_dag_fine()
    slow = EvalServer(g, delay=0.3).start()
    fast = EvalServer(g).start()
    rng = random.Random(12)
    scheds = [random_schedule(g, 2, rng) for _ in range(16)]
    try:
        with E.make_evaluator(g, "rpc", hosts=[slow.addr, fast.addr],
                              min_shard=1, max_inflight=2) as ev:
            assert ev.evaluate(scheds) == [C.makespan(g, s)
                                           for s in scheds]
            hosts = ev.rpc_stats()["hosts"]
            assert hosts[fast.addr]["hedged"] >= 1
    finally:
        _close_all([slow, fast])
