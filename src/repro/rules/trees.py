"""Vectorized CART training — the shared tree kernel of the rules
subsystem (paper §IV-C, Algorithm 1).

This container has no scikit-learn, so we implement the subset of
``DecisionTreeClassifier`` the paper uses: CART with gini impurity,
``class_weight='balanced'``, ``max_leaf_nodes`` (best-first growth by
weighted impurity decrease, like sklearn) and ``max_depth``. Tests
cross-check against sklearn when it is importable.

The split finder comes in two interchangeable implementations:

``splitter="vectorized"`` (default)
    Sort-based: every feature column is analysed **once** per dataset
    (:class:`Presort`, reused across the whole Algorithm-1
    ``max_leaf_nodes`` sweep and across boosting rounds) and all
    thresholds of all features are scored per node as numpy array ops.
    Binary features — the paper's entire §IV-B order/stream space —
    take a matmul fast path: per node, one (rows × features) indicator
    gather and one BLAS product against the one-hot classes yield every
    candidate's class histogram, with no per-node sorting state at
    all. Multi-valued features keep presorted row orders (argsorted
    once, then *filtered* down the tree, never re-sorted) and score
    candidates from cumulative class counts gathered only at
    value-boundary positions.

``splitter="loop"``
    The original per-candidate Python loop (one masked histogram pair
    per threshold), kept as the benchmark/property-test reference
    (``benchmarks/trees_bench.py``, tests/test_rules_trees.py).

Both splitters produce **bit-identical trees**: class histograms are
computed as ``class_weight * integer_count`` (exact — never an
order-dependent float accumulation), every reduction over the class
axis runs in ascending class order in both implementations, and ties
in gain resolve to the lowest (feature, threshold) candidate. The same
kernels score variance-reduction splits for :class:`RegressionTree`,
the base learner of :class:`repro.rules.boost.GradientBoostedSurrogate`.

The tree is intentionally allowed to overfit (paper §IV-C): it
describes the explored design space; generalization is measured
separately (Table V).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools

import numpy as np

# Work-chunk size (elements of the (features x node rows) score matrix)
# for the sorted split path: bounds peak memory without changing any
# result (the kernel is elementwise across features).
_FEATURE_BLOCK = 4 * 1024 * 1024
# float32 holds integer counts exactly below 2^24; larger nodes use the
# float64 indicator copy in the binary matmul path.
_F32_EXACT = 1 << 24


def _wsum(vec) -> float:
    """Sum in ascending index order.

    Both splitters reduce over the class axis with this exact
    (sequential) order, so their per-candidate gains — and therefore
    the trees they grow — are bit-identical; ``np.sum`` reorders by
    memory layout and would break that.
    """
    tot = 0.0
    for x in vec:
        tot += float(x)
    return tot


def _gini(weighted_counts) -> float:
    tot = _wsum(weighted_counts)
    if tot <= 0:
        return 0.0
    acc = 0.0
    for c in weighted_counts:
        p = float(c) / tot
        acc += p * p
    return 1.0 - acc


class Presort:
    """Per-dataset feature analysis shared across tree fits.

    Built once per feature matrix — the expensive O(d·n·log n) part of
    sort-based CART — and reused by every ``train(max_leaf_nodes)``
    trial of :func:`algorithm1` and every boosting round of a
    gradient-boosted ensemble (only labels/residuals change between
    those fits). Holds:

    * ``order`` / ``ranks`` — per-feature stable argsort and dense
      value ranks (equal values share a rank), restricted to the
      multi-valued features (``nb_cols``) for node-level split scoring;
    * ``bin_cols`` / ``bin_thr`` / ``IBf`` / ``IBd`` — the binary
      features, their single candidate threshold (midpoint of the two
      observed values), and the 0/1 indicator matrix of the upper
      value in float32/float64 for exact BLAS count histograms;
    * constant features appear in neither set — no splitter can use
      them (the loop reference skips them the same way).
    """

    def __init__(self, X: np.ndarray):
        X = np.asarray(X, dtype=np.float64)
        if X.ndim != 2:
            raise ValueError(f"X must be 2-D, got shape {X.shape}")
        self.X = np.ascontiguousarray(X)
        self.XT = np.ascontiguousarray(self.X.T)
        n, d = self.X.shape
        self.order = np.argsort(self.XT, axis=1, kind="stable") \
            .astype(np.int32)
        # Dense per-feature value ranks: equal values share a rank, so
        # split-candidate boundaries are int32 comparisons instead of
        # float64 gathers.
        V = np.take_along_axis(self.XT, self.order, axis=1)
        grp = np.zeros(V.shape, dtype=np.int32)
        if n > 1:
            np.cumsum(V[:, 1:] != V[:, :-1], axis=1, dtype=np.int32,
                      out=grp[:, 1:])
        self.ranks = np.empty_like(grp)
        np.put_along_axis(self.ranks, self.order, grp, axis=1)

        max_rank = self.ranks.max(axis=1) if n else \
            np.zeros(d, dtype=np.int32)
        self.bin_cols = np.flatnonzero(max_rank == 1)
        self.nb_cols = np.flatnonzero(max_rank >= 2)
        self.order_nb = np.ascontiguousarray(self.order[self.nb_cols])
        self.ranks_nb = np.ascontiguousarray(self.ranks[self.nb_cols])
        if self.bin_cols.size:
            lo_v = self.XT[self.bin_cols, self.order[self.bin_cols, 0]]
            hi_v = self.XT[self.bin_cols, self.order[self.bin_cols, -1]]
            self.bin_thr = (lo_v + hi_v) / 2.0
            self.IBf = (self.X[:, self.bin_cols] == hi_v[None, :]) \
                .astype(np.float32)
        else:
            self.bin_thr = np.zeros(0, dtype=np.float64)
            self.IBf = np.zeros((n, 0), dtype=np.float32)
        self._IBd: np.ndarray | None = None

    @property
    def IBd(self) -> np.ndarray:
        """float64 indicator copy, built on first use.

        Only the regression path (and >=2^24-row classifier nodes)
        reads it; classification-only workloads never pay the copy.
        """
        if self._IBd is None:
            self._IBd = self.IBf.astype(np.float64)
        return self._IBd

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def d(self) -> int:
        return self.X.shape[1]


def _check_presort(presort: Presort | None, X: np.ndarray) -> Presort:
    if presort is None:
        return Presort(X)
    if presort.X.shape != np.shape(X):
        raise ValueError(
            f"presort built for shape {presort.X.shape}, got "
            f"{np.shape(X)}")
    return presort


@dataclasses.dataclass
class TreeNode:
    node_id: int
    depth: int
    indices: np.ndarray                  # training rows in this node
    value: np.ndarray                    # weighted class counts
    n_samples: int
    feature: int | None = None           # split feature (None = leaf)
    threshold: float = 0.5
    left: "TreeNode | None" = None
    right: "TreeNode | None" = None

    @property
    def is_leaf(self) -> bool:
        return self.feature is None

    def majority_class(self) -> int:
        return int(np.argmax(self.value))


@dataclasses.dataclass
class _Candidate:
    gain: float
    feature: int
    threshold: float
    left_idx: np.ndarray
    right_idx: np.ndarray
    left_value: np.ndarray
    right_value: np.ndarray


# -- split scoring -----------------------------------------------------------

def _best_split_loop(ps: Presort, y_enc: np.ndarray, class_w: np.ndarray,
                     idx: np.ndarray, parent_imp: float,
                     tot_w: float) -> tuple[float, int, float] | None:
    """Reference split finder: one histogram pair per candidate."""
    K = len(class_w)
    Xn = ps.X[idx]
    yn = y_enc[idx]
    best: tuple[float, int, float] | None = None
    for f in range(Xn.shape[1]):
        col = Xn[:, f]
        vals = np.unique(col)
        if len(vals) < 2:
            continue
        for j in range(len(vals) - 1):
            t = (vals[j] + vals[j + 1]) / 2.0
            mask = col <= t
            lv = class_w * np.bincount(yn[mask], minlength=K)
            rv = class_w * np.bincount(yn[~mask], minlength=K)
            lw, rw = _wsum(lv), _wsum(rv)
            child = (lw * _gini(lv) + rw * _gini(rv)) / tot_w
            gain = tot_w * (parent_imp - child)
            if best is None or gain > best[0]:
                best = (gain, f, float(t))
    return best


def _gini_gains(left_counts: list[np.ndarray],
                right_counts: list[np.ndarray], class_w: np.ndarray,
                parent_imp: float, tot_w: float) -> np.ndarray:
    """Per-candidate weighted impurity decrease from integer counts.

    Two passes over the (small) class axis, both in ascending class
    order: first the left/right total weights, then the gini sums of
    squares (which need the totals) — the exact op order of the loop
    reference's scalar math, applied elementwise.
    """
    K = len(class_w)
    Lw: list[np.ndarray] = []
    Rw: list[np.ndarray] = []
    lw = rw = None
    for k in range(K):
        l_k = class_w[k] * left_counts[k]
        r_k = class_w[k] * right_counts[k]
        Lw.append(l_k)
        Rw.append(r_k)
        lw = l_k if lw is None else lw + l_k
        rw = r_k if rw is None else rw + r_k
    lacc = racc = None
    for k in range(K):
        p = Lw[k] / lw
        q = Rw[k] / rw
        lacc = p * p if lacc is None else lacc + p * p
        racc = q * q if racc is None else racc + q * q
    child = (lw * (1.0 - lacc) + rw * (1.0 - racc)) / tot_w
    return tot_w * (parent_imp - child)


def _best_split_binary(ps: Presort, y_enc: np.ndarray,
                       class_w: np.ndarray, idx: np.ndarray,
                       tcnt: np.ndarray, parent_imp: float,
                       tot_w: float) -> tuple[float, int, float] | None:
    """All binary features of a node in one BLAS product.

    The class histogram right of every binary feature's single
    threshold is ``indicator.T @ onehot(classes)`` — integer counts,
    exact in float32 below 2^24 rows — and the left histogram is the
    node total minus it. No sorting state is touched.
    """
    m = idx.size
    K = len(class_w)
    IB = ps.IBf if m < _F32_EXACT else ps.IBd
    In = IB if m == IB.shape[0] else np.take(IB, idx, axis=0)
    oh = np.zeros((m, K), dtype=IB.dtype)
    oh[np.arange(m), y_enc[idx]] = 1.0
    rcnt = (In.T @ oh).astype(np.int64)              # (d_bin, K) exact
    nright = rcnt.sum(axis=1)
    valid = (nright > 0) & (nright < m)
    if not valid.any():
        return None
    left_counts = [tcnt[k] - rcnt[:, k] for k in range(K)]
    right_counts = [rcnt[:, k] for k in range(K)]
    with np.errstate(invalid="ignore", divide="ignore"):
        # node-constant features divide by an empty side; masked below
        gains = _gini_gains(left_counts, right_counts, class_w,
                            parent_imp, tot_w)
    gains[~valid] = -np.inf
    i = int(np.argmax(gains))            # first max: lowest feature id
    return (float(gains[i]), int(ps.bin_cols[i]), float(ps.bin_thr[i]))


def _best_split_sorted(ps: Presort, y_enc: np.ndarray,
                       class_w: np.ndarray, no: np.ndarray,
                       tcnt: np.ndarray, parent_imp: float,
                       tot_w: float) -> tuple[float, int, float] | None:
    """Multi-valued features: score every threshold from presorted rows.

    ``no`` is the (d_nb, m) matrix of this node's row indices,
    presorted per feature (filtered down from :attr:`Presort.order_nb`,
    never re-sorted). Candidate thresholds sit between consecutive
    distinct sorted values (int32 rank comparisons); gains are
    evaluated **only at those boundary positions** (flat-indexed) from
    cumulative integer class counts.
    """
    d, m = no.shape
    if d == 0 or m < 2:
        return None
    K = len(class_w)
    best: tuple[float, int, float] | None = None
    block = max(1, _FEATURE_BLOCK // m)
    for lo in range(0, d, block):
        o = no[lo:lo + block]
        RV = np.take_along_axis(ps.ranks_nb[lo:lo + block], o, axis=1)
        boundary = RV[:, :-1] != RV[:, 1:]
        ridx = np.flatnonzero(boundary.any(axis=1))  # non-constant here
        if ridx.size == 0:
            continue
        ov = o[ridx]
        rows, cols = np.nonzero(boundary[ridx])      # feature-major order
        C = y_enc[ov]
        # Integer left counts per class at the candidates; the last
        # class is implied (left size minus the others) — all exact.
        left_counts: list[np.ndarray] = []
        csum = None
        for k in range(K - 1):
            cnt = np.cumsum(C == k, axis=1, dtype=np.int32)[rows, cols]
            left_counts.append(cnt)
            csum = cnt.astype(np.int64) if csum is None else csum + cnt
        left_counts.append(cols + 1 - csum)          # left sizes - rest
        right_counts = [tcnt[k] - left_counts[k] for k in range(K)]
        gains = _gini_gains(left_counts, right_counts, class_w,
                            parent_imp, tot_w)
        i = int(np.argmax(gains))        # first max: lowest (f, t) wins
        g = float(gains[i])
        if best is None or g > best[0]:  # strict: earlier chunk wins ties
            fa = lo + int(ridx[rows[i]])     # chunk-local -> nb-global
            pos = int(cols[i])
            a = ps.XT[ps.nb_cols[fa], ov[rows[i], pos]]
            b = ps.XT[ps.nb_cols[fa], ov[rows[i], pos + 1]]
            best = (g, int(ps.nb_cols[fa]), float((a + b) / 2.0))
    return best


def _merge_candidates(a: tuple[float, int, float] | None,
                      b: tuple[float, int, float] | None
                      ) -> tuple[float, int, float] | None:
    """Best of two per-path candidates, loop-ordered on exact ties.

    The loop reference walks features in ascending global index and
    only replaces on strictly larger gain, so an exact tie between the
    binary and sorted paths resolves to the lower feature index.
    """
    if a is None:
        return b
    if b is None:
        return a
    if a[0] != b[0]:
        return a if a[0] > b[0] else b
    return a if a[1] < b[1] else b


# -- regression (variance-reduction) scoring ---------------------------------

def _best_split_reg_binary(ps: Presort, y: np.ndarray, idx: np.ndarray,
                           parent_sse: float, s: float,
                           ss: float) -> tuple[float, int, float] | None:
    m = idx.size
    In = ps.IBd if m == ps.IBd.shape[0] \
        else np.take(ps.IBd, idx, axis=0)
    yn = y if m == ps.IBd.shape[0] else y[idx]
    nr = In.sum(axis=0)
    valid = (nr > 0) & (nr < m)
    if not valid.any():
        return None
    sr = yn @ In
    ssr = (yn * yn) @ In
    nl = m - nr
    sl = s - sr
    with np.errstate(invalid="ignore", divide="ignore"):
        sse_l = (ss - ssr) - sl * sl / nl
        sse_r = ssr - sr * sr / nr
        gains = parent_sse - sse_l - sse_r
    gains[~valid] = -np.inf
    i = int(np.argmax(gains))
    return (float(gains[i]), int(ps.bin_cols[i]), float(ps.bin_thr[i]))


def _best_split_reg_sorted(ps: Presort, y: np.ndarray, no: np.ndarray,
                           parent_sse: float, s: float,
                           ss: float) -> tuple[float, int, float] | None:
    d, m = no.shape
    if d == 0 or m < 2:
        return None
    best: tuple[float, int, float] | None = None
    block = max(1, _FEATURE_BLOCK // m)
    for lo in range(0, d, block):
        o = no[lo:lo + block]
        RV = np.take_along_axis(ps.ranks_nb[lo:lo + block], o, axis=1)
        boundary = RV[:, :-1] != RV[:, 1:]
        ridx = np.flatnonzero(boundary.any(axis=1))
        if ridx.size == 0:
            continue
        ov = o[ridx]
        rows, cols = np.nonzero(boundary[ridx])
        Y = y[ov]
        ls = np.cumsum(Y, axis=1)[rows, cols]
        lss = np.cumsum(Y * Y, axis=1)[rows, cols]
        cl = (cols + 1).astype(np.float64)
        sse_l = lss - ls * ls / cl
        sse_r = (ss - lss) - (s - ls) ** 2 / (m - cl)
        gains = parent_sse - sse_l - sse_r
        i = int(np.argmax(gains))
        g = float(gains[i])
        if best is None or g > best[0]:
            fa = lo + int(ridx[rows[i]])     # chunk-local -> nb-global
            pos = int(cols[i])
            a = ps.XT[ps.nb_cols[fa], ov[rows[i], pos]]
            b = ps.XT[ps.nb_cols[fa], ov[rows[i], pos + 1]]
            best = (g, int(ps.nb_cols[fa]), float((a + b) / 2.0))
    return best


# -- shared growth machinery -------------------------------------------------

def _partition_sorted(parent_no: np.ndarray, left_idx: np.ndarray,
                      n: int) -> tuple[np.ndarray, np.ndarray]:
    """Split a node's presorted row matrix between its children.

    A stable boolean filter of each presorted row preserves the sort,
    so children never pay another argsort.
    """
    mask = np.zeros(n, dtype=bool)
    mask[left_idx] = True
    sel = mask[parent_no]
    d = parent_no.shape[0]
    return (parent_no[sel].reshape(d, -1),
            parent_no[~sel].reshape(d, -1))


def _flatten(root, leaf_value) -> tuple[np.ndarray, ...]:
    """Preorder array form of a tree for vectorized batch descent.

    Leaves self-loop (``left == right == own slot``), so descent can
    run a fixed number of rounds without an activity mask.
    """
    nodes: list = []

    def walk(nd) -> None:
        nodes.append(nd)
        if nd.feature is not None:
            walk(nd.left)
            walk(nd.right)

    walk(root)
    slot = {id(nd): i for i, nd in enumerate(nodes)}
    size = len(nodes)
    feat = np.full(size, -1, dtype=np.int64)
    thr = np.zeros(size, dtype=np.float64)
    left = np.arange(size, dtype=np.int64)
    right = np.arange(size, dtype=np.int64)
    val = np.zeros(size, dtype=np.float64)
    for i, nd in enumerate(nodes):
        if nd.feature is not None:
            feat[i] = nd.feature
            thr[i] = nd.threshold
            left[i] = slot[id(nd.left)]
            right[i] = slot[id(nd.right)]
        else:
            val[i] = leaf_value(nd)
    return feat, thr, left, right, val


def _descend(flat: tuple[np.ndarray, ...], X: np.ndarray) -> np.ndarray:
    """Leaf slot per row of ``X`` (vectorized batch traversal)."""
    feat, thr, left, right, _ = flat
    cur = np.zeros(len(X), dtype=np.int64)
    rows = np.arange(len(X))
    while True:
        f = feat[cur]
        active = f >= 0
        if not active.any():
            return cur
        xv = X[rows, np.where(active, f, 0)]
        nxt = np.where(xv <= thr[cur], left[cur], right[cur])
        cur = np.where(active, nxt, cur)


def forest_leaf_values(trees, X: np.ndarray) -> np.ndarray:
    """Per-tree leaf predictions for a whole ensemble in one descent.

    ``trees`` is a sequence of fitted :class:`RegressionTree` /
    :class:`DecisionTree`; the result is ``(n_trees, n_rows)`` with
    row ``t`` equal to ``trees[t]``'s raw leaf values on ``X`` (the
    regression mean per leaf; the *encoded* majority class for
    classifiers). All trees' flattened node arrays are concatenated
    with slot offsets and descended together — one gather per level of
    the deepest tree instead of one full descent per tree, which is
    what makes per-tree ensemble variance
    (:meth:`repro.rules.boost.GradientBoostedSurrogate.
    predict_with_std`) cheap enough to sit in the acquisition hot
    path. Leaves self-loop, so rows that finish early idle at their
    leaf slot without a compaction pass.
    """
    if not trees:
        raise ValueError("forest_leaf_values needs at least one tree")
    X = np.asarray(X, dtype=np.float64)
    flats = [t._flatten() for t in trees]
    sizes = np.array([f[0].size for f in flats], dtype=np.int64)
    off = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    feat = np.concatenate([f[0] for f in flats])
    thr = np.concatenate([f[1] for f in flats])
    left = np.concatenate([f[2] + o for f, o in zip(flats, off)])
    right = np.concatenate([f[3] + o for f, o in zip(flats, off)])
    val = np.concatenate([f[4] for f in flats])
    n_trees, n = len(flats), len(X)
    cur = np.repeat(off, n)                       # each tree's root slot
    rows = np.tile(np.arange(n), n_trees)
    while True:
        f = feat[cur]
        active = f >= 0
        if not active.any():
            break
        xv = X[rows, np.where(active, f, 0)]
        nxt = np.where(xv <= thr[cur], left[cur], right[cur])
        cur = np.where(active, nxt, cur)
    return val[cur].reshape(n_trees, n)


class DecisionTree:
    """CART classifier (gini, balanced class weights, best-first growth).

    ``splitter="vectorized"`` (default) and ``splitter="loop"`` grow
    bit-identical trees; the former scores all candidate splits with
    numpy/BLAS array ops over a :class:`Presort` analysis. Pass a
    shared ``presort`` to ``fit`` to amortize that analysis across
    fits on the same feature matrix.
    """

    _SPLITTERS = ("vectorized", "loop")

    def __init__(self, max_leaf_nodes: int, max_depth: int | None = None,
                 splitter: str = "vectorized"):
        if max_leaf_nodes < 2:
            raise ValueError("max_leaf_nodes must be >= 2")
        if splitter not in self._SPLITTERS:
            raise ValueError(f"splitter must be one of {self._SPLITTERS}")
        self.max_leaf_nodes = max_leaf_nodes
        self.max_depth = max_depth
        self.splitter = splitter
        self.root: TreeNode | None = None
        self.n_classes = 0
        self.classes_: np.ndarray | None = None
        self._flat: tuple[np.ndarray, ...] | None = None

    # -- fitting ----------------------------------------------------------
    def fit(self, X: np.ndarray, y: np.ndarray,
            presort: Presort | None = None,
            split_cache: dict | None = None) -> "DecisionTree":
        """Fit on (X, y); see the class docstring.

        ``split_cache`` memoizes best-split candidates by node row-set
        across fits on the **same (X, y)** — a node's best split does
        not depend on ``max_leaf_nodes``/``max_depth``, so the
        Algorithm-1 sweep passes one dict and every re-trial reuses the
        shallow splits it already scored. Never share a cache across
        different data.
        """
        ps = _check_presort(presort, X)
        y = np.asarray(y)
        if len(y) != ps.n:
            raise ValueError(f"X has {ps.n} rows but y has {len(y)}")
        self.classes_, y_enc = np.unique(y, return_inverse=True)
        y_enc = y_enc.astype(np.int32)       # halves per-node gathers
        self.n_classes = K = len(self.classes_)
        n = ps.n
        # class_weight='balanced': w_c = n / (k * n_c)
        counts = np.bincount(y_enc, minlength=K)
        class_w = np.where(counts > 0,
                           n / (K * np.maximum(counts, 1)), 0.0)
        vectorized = self.splitter == "vectorized"
        track_sorted = vectorized and ps.nb_cols.size > 0

        ids = itertools.count()
        all_idx = np.arange(n)
        self.root = TreeNode(next(ids), 0, all_idx,
                             class_w * counts, n_samples=n)
        sorted_rows: dict[int, np.ndarray] = {}
        if track_sorted:
            sorted_rows[self.root.node_id] = ps.order_nb

        def best_split(node: TreeNode) -> _Candidate | None:
            idx = node.indices
            if len(idx) < 2:
                return None
            key = idx.tobytes() if split_cache is not None else None
            if key is not None and key in split_cache:
                return split_cache[key]
            parent_imp = _gini(node.value)
            if parent_imp == 0.0:
                return None
            tot_w = _wsum(node.value)
            if vectorized:
                tcnt = np.bincount(y_enc[idx], minlength=K)
                res = None
                if ps.bin_cols.size:
                    res = _best_split_binary(ps, y_enc, class_w, idx,
                                             tcnt, parent_imp, tot_w)
                if track_sorted:
                    res = _merge_candidates(res, _best_split_sorted(
                        ps, y_enc, class_w, sorted_rows[node.node_id],
                        tcnt, parent_imp, tot_w))
            else:
                res = _best_split_loop(ps, y_enc, class_w, idx,
                                       parent_imp, tot_w)
            # Zero-gain splits are allowed (CART/sklearn semantics):
            # XOR-style labels need a gainless first split to become
            # separable; max_leaf_nodes bounds growth.
            cand = None
            if res is not None and res[0] >= -1e-12:
                gain, f, thr = res
                went = ps.X[idx, f] <= thr
                li, ri = idx[went], idx[~went]
                lv = class_w * np.bincount(y_enc[li], minlength=K)
                rv = class_w * np.bincount(y_enc[ri], minlength=K)
                cand = _Candidate(gain, f, thr, li, ri, lv, rv)
            if key is not None:
                split_cache[key] = cand
            return cand

        # Best-first growth: split the frontier leaf with the largest
        # impurity-decrease until max_leaf_nodes is reached.
        heap: list[tuple[float, int, TreeNode, _Candidate]] = []

        def push(node: TreeNode) -> None:
            if self.max_depth is not None and node.depth >= self.max_depth:
                sorted_rows.pop(node.node_id, None)
                return
            cand = best_split(node)
            if cand is None:
                sorted_rows.pop(node.node_id, None)
                return
            heapq.heappush(heap, (-cand.gain, node.node_id, node, cand))

        push(self.root)
        n_leaves = 1
        while heap and n_leaves < self.max_leaf_nodes:
            _, _, node, cand = heapq.heappop(heap)
            node.feature = cand.feature
            node.threshold = cand.threshold
            node.left = TreeNode(next(ids), node.depth + 1, cand.left_idx,
                                 cand.left_value, len(cand.left_idx))
            node.right = TreeNode(next(ids), node.depth + 1, cand.right_idx,
                                  cand.right_value, len(cand.right_idx))
            n_leaves += 1
            if track_sorted:
                lno, rno = _partition_sorted(
                    sorted_rows.pop(node.node_id), cand.left_idx, n)
                sorted_rows[node.left.node_id] = lno
                sorted_rows[node.right.node_id] = rno
            push(node.left)
            push(node.right)
        sorted_rows.clear()
        self._flat = None
        return self

    # -- inference ----------------------------------------------------------
    def _leaf(self, x: np.ndarray) -> TreeNode:
        node = self.root
        assert node is not None, "tree not fitted"
        while not node.is_leaf:
            node = node.left if x[node.feature] <= node.threshold \
                else node.right
        return node

    def _flatten(self) -> tuple[np.ndarray, ...]:
        if self._flat is None:
            assert self.root is not None, "tree not fitted"
            self._flat = _flatten(self.root,
                                  lambda nd: float(nd.majority_class()))
        return self._flat

    def predict(self, X: np.ndarray) -> np.ndarray:
        """Class label per row — one vectorized descent for the batch."""
        X = np.asarray(X, dtype=np.float64)
        flat = self._flatten()
        slots = _descend(flat, X)
        return self.classes_[flat[4][slots].astype(np.int64)]

    def training_error(self, X: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(X) != np.asarray(y)))

    # -- structure ----------------------------------------------------------
    def leaves(self) -> list[TreeNode]:
        out: list[TreeNode] = []

        def walk(node: TreeNode) -> None:
            if node.is_leaf:
                out.append(node)
            else:
                walk(node.left)
                walk(node.right)

        if self.root is not None:
            walk(self.root)
        return out

    def depth(self) -> int:
        def d(node: TreeNode) -> int:
            if node.is_leaf:
                return node.depth
            return max(d(node.left), d(node.right))
        return d(self.root) if self.root is not None else 0

    def n_leaves(self) -> int:
        return len(self.leaves())

    def paths(self) -> list[tuple[list[tuple[int, float, bool]], TreeNode]]:
        """All (path, leaf) pairs; path = [(feature, threshold, went_right)]."""
        out = []

        def walk(node: TreeNode, path):
            if node.is_leaf:
                out.append((list(path), node))
                return
            walk(node.left, path + [(node.feature, node.threshold, False)])
            walk(node.right, path + [(node.feature, node.threshold, True)])

        if self.root is not None:
            walk(self.root, [])
        return out


# -- regression trees (boosting base learner) --------------------------------

@dataclasses.dataclass
class RegressionNode:
    node_id: int
    depth: int
    indices: np.ndarray
    mean: float
    sse: float
    n_samples: int
    feature: int | None = None
    threshold: float = 0.5
    left: "RegressionNode | None" = None
    right: "RegressionNode | None" = None

    @property
    def is_leaf(self) -> bool:
        return self.feature is None


@dataclasses.dataclass
class _RegCandidate:
    gain: float
    feature: int
    threshold: float
    left_idx: np.ndarray
    right_idx: np.ndarray


class RegressionTree:
    """Least-squares CART on the same vectorized split kernels.

    Best-first growth by SSE reduction under ``max_leaf_nodes`` /
    ``max_depth``; leaf prediction is the mean target. The base
    learner of :class:`repro.rules.boost.GradientBoostedSurrogate` —
    every boosting round refits on new residuals but shares one
    :class:`Presort` (the feature matrix never changes).
    """

    def __init__(self, max_leaf_nodes: int = 8,
                 max_depth: int | None = None, min_gain: float = 1e-12):
        if max_leaf_nodes < 2:
            raise ValueError("max_leaf_nodes must be >= 2")
        self.max_leaf_nodes = max_leaf_nodes
        self.max_depth = max_depth
        self.min_gain = min_gain
        self.root: RegressionNode | None = None
        self._flat: tuple[np.ndarray, ...] | None = None

    def fit(self, X: np.ndarray, y: np.ndarray,
            presort: Presort | None = None) -> "RegressionTree":
        ps = _check_presort(presort, X)
        y = np.asarray(y, dtype=np.float64)
        if len(y) != ps.n:
            raise ValueError(f"X has {ps.n} rows but y has {len(y)}")
        n = ps.n
        track_sorted = ps.nb_cols.size > 0

        def stats(idx: np.ndarray) -> tuple[float, float, float]:
            yi = y[idx]
            s = float(yi.sum())
            ss = float((yi * yi).sum())
            return s, ss, max(0.0, ss - s * s / max(1, len(idx)))

        ids = itertools.count()
        all_idx = np.arange(n)
        s0, ss0, sse0 = stats(all_idx)
        self.root = RegressionNode(next(ids), 0, all_idx,
                                   s0 / max(1, n), sse0, n)
        sorted_rows: dict[int, np.ndarray] = {}
        if track_sorted:
            sorted_rows[self.root.node_id] = ps.order_nb

        def best_split(node: RegressionNode) -> _RegCandidate | None:
            idx = node.indices
            if len(idx) < 2 or node.sse <= self.min_gain:
                return None
            s, ss, sse = stats(idx)
            res = None
            if ps.bin_cols.size:
                res = _best_split_reg_binary(ps, y, idx, sse, s, ss)
            if track_sorted:
                res = _merge_candidates(res, _best_split_reg_sorted(
                    ps, y, sorted_rows[node.node_id], sse, s, ss))
            if res is None or res[0] <= self.min_gain:
                return None
            gain, f, thr = res
            went = ps.X[idx, f] <= thr
            return _RegCandidate(gain, f, thr, idx[went], idx[~went])

        heap: list[tuple[float, int, RegressionNode, _RegCandidate]] = []

        def push(node: RegressionNode) -> None:
            if self.max_depth is not None and node.depth >= self.max_depth:
                sorted_rows.pop(node.node_id, None)
                return
            cand = best_split(node)
            if cand is None:
                sorted_rows.pop(node.node_id, None)
                return
            heapq.heappush(heap, (-cand.gain, node.node_id, node, cand))

        push(self.root)
        n_leaves = 1
        while heap and n_leaves < self.max_leaf_nodes:
            _, _, node, cand = heapq.heappop(heap)
            node.feature = cand.feature
            node.threshold = cand.threshold
            for attr, ci in (("left", cand.left_idx),
                             ("right", cand.right_idx)):
                s, ss, sse = stats(ci)
                setattr(node, attr,
                        RegressionNode(next(ids), node.depth + 1, ci,
                                       s / len(ci), sse, len(ci)))
            n_leaves += 1
            if track_sorted:
                lno, rno = _partition_sorted(
                    sorted_rows.pop(node.node_id), cand.left_idx, n)
                sorted_rows[node.left.node_id] = lno
                sorted_rows[node.right.node_id] = rno
            push(node.left)
            push(node.right)
        sorted_rows.clear()
        self._flat = None
        return self

    def _flatten(self) -> tuple[np.ndarray, ...]:
        if self._flat is None:
            assert self.root is not None, "tree not fitted"
            self._flat = _flatten(self.root, lambda nd: nd.mean)
        return self._flat

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        flat = self._flatten()
        return flat[4][_descend(flat, X)]

    def n_leaves(self) -> int:
        def count(nd: RegressionNode) -> int:
            if nd.is_leaf:
                return 1
            return count(nd.left) + count(nd.right)
        return count(self.root) if self.root is not None else 0

    def depth(self) -> int:
        def d(nd: RegressionNode) -> int:
            if nd.is_leaf:
                return nd.depth
            return max(d(nd.left), d(nd.right))
        return d(self.root) if self.root is not None else 0


# -- the paper's Algorithm 1 -------------------------------------------------

@dataclasses.dataclass
class TreeSearchTrace:
    max_leaf_nodes: list[float]
    errors: list[float]
    depths: list[int]


def algorithm1(X: np.ndarray, y: np.ndarray,
               initial_leaves: int | None = None,
               trace: TreeSearchTrace | None = None,
               presort: Presort | None = None,
               splitter: str = "vectorized") -> DecisionTree:
    """Paper Algorithm 1: grow max_leaf_nodes until error stops shrinking.

    ``train(mln)`` fits a tree with max_leaf_nodes=mln and
    max_depth=mln-1. Starting leaf count = number of classes (the paper's
    listing initialises with 2; we use max(2, n_classes) per §IV-C text).

    Warm start: the per-feature sort/arity analysis (:class:`Presort`)
    is computed once and reused by every trial of the sweep — the
    feature matrix is the same; only ``max_leaf_nodes`` moves — and
    the trials share a split cache, so a re-trial only scores the
    frontier nodes its predecessors never reached. Pass ``presort`` to
    share the analysis even further (e.g. with a boosted surrogate on
    the same matrix).
    """
    ps = _check_presort(presort, X)
    n_classes = len(np.unique(y))
    mln = initial_leaves if initial_leaves is not None \
        else max(2, n_classes)
    split_cache: dict = {}

    def train(k: int) -> tuple[float, DecisionTree]:
        t = DecisionTree(max_leaf_nodes=k, max_depth=k - 1,
                         splitter=splitter).fit(ps.X, y, presort=ps,
                                                split_cache=split_cache)
        e = t.training_error(ps.X, y)
        if trace is not None:
            trace.max_leaf_nodes.append(k)
            trace.errors.append(e)
            trace.depths.append(t.depth())
        return e, t

    err, clf = train(mln)
    improved = True
    while improved and err > 0.0:
        improved = False
        for i in range(1, 6):
            cur, nclf = train(mln + i)
            if cur < err:
                err, clf, mln = cur, nclf, mln + i
                improved = True
                break
    return clf


# -- out-of-core histogram training (blockwise CART) -------------------------

class ClassCountHistogram:
    """Exact per-feature x per-class integer count histogram.

    The foldable sufficient statistic of a CART node: for every
    feature, how many rows of each class sit at each of the feature's
    finitely many values. Binary features are 0/1 indicators and
    multi-valued features have finite arity, so the counts are exact
    ``int64`` integers — no sketching, no approximation — which is what
    lets the histogram-trained tree reproduce the in-memory splitter
    bit for bit.

    ``values[j]`` is feature ``j``'s strictly increasing value grid;
    all features' bins live concatenated in one ``(total_bins,
    n_classes)`` count matrix (``offsets[j]:offsets[j+1]`` is feature
    ``j``'s segment), so ``add`` folds a whole block with a single
    ``np.bincount`` and ``subtract``/``merge`` are plain array
    arithmetic. ``merge`` is associative and commutative (grids union,
    counts add), so histograms folded on sharded hosts combine in any
    order — mirroring the engine's sharded-miss design.
    """

    def __init__(self, values: list[np.ndarray], n_classes: int):
        if n_classes < 1:
            raise ValueError("n_classes must be >= 1")
        self.values = [np.ascontiguousarray(np.asarray(v, dtype=np.float64)
                                            .ravel()) for v in values]
        for j, v in enumerate(self.values):
            if v.size == 0:
                raise ValueError(f"feature {j} has an empty value grid")
            if v.size > 1 and not np.all(v[1:] > v[:-1]):
                raise ValueError(
                    f"feature {j} grid must be strictly increasing")
        sizes = np.array([v.size for v in self.values], dtype=np.int64)
        self.offsets = np.zeros(sizes.size + 1, dtype=np.int64)
        np.cumsum(sizes, out=self.offsets[1:])
        self.n_classes = int(n_classes)
        self.counts = np.zeros((int(self.offsets[-1]), self.n_classes),
                               dtype=np.int64)
        # Arity-2 features bin with one vectorized comparison against
        # the upper grid value; everything else takes a per-feature
        # searchsorted.
        self._bin2 = np.flatnonzero(sizes == 2)
        self._multi = np.flatnonzero(sizes != 2)
        if self._bin2.size:
            self._lo2 = np.array([self.values[j][0] for j in self._bin2])
            self._hi2 = np.array([self.values[j][1] for j in self._bin2])
        else:
            self._lo2 = self._hi2 = np.zeros(0, dtype=np.float64)

    @property
    def n_features(self) -> int:
        return len(self.values)

    def add(self, X: np.ndarray, y_enc: np.ndarray) -> "ClassCountHistogram":
        """Fold one ``(rows, n_features)`` block of encoded labels."""
        X = np.asarray(X)
        if X.ndim != 2 or X.shape[1] != self.n_features:
            raise ValueError(
                f"expected (rows, {self.n_features}) block, got {X.shape}")
        m = X.shape[0]
        if m == 0:
            return self
        y_enc = np.asarray(y_enc)
        if y_enc.shape != (m,):
            raise ValueError(
                f"block has {m} rows but y_enc has shape {y_enc.shape}")
        K = self.n_classes
        bins = np.empty((m, self.n_features), dtype=np.int64)
        if self._bin2.size:
            Xb = np.asarray(X[:, self._bin2], dtype=np.float64)
            is_hi = Xb == self._hi2[None, :]
            if not (is_hi | (Xb == self._lo2[None, :])).all():
                raise ValueError("value outside a binary feature's grid")
            bins[:, self._bin2] = is_hi
        for j in self._multi:
            v = self.values[j]
            col = np.asarray(X[:, j], dtype=np.float64)
            b = np.minimum(np.searchsorted(v, col), v.size - 1)
            if not np.array_equal(v[b], col):
                raise ValueError(
                    f"value outside feature {int(j)}'s grid")
            bins[:, j] = b
        flat = (bins + self.offsets[:-1][None, :]) * K + y_enc[:, None]
        self.counts += np.bincount(
            flat.ravel(), minlength=self.counts.size
        ).reshape(self.counts.shape)
        return self

    def class_counts(self) -> np.ndarray:
        """Node class totals (feature 0's bins; every feature agrees)."""
        return self.counts[self.offsets[0]:self.offsets[1]].sum(axis=0)

    @property
    def n(self) -> int:
        return int(self.class_counts().sum())

    def _check_shape(self, other: "ClassCountHistogram") -> None:
        if not isinstance(other, ClassCountHistogram):
            raise TypeError(f"expected ClassCountHistogram, got "
                            f"{type(other).__name__}")
        if other.n_classes != self.n_classes:
            raise ValueError("class counts disagree on n_classes")
        if other.n_features != self.n_features:
            raise ValueError("class counts disagree on n_features")

    def _same_grids(self, other: "ClassCountHistogram") -> bool:
        return all(np.array_equal(a, b)
                   for a, b in zip(self.values, other.values))

    def subtract(self, other: "ClassCountHistogram") -> "ClassCountHistogram":
        """``self - other`` on identical grids — the sibling trick
        ``right_child = parent - left_child`` that halves per-level
        scan work during growth. Returns a new histogram."""
        self._check_shape(other)
        if not self._same_grids(other):
            raise ValueError("subtract requires identical value grids")
        out = ClassCountHistogram(self.values, self.n_classes)
        np.subtract(self.counts, other.counts, out=out.counts)
        if np.any(out.counts < 0):
            raise ValueError("subtrahend is not a sub-histogram")
        return out

    def merge(self, other: "ClassCountHistogram") -> "ClassCountHistogram":
        """Exact union of two disjoint corpora's histograms.

        Grids union (``np.union1d`` is exact on floats), counts land at
        their value's position in the union — associative, commutative,
        and equal to single-stream ``add`` of both corpora. Returns a
        new histogram; neither input is touched.
        """
        self._check_shape(other)
        if self._same_grids(other):
            out = ClassCountHistogram(self.values, self.n_classes)
            np.add(self.counts, other.counts, out=out.counts)
            return out
        grids = [np.union1d(a, b)
                 for a, b in zip(self.values, other.values)]
        out = ClassCountHistogram(grids, self.n_classes)
        for src in (self, other):
            for j in range(self.n_features):
                pos = out.offsets[j] + np.searchsorted(out.values[j],
                                                       src.values[j])
                out.counts[pos] += src.counts[src.offsets[j]:
                                              src.offsets[j + 1]]
        return out


def _hist_best_split(hist: ClassCountHistogram, bin_f: np.ndarray,
                     nb_f: np.ndarray, class_w: np.ndarray,
                     tcnt: np.ndarray, m: int, parent_imp: float,
                     tot_w: float) -> tuple[float, int, float] | None:
    """Best ``(gain, feature, threshold)`` of a node from its histogram.

    Bit-identical to the in-memory vectorized splitter on an equal
    node: the arity-2 candidates reproduce ``_best_split_binary``'s
    count math (right histogram = upper-value bin counts) and
    first-argmax tie-break over ascending feature index; multi-valued
    candidates reproduce ``_best_split_sorted``'s boundary enumeration
    — thresholds between consecutive *present* grid values, left
    counts as cumulative per-bin class sums — with its strictly-greater
    cross-feature merge; and the two paths resolve ties through the
    same :func:`_merge_candidates`.
    """
    K = len(class_w)
    best: tuple[float, int, float] | None = None
    if bin_f.size:
        rcnt = hist.counts[hist.offsets[bin_f] + 1]      # upper-value bin
        nright = rcnt.sum(axis=1)
        valid = (nright > 0) & (nright < m)
        if valid.any():
            left_counts = [tcnt[k] - rcnt[:, k] for k in range(K)]
            right_counts = [rcnt[:, k] for k in range(K)]
            with np.errstate(invalid="ignore", divide="ignore"):
                gains = _gini_gains(left_counts, right_counts, class_w,
                                    parent_imp, tot_w)
            gains[~valid] = -np.inf
            i = int(np.argmax(gains))        # first max: lowest feature
            v = hist.values[int(bin_f[i])]
            best = (float(gains[i]), int(bin_f[i]),
                    float((v[0] + v[1]) / 2.0))
    snd: tuple[float, int, float] | None = None
    for f in nb_f:
        seg = hist.counts[hist.offsets[f]:hist.offsets[f + 1]]
        pres = np.flatnonzero(seg.sum(axis=1) > 0)
        if pres.size < 2:
            continue
        lc = np.cumsum(seg[pres[:-1]], axis=0)    # left of boundary i
        left_counts = [lc[:, k] for k in range(K)]
        right_counts = [tcnt[k] - lc[:, k] for k in range(K)]
        gains = _gini_gains(left_counts, right_counts, class_w,
                            parent_imp, tot_w)
        i = int(np.argmax(gains))
        g = float(gains[i])
        if snd is None or g > snd[0]:    # strict: earlier feature wins
            v = hist.values[int(f)][pres]
            snd = (g, int(f), float((v[i] + v[i + 1]) / 2.0))
    return _merge_candidates(best, snd)


class _HistNode:
    """Node of the level-order histogram expansion (grower internal)."""

    __slots__ = ("depth", "counts", "n_samples", "hist", "cand",
                 "cand_done", "feature", "threshold", "left", "right")

    def __init__(self, depth: int, counts: np.ndarray | None = None,
                 n_samples: int = 0,
                 hist: ClassCountHistogram | None = None):
        self.depth = depth
        self.counts = counts
        self.n_samples = n_samples
        self.hist = hist
        self.cand: tuple[float, int, float] | None = None
        self.cand_done = False
        self.feature: int | None = None
        self.threshold = 0.5
        self.left: "_HistNode | None" = None
        self.right: "_HistNode | None" = None


class HistogramGrower:
    """Out-of-core CART growth: one blockwise pass per tree level.

    ``blocks`` is a callable returning an iterable of ``(rows,
    n_features)`` blocks (or a re-iterable sequence of such blocks) —
    typically a :class:`repro.driver.sinks.HistogramSink` featurizing
    stored compact encodings on the fly. The grower never materializes
    the ``(rows x features)`` matrix: it holds one
    :class:`ClassCountHistogram` per *frontier* node (O(features x
    bins x frontier) memory) and expands the candidate tree level by
    level — each level is a single pass over the blocks, routing rows
    with the vectorized :func:`_descend` and folding only the
    **left**-child histograms; right children come free from the
    subtraction trick ``right = parent - left``.

    :meth:`fit` then replays the in-memory best-first heap over the
    pre-expanded candidates, producing a genuine :class:`DecisionTree`
    that is bit-identical (splits, thresholds, tie-breaks, ``predict``)
    to ``DecisionTree(...).fit(X, y)`` on the materialized matrix —
    locked by tests/test_histogram_trees.py. A node popped at depth
    ``D`` needs ``D + 1`` of the at most ``max_leaf_nodes - 1`` pops,
    so candidates are only ever needed down to depth
    ``min(max_leaf_nodes - 2, max_depth - 1)``; repeated ``fit`` calls
    (the Algorithm-1 sweep) reuse every level already expanded, the
    histogram path's analogue of the in-memory ``split_cache``.
    """

    def __init__(self, blocks, y: np.ndarray,
                 values: list[np.ndarray] | None = None):
        self._blocks = blocks if callable(blocks) else (lambda: blocks)
        self.y = np.asarray(y)
        if self.y.ndim != 1:
            raise ValueError(f"y must be 1-D, got shape {self.y.shape}")
        self.n = int(self.y.shape[0])
        if self.n == 0:
            raise ValueError("y is empty")
        self.classes_, y_enc = np.unique(self.y, return_inverse=True)
        self.y_enc = y_enc.astype(np.int32)
        self.n_classes = K = len(self.classes_)
        counts = np.bincount(self.y_enc, minlength=K)
        # class_weight='balanced', exactly as DecisionTree.fit
        self.class_w = np.where(counts > 0,
                                self.n / (K * np.maximum(counts, 1)), 0.0)
        if values is None:
            values = self._discover_values()
        self.values = [np.asarray(v, dtype=np.float64) for v in values]
        self.n_features = len(self.values)
        arity = np.array([v.size for v in self.values], dtype=np.int64)
        self.bin_f = np.flatnonzero(arity == 2)
        self.nb_f = np.flatnonzero(arity >= 3)
        root_hist = ClassCountHistogram(self.values, K)
        rows = 0
        for X, lo in self._iter():
            root_hist.add(X, self.y_enc[lo:lo + X.shape[0]])
            rows += X.shape[0]
        if rows != self.n:
            raise ValueError(
                f"blocks yielded {rows} rows but y has {self.n}")
        self.root = _HistNode(0, counts.astype(np.int64), self.n,
                              root_hist)
        self._frontier: list[_HistNode] = [self.root]
        self._cand_depth = -1          # deepest level with candidates
        self._exhausted = False

    def _iter(self):
        lo = 0
        for X in self._blocks():
            X = np.asarray(X)
            if X.ndim != 2:
                raise ValueError(f"blocks must be 2-D, got {X.shape}")
            if lo + X.shape[0] > self.n:
                raise ValueError(
                    f"blocks yielded more than {self.n} rows")
            yield X, lo
            lo += X.shape[0]

    def _discover_values(self) -> list[np.ndarray]:
        vals: list[np.ndarray] | None = None
        for X, _ in self._iter():
            cols = [np.unique(np.asarray(X[:, j], dtype=np.float64))
                    for j in range(X.shape[1])]
            if vals is None:
                vals = cols
            elif len(cols) != len(vals):
                raise ValueError("blocks disagree on feature count")
            else:
                vals = [np.union1d(a, b) for a, b in zip(vals, cols)]
        if vals is None:
            raise ValueError("blocks yielded no rows")
        return vals

    # -- level-order expansion -------------------------------------------
    def _candidate(self, nd: _HistNode) -> tuple[float, int, float] | None:
        if nd.n_samples < 2:
            return None
        parent_imp = _gini(self.class_w * nd.counts)
        if parent_imp == 0.0:
            return None
        tot_w = _wsum(self.class_w * nd.counts)
        res = _hist_best_split(nd.hist, self.bin_f, self.nb_f,
                               self.class_w, nd.counts, nd.n_samples,
                               parent_imp, tot_w)
        # Zero-gain splits are allowed (CART/sklearn semantics), same
        # tolerance as the in-memory grower.
        if res is not None and res[0] >= -1e-12:
            return res
        return None

    def _flatten_partial(self) -> tuple[tuple[np.ndarray, ...], dict]:
        """Flatten the expansion tree so far; unexpanded nodes self-loop."""
        nodes: list[_HistNode] = []

        def walk(nd: _HistNode) -> None:
            nodes.append(nd)
            if nd.left is not None:
                walk(nd.left)
                walk(nd.right)

        walk(self.root)
        slot = {id(nd): i for i, nd in enumerate(nodes)}
        size = len(nodes)
        feat = np.full(size, -1, dtype=np.int64)
        thr = np.zeros(size, dtype=np.float64)
        left = np.arange(size, dtype=np.int64)
        right = np.arange(size, dtype=np.int64)
        for i, nd in enumerate(nodes):
            if nd.left is not None:
                feat[i] = nd.feature
                thr[i] = nd.threshold
                left[i] = slot[id(nd.left)]
                right[i] = slot[id(nd.right)]
        return (feat, thr, left, right, np.zeros(size)), slot

    def _expand_level(self) -> None:
        level = self._frontier
        for nd in level:
            if not nd.cand_done:
                nd.cand = self._candidate(nd)
                nd.cand_done = True
        self._cand_depth += 1
        splitting = [nd for nd in level if nd.cand is not None]
        if not splitting:
            for nd in level:
                nd.hist = None
            self._frontier = []
            self._exhausted = True
            return
        K = self.n_classes
        for nd in splitting:
            _, f, thr = nd.cand
            nd.feature, nd.threshold = int(f), float(thr)
            nd.left = _HistNode(nd.depth + 1,
                                hist=ClassCountHistogram(self.values, K))
            nd.right = _HistNode(nd.depth + 1)
        flat, slot = self._flatten_partial()
        left_of = {slot[id(nd.left)]: nd for nd in splitting}
        # One routing pass over the corpus fills every new left child's
        # histogram; rows routed right (or to permanent leaves) are
        # skipped — their counts come from the subtraction trick below.
        # Routing with the *actual* split predicate (X <= thr via
        # _descend) rather than histogram-boundary arithmetic keeps the
        # partition identical to the in-memory ``ps.X[idx, f] <= thr``
        # even when a midpoint rounds onto its upper grid value.
        for X, lo in self._iter():
            where = _descend(flat, np.asarray(X, dtype=np.float64))
            yb = self.y_enc[lo:lo + X.shape[0]]
            for s, nd in left_of.items():
                mask = where == s
                if mask.any():
                    nd.left.hist.add(X[mask], yb[mask])
        for nd in splitting:
            lc = nd.left.hist.class_counts()
            nd.left.counts = lc
            nd.left.n_samples = int(lc.sum())
            nd.right.hist = nd.hist.subtract(nd.left.hist)
            nd.right.counts = nd.counts - lc
            nd.right.n_samples = nd.n_samples - nd.left.n_samples
            nd.hist = None                 # parent histogram retired
        for nd in level:
            if nd.cand is None:
                nd.hist = None             # permanent leaf
        self._frontier = [c for nd in splitting
                          for c in (nd.left, nd.right)]

    def _ensure(self, cand_depth: int) -> None:
        while self._cand_depth < cand_depth and not self._exhausted:
            self._expand_level()

    # -- producing trees --------------------------------------------------
    def fit(self, max_leaf_nodes: int,
            max_depth: int | None = None) -> DecisionTree:
        """Grow a :class:`DecisionTree` from the expanded candidates.

        Replays the in-memory best-first heap — ``(-gain, node_id)``
        ordering, pop-time child ids (left before right), depth gate
        before candidate — over the histogram-scored splits.
        """
        tree = DecisionTree(max_leaf_nodes, max_depth)
        tree.splitter = "histogram"
        tree.classes_ = self.classes_
        tree.n_classes = self.n_classes
        cand_cap = max_leaf_nodes - 2
        if max_depth is not None:
            cand_cap = min(cand_cap, max_depth - 1)
        self._ensure(cand_cap)
        ids = itertools.count()
        empty = np.zeros(0, dtype=np.int64)   # rows are never held

        def mk(hn: _HistNode) -> TreeNode:
            return TreeNode(next(ids), hn.depth, empty,
                            self.class_w * hn.counts, hn.n_samples)

        tree.root = mk(self.root)
        heap: list[tuple[float, int, TreeNode, _HistNode]] = []

        def push(tn: TreeNode, hn: _HistNode) -> None:
            if max_depth is not None and tn.depth >= max_depth:
                return
            # A node popped at depth D needs D+1 pops of the at most
            # max_leaf_nodes-1 total, so anything past cand_cap can
            # never be popped — safe to leave off the heap even though
            # the in-memory grower pushes it.
            if hn.cand is None:
                return
            heapq.heappush(heap, (-hn.cand[0], tn.node_id, tn, hn))

        push(tree.root, self.root)
        n_leaves = 1
        while heap and n_leaves < max_leaf_nodes:
            _, _, tn, hn = heapq.heappop(heap)
            tn.feature = hn.feature
            tn.threshold = hn.threshold
            tn.left = mk(hn.left)
            tn.right = mk(hn.right)
            n_leaves += 1
            push(tn.left, hn.left)
            push(tn.right, hn.right)
        tree._flat = None
        return tree

    def training_error(self, tree: DecisionTree) -> float:
        """Blockwise misclassification rate — equals
        ``tree.training_error(X, y)`` on the materialized matrix."""
        flat = tree._flatten()
        wrong = 0
        for X, lo in self._iter():
            slots = _descend(flat, np.asarray(X, dtype=np.float64))
            pred = tree.classes_[flat[4][slots].astype(np.int64)]
            wrong += int(np.count_nonzero(
                pred != self.y[lo:lo + X.shape[0]]))
        return wrong / self.n


def fit_from_histograms(blocks, y: np.ndarray, max_leaf_nodes: int,
                        max_depth: int | None = None,
                        values: list[np.ndarray] | None = None,
                        grower: HistogramGrower | None = None
                        ) -> DecisionTree:
    """One histogram-trained CART fit; see :class:`HistogramGrower`.

    ``blocks`` streams the feature matrix in row blocks (a callable
    returning an iterable, or a re-iterable sequence); ``values``
    optionally pins the per-feature value grids (skipping the
    discovery pass — sinks know their grids). Pass an existing
    ``grower`` to reuse its expanded levels across fits.
    """
    if grower is None:
        grower = HistogramGrower(blocks, y, values=values)
    return grower.fit(max_leaf_nodes, max_depth)


def algorithm1_from_histograms(blocks, y: np.ndarray,
                               initial_leaves: int | None = None,
                               trace: TreeSearchTrace | None = None,
                               values: list[np.ndarray] | None = None,
                               grower: HistogramGrower | None = None
                               ) -> DecisionTree:
    """Paper Algorithm 1 through the out-of-core histogram path.

    Identical trial schedule, stopping rule, and trees to
    :func:`algorithm1` (locked by test): the shared grower's expanded
    levels play the role of the in-memory sweep's presort +
    split cache, so each re-trial only pays passes for the levels it
    newly reaches.
    """
    if grower is None:
        grower = HistogramGrower(blocks, y, values=values)
    mln = initial_leaves if initial_leaves is not None \
        else max(2, grower.n_classes)

    def train(k: int) -> tuple[float, DecisionTree]:
        t = grower.fit(max_leaf_nodes=k, max_depth=k - 1)
        e = grower.training_error(t)
        if trace is not None:
            trace.max_leaf_nodes.append(k)
            trace.errors.append(e)
            trace.depths.append(t.depth())
        return e, t

    err, clf = train(mln)
    improved = True
    while improved and err > 0.0:
        improved = False
        for i in range(1, 6):
            cur, nclf = train(mln + i)
            if cur < err:
                err, clf, mln = cur, nclf, mln + i
                improved = True
                break
    return clf
