"""Persistent content-addressed evaluation store: measurements that
outlive the process.

The paper's whole cost is measurement — MCTS explores an enormous
schedule space and every node expansion pays a simulation or a
wall-clock run, so the memo cache *is* the budget (§III,
``sim_budget``). :class:`~repro.engine.base.EvaluatorBase` already
keys everything on canonical ``(B, 2, N)`` row bytes; this module adds
the one missing layer — an on-disk store keyed by
``(fingerprint, canonical row bytes) -> base time`` — so every search
(CI runs, benchmark sweeps, many users tuning the same graph) starts
warm instead of re-simulating from zero.

Contracts:

* **Content-addressed.** The fingerprint
  (:func:`store_fingerprint`) hashes the graph's ops and edges, the
  machine/durations table, and the backend's objective identity, so
  results from different graphs, machines, or objectives can never
  collide — one store file safely serves many searches.
* **Noiseless base times only.** Measurement noise stays parent-side,
  seeded per ``(canonical key, draw index)``
  (see :mod:`repro.engine.base`), so the store holds the underlying
  base time and noisy searches are bit-reproducible warm or cold.
* **Crash-safe, append-only.** Records are length-prefixed and
  CRC-checksummed; writers only ever append whole records with a
  single ``O_APPEND`` write, so concurrent writers interleave at
  record granularity and a crash can corrupt at most the file tail.
  :meth:`EvalStore.open`-time parsing truncates a corrupt tail and
  keeps every intact record.

File format (little-endian)::

    magic:  b"REPRO-EVALSTORE-v1\\n"
    record: u32 payload_len | payload | u32 crc32(payload)
    payload: fingerprint (16 bytes) | canonical row bytes | f64 time

Duplicate keys may appear in the file (concurrent writers racing the
same miss); the first record wins on load — all writers of a given
``(fingerprint, key)`` measured the same deterministic quantity.
"""
from __future__ import annotations

import hashlib
import os
import struct
import time
import zlib
from typing import Iterable

from repro import obs
from repro.core.costmodel import Machine
from repro.core.dag import Graph

MAGIC = b"REPRO-EVALSTORE-v1\n"
FINGERPRINT_SIZE = 16
_LEN = struct.Struct("<I")
_TIME = struct.Struct("<d")
# payload = fingerprint + key (>= 1 encoded position = 8 bytes) + time
_MIN_PAYLOAD = FINGERPRINT_SIZE + _TIME.size


def store_fingerprint(graph: Graph, machine: Machine,
                      durations: dict[str, float],
                      objective: str) -> bytes:
    """16-byte content address of *what a base time means*.

    Hashes everything that determines the mapping
    ``canonical row bytes -> base time``: the graph's ops (all cost
    metadata — the canonical encoding only carries op *indices*, so op
    identity must come from here), its edge set, the machine constants,
    the resolved per-op duration table, and the backend's objective
    identity (``"analytic"`` for the bit-identical sim/vectorized/pool
    family — their results are interchangeable by construction, so they
    deliberately *share* a fingerprint and warm-start each other —
    vs ``"wallclock:..."`` for measured time). blake2b is stable
    across processes and ``PYTHONHASHSEED`` values.
    """
    h = hashlib.blake2b(digest_size=FINGERPRINT_SIZE)
    h.update(b"objective=" + objective.encode() + b"\n")
    h.update(repr(machine).encode() + b"\n")
    for name in sorted(graph.ops):
        op = graph.ops[name]
        h.update(repr((op.name, op.kind.value, op.flops, op.bytes_hbm,
                       op.comm_bytes, op.comm_role.value, op.duration,
                       durations.get(name))).encode())
    for u in sorted(graph.succs):
        for v in sorted(graph.succs[u]):
            h.update(f"edge {u}->{v}\n".encode())
    return h.digest()


class EvalStore:
    """Append-only on-disk memo of ``(fingerprint, key) -> base time``.

    Opening loads every intact record into memory (lookups are dict
    hits; the search hot path never touches the disk for reads) and
    truncates any corrupt tail left by a crashed writer. ``put_many``
    appends each batch with one ``write`` syscall on an ``O_APPEND``
    descriptor, so concurrent writers on a local filesystem interleave
    whole batches. Idempotent: keys already present are not re-written.
    """

    def __init__(self, path: "str | os.PathLike"):
        self.path = os.fspath(path)
        self._mem: dict[bytes, dict[bytes, float]] = {}
        self.n_records = 0
        self.n_truncated_bytes = 0
        # read/append accounting (surfaced by stats() and telemetry):
        self.n_bytes_read = 0          # file bytes parsed at open
        self.n_records_appended = 0    # records this handle wrote
        self.n_bytes_appended = 0      # bytes this handle wrote
        self.n_lookups = 0             # get() calls
        self.n_lookup_hits = 0         # get() calls that found a time
        self.lookup_seconds = 0.0      # wall inside get()
        self.append_seconds = 0.0      # wall inside put_many()
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fd: int | None = os.open(
            self.path, os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            with obs.span("store.open", path=self.path) as sp:
                self._load()
                sp.set(records=self.n_records,
                       truncated_bytes=self.n_truncated_bytes)
        except Exception:
            os.close(self._fd)
            self._fd = None
            raise
        if self.n_truncated_bytes:
            obs.event("store.truncated_tail", path=self.path,
                      bytes=self.n_truncated_bytes)
            obs.counter("store.truncated_tails").add(1)

    # -- load / recovery ---------------------------------------------------
    def _load(self) -> None:
        size = os.fstat(self._fd).st_size
        data = os.pread(self._fd, size, 0) if size else b""
        self.n_bytes_read = len(data)
        if not data:
            os.write(self._fd, MAGIC)
            return
        if not data.startswith(MAGIC):
            raise ValueError(
                f"{self.path!r} is not an evaluation store "
                f"(bad magic {data[:8]!r})")
        off = len(MAGIC)
        end_ok = off
        n = len(data)
        while off + _LEN.size <= n:
            (plen,) = _LEN.unpack_from(data, off)
            rec_end = off + _LEN.size + plen + _LEN.size
            if plen < _MIN_PAYLOAD or rec_end > n:
                break                      # truncated / nonsense tail
            payload = data[off + _LEN.size:off + _LEN.size + plen]
            (crc,) = _LEN.unpack_from(data, rec_end - _LEN.size)
            if zlib.crc32(payload) != crc:
                break                      # corrupt tail
            fp = payload[:FINGERPRINT_SIZE]
            key = payload[FINGERPRINT_SIZE:plen - _TIME.size]
            (t,) = _TIME.unpack_from(payload, plen - _TIME.size)
            self._mem.setdefault(fp, {}).setdefault(key, t)
            self.n_records += 1
            off = end_ok = rec_end
        if end_ok < n:
            self.n_truncated_bytes = n - end_ok
            os.ftruncate(self._fd, end_ok)

    # -- lookups -----------------------------------------------------------
    def get(self, fingerprint: bytes, key: bytes) -> float | None:
        """The stored base time, or ``None`` if never measured."""
        t0 = time.perf_counter()
        bucket = self._mem.get(fingerprint)
        out = None if bucket is None else bucket.get(key)
        self.lookup_seconds += time.perf_counter() - t0
        self.n_lookups += 1
        if out is not None:
            self.n_lookup_hits += 1
        return out

    def __len__(self) -> int:
        return sum(len(b) for b in self._mem.values())

    def __contains__(self, fp_key: tuple[bytes, bytes]) -> bool:
        fp, key = fp_key
        return key in self._mem.get(fp, ())

    def fingerprints(self) -> list[bytes]:
        return list(self._mem)

    def stats(self) -> dict:
        """Traffic + recovery meter for this handle.

        Load-side: ``records_loaded`` / ``bytes_read`` (parsed at
        open) and ``truncated_bytes`` (corrupt tail dropped, 0 on a
        clean file). Write-side: ``records_appended`` /
        ``bytes_appended`` by this handle. Lookup-side: ``lookups`` /
        ``lookup_hits`` — on a warm run these mirror the evaluator's
        ``store_hits`` meter one-for-one (each distinct uncached key is
        looked up exactly once; parity locked by tests/test_obs.py) —
        plus the accumulated ``lookup_seconds`` / ``append_seconds``
        walls.
        """
        return {
            "path": self.path,
            "entries": len(self),
            "fingerprints": len(self._mem),
            "records_loaded": self.n_records,
            "truncated_bytes": self.n_truncated_bytes,
            "bytes_read": self.n_bytes_read,
            "records_appended": self.n_records_appended,
            "bytes_appended": self.n_bytes_appended,
            "lookups": self.n_lookups,
            "lookup_hits": self.n_lookup_hits,
            "lookup_seconds": self.lookup_seconds,
            "append_seconds": self.append_seconds,
        }

    # -- writes ------------------------------------------------------------
    def put_many(self, fingerprint: bytes,
                 items: Iterable[tuple[bytes, float]]) -> int:
        """Append ``(key, base time)`` pairs; returns how many were new.

        Keys already present are skipped (content-addressed: the value
        is a pure function of the address). The whole batch goes out as
        one append so concurrent writers cannot interleave inside it.
        """
        if self._fd is None:
            raise ValueError(f"store {self.path!r} is closed")
        if len(fingerprint) != FINGERPRINT_SIZE:
            raise ValueError(
                f"fingerprint must be {FINGERPRINT_SIZE} bytes")
        t0 = time.perf_counter()
        bucket = self._mem.setdefault(fingerprint, {})
        buf = bytearray()
        n_new = 0
        for key, t in items:
            if key in bucket:
                continue
            t = float(t)
            bucket[key] = t
            payload = fingerprint + bytes(key) + _TIME.pack(t)
            buf += _LEN.pack(len(payload))
            buf += payload
            buf += _LEN.pack(zlib.crc32(payload))
            n_new += 1
        if buf:
            with obs.span("store.append", records=n_new,
                          bytes=len(buf)):
                os.write(self._fd, bytes(buf))
            self.n_records += n_new
            self.n_records_appended += n_new
            self.n_bytes_appended += len(buf)
        self.append_seconds += time.perf_counter() - t0
        return n_new

    def put(self, fingerprint: bytes, key: bytes, t: float) -> int:
        return self.put_many(fingerprint, [(key, t)])

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Close the file descriptor; idempotent. Reads keep working
        (the in-memory index survives); writes raise."""
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "EvalStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; context-manager close preferred
        try:
            self.close()
        except Exception:
            pass
