"""repro.obs: the telemetry subsystem and its pure-observer contract.

Locks the PR's acceptance criteria:

* ``run_search`` with an exporter-attached registry is byte-identical
  to a telemetry-off run — times, schedules, and cache counters — on
  every analytic backend (sim / vectorized / pool);
* the Perfetto/Chrome trace a run writes is schema-sane: valid JSON,
  monotone ``ts``, every ``"B"`` matched by an ``"E"`` (LIFO per tid);
* a warm store-backed run's telemetry shows **zero** ``engine.measure``
  spans, and ``EvalStore.stats()`` lookup meters agree one-for-one
  with the evaluator's ``store_hits``;
* ``TraceSink`` rounds carry their index, ``key_stream()`` keeps its
  flat back-compat shape, and the ``"telemetry"`` sink is registered;
* ``benchmarks/run.py``'s baseline comparator flags exactly the
  regressed rows.
"""
import json

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro import obs
from repro.driver import SINKS, TelemetrySink, TraceSink, make_sink
from repro.engine.base import EvalBatch
from repro.engine.store import MAGIC, EvalStore


# -- the core -----------------------------------------------------------------

def test_spans_counters_gauges_and_summary():
    tel = obs.Telemetry()
    with obs.use(tel):
        assert obs.enabled()
        with obs.span("outer", layer="driver") as sp:
            sp.set(n=3)
            with obs.span("inner"):
                pass
            with obs.span("inner"):
                pass
        obs.counter("hits").add(2)
        obs.counter("hits").add(3)
        obs.gauge("best").set(1.5)
        obs.event("marker", round=0)
    spans = tel.spans_by_name()
    assert spans["outer"]["count"] == 1
    assert spans["inner"]["count"] == 2
    assert spans["outer"]["total_s"] >= spans["inner"]["total_s"] >= 0
    assert tel.counters() == {"hits": 5.0}
    assert tel.gauges() == {"best": 1.5}
    text = tel.summary()
    for needle in ("outer", "inner", "hits", "best"):
        assert needle in text


def test_span_attrs_land_on_end_event():
    ex = obs.MemoryExporter()
    tel = obs.Telemetry(exporters=[ex])
    with obs.use(tel):
        with obs.span("work", n=4) as sp:
            sp.set(misses=1)             # discovered mid-span
    begin = next(e for e in ex.events if e["ph"] == "B")
    end = next(e for e in ex.events if e["ph"] == "E")
    assert begin["name"] == end["name"] == "work"
    assert end["args"] == {"n": 4, "misses": 1}
    assert end["ts"] >= begin["ts"]


def test_disabled_default_is_noop_singletons():
    assert obs.current() is obs.DISABLED
    assert not obs.enabled()
    sp = obs.span("anything", n=1)
    with sp as inner:
        inner.set(x=2)                   # all no-ops, nothing raised
    assert obs.span("other") is sp       # one shared singleton
    assert obs.counter("c") is obs.counter("d")
    obs.counter("c").add(5)
    obs.gauge("g").set(3.0)
    obs.event("e", k=1)
    assert obs.DISABLED.spans_by_name() == {}
    assert obs.DISABLED.counters() == {}


def test_use_restores_previous_registry():
    tel = obs.Telemetry()
    with obs.use(tel):
        assert obs.current() is tel
        with obs.use(None):              # explicit re-disable nests
            assert obs.current() is obs.DISABLED
        assert obs.current() is tel
    assert obs.current() is obs.DISABLED


def test_exception_inside_span_still_closes_it():
    tel = obs.Telemetry()
    with obs.use(tel):
        with pytest.raises(RuntimeError):
            with obs.span("fails"):
                raise RuntimeError("boom")
    assert tel.spans_by_name()["fails"]["count"] == 1


# -- pure observer: byte-identity with exporters attached ---------------------

@pytest.mark.parametrize("backend,kwargs", [
    ("sim", {}),
    ("vectorized", {}),
    ("pool", {"n_workers": 2, "min_shard": 1}),
])
def test_run_search_byte_identical_with_telemetry(backend, kwargs):
    g = C.spmv_dag()

    def search():
        return S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=40,
                            batch_size=8, backend=backend,
                            backend_kwargs=kwargs)

    plain = search()
    tel = obs.Telemetry(exporters=[obs.MemoryExporter()])
    with obs.use(tel):
        traced = search()

    assert traced.times == plain.times
    assert [s.items for s in traced.schedules] \
        == [s.items for s in plain.schedules]
    assert traced.n_proposed == plain.n_proposed
    assert traced.cache_hits == plain.cache_hits
    assert traced.cache_misses == plain.cache_misses
    # The registry saw the run; the plain result carries no digest.
    assert plain.telemetry is None
    assert traced.telemetry is not None and len(traced.telemetry) > 0
    spans = tel.spans_by_name()
    assert spans["driver.run"]["count"] == 1
    assert spans["driver.round"]["count"] == len(traced.telemetry)
    assert spans["engine.batch"]["count"] >= 1
    # Round digests account for every proposal and every miss.
    assert sum(r["n"] for r in traced.telemetry) == traced.n_proposed
    assert sum(r["misses"] for r in traced.telemetry) \
        == traced.cache_misses
    assert traced.telemetry[-1]["best"] == traced.best()[1]


# -- Perfetto trace schema ----------------------------------------------------

def test_perfetto_trace_schema(tmp_path):
    path = tmp_path / "trace.json"
    g = C.spmv_dag()
    tel = obs.Telemetry(exporters=[obs.PerfettoExporter(path)])
    with obs.use(tel):
        res = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=40,
                           batch_size=8, backend="vectorized")
    tel.close()

    with open(path) as f:                # valid JSON, Chrome shape
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    events = doc["traceEvents"]
    assert events and obs.load_trace(path) == events

    names = {e["name"] for e in events}
    assert {"driver.run", "driver.round", "driver.evaluate",
            "engine.batch"} <= names

    stacks: dict = {}
    last_ts = -1.0
    for e in events:
        assert {"name", "ph", "ts", "pid"} <= set(e)
        assert e["ts"] >= last_ts        # monotone emission order
        last_ts = e["ts"]
        if e["ph"] == "B":
            stacks.setdefault(e["tid"], []).append(e["name"])
        elif e["ph"] == "E":             # matched LIFO per thread
            assert stacks[e["tid"]].pop() == e["name"]
        else:
            assert e["ph"] in ("C", "i")
    assert all(not st for st in stacks.values())

    # driver.round B events carry their 0-based round index, in order.
    rounds = [e["args"]["round"] for e in events
              if e["name"] == "driver.round" and e["ph"] == "B"]
    assert rounds == list(range(len(res.telemetry)))


# -- warm runs: zero measure spans + store/evaluator meter parity -------------

def test_warm_run_zero_measure_spans_and_store_stats_parity(tmp_path):
    path = str(tmp_path / "eval.store")
    g = C.spmv_dag()

    def search(store):
        return S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=60,
                            batch_size=8, backend="vectorized",
                            store=store)

    with EvalStore(path) as st:
        cold = search(st)
        cold_stats = st.stats()
    assert cold.cache_misses > 0
    assert cold_stats["records_appended"] == cold.cache_misses
    assert cold_stats["bytes_appended"] > 0
    assert cold_stats["append_seconds"] >= 0.0

    tel = obs.Telemetry()
    with obs.use(tel), EvalStore(path) as st2:   # registry sees the open
        warm = search(st2)
        warm_stats = st2.stats()
    assert warm.times == cold.times
    assert warm.cache_misses == 0 and warm.store_hits > 0
    spans = tel.spans_by_name()
    assert spans.get("engine.measure", {}).get("count", 0) == 0
    assert spans["store.open"]["count"] == 1
    assert "store.append" not in spans           # nothing new to write
    # stats() parity: every store hit the evaluator metered is exactly
    # one successful lookup on the store handle.
    assert warm_stats["lookup_hits"] == warm.store_hits
    assert warm_stats["lookups"] >= warm_stats["lookup_hits"]
    assert warm_stats["records_appended"] == 0
    assert warm_stats["records_loaded"] == cold.cache_misses
    # The warm open reads back exactly what the cold run appended,
    # plus the file-format magic header.
    assert warm_stats["bytes_read"] \
        == cold_stats["bytes_appended"] + len(MAGIC)


def test_store_open_span_reports_truncated_tail(tmp_path):
    path = tmp_path / "eval.store"
    with EvalStore(path) as st:
        st.put_many(b"f" * 16, [(b"k1", 1.0)])
    with open(path, "ab") as f:
        f.write(b"\x01garbage-partial-record")
    ex = obs.MemoryExporter()
    tel = obs.Telemetry(exporters=[ex])
    with obs.use(tel):
        with EvalStore(path) as st2:
            assert len(st2) == 1
            assert st2.stats()["truncated_bytes"] > 0
    assert tel.counters()["store.truncated_tails"] == 1.0
    trunc = [e for e in ex.events
             if e["name"] == "store.truncated_tail" and e["ph"] == "i"]
    assert len(trunc) == 1 and trunc[0]["args"]["bytes"] > 0


# -- sinks --------------------------------------------------------------------

def _fake_batch(keys, times):
    g = C.spmv_dag()
    scheds = [None] * len(keys)          # TraceSink never touches them
    return EvalBatch(schedules=scheds, keys=list(keys),
                     times=np.asarray(times, dtype=np.float64))


def test_trace_sink_round_indices_and_key_stream_shapes():
    sink = TraceSink()
    sink.consume(_fake_batch([b"a", b"b"], [2.0, 1.0]),
                 np.array([True, True]))
    sink.consume(_fake_batch([b"c"], [3.0]), np.array([False]))
    assert [r["round"] for r in sink.rounds] == [0, 1]
    assert sink.rounds[0]["best"] == 1.0
    assert sink.rounds[1]["best"] == 1.0  # running best, not per-round
    # Back-compat: the default stream is still a flat key tuple.
    assert sink.key_stream() == (b"a", b"b", b"c")
    assert sink.key_stream(rounds=True) \
        == ((0, b"a"), (0, b"b"), (1, b"c"))


def test_telemetry_sink_registered_and_emits():
    assert "telemetry" in SINKS
    g = C.spmv_dag()
    sink = make_sink("telemetry", g)
    assert isinstance(sink, TelemetrySink)

    # Disabled registry: a pure no-op that still counts rounds.
    sink.consume(_fake_batch([b"a"], [1.0]), np.array([True]))
    assert sink.n_rounds == 1

    ex = obs.MemoryExporter()
    tel = obs.Telemetry(exporters=[ex])
    with obs.use(tel):
        sink.consume(_fake_batch([b"b", b"c"], [2.0, 0.5]),
                     np.array([True, False]))
    assert sink.n_rounds == 2
    assert tel.counters() == {"sink.consumed": 2.0, "sink.fresh": 1.0}
    assert tel.gauges() == {"sink.best": 0.5}
    marks = [e for e in ex.events if e["name"] == "sink.round"]
    assert len(marks) == 1 and marks[0]["args"]["round"] == 1


def test_driver_run_with_telemetry_sink_matches_plain():
    g = C.spmv_dag()
    from repro.driver import SearchDriver
    plain = SearchDriver(g, S.MCTSSearch(g, 2, seed=0), budget=30,
                         batch_size=6).run()
    tel = obs.Telemetry()
    with obs.use(tel):
        sunk = SearchDriver(g, S.MCTSSearch(g, 2, seed=0), budget=30,
                            batch_size=6, sinks=["telemetry"]).run()
    assert sunk.times == plain.times
    assert tel.counters()["sink.consumed"] == sunk.n_proposed
    assert tel.gauges()["sink.best"] == sunk.best()[1]


# -- the benchmark baseline comparator ----------------------------------------

def test_compare_to_baseline_flags_only_regressions():
    from benchmarks.run import compare_to_baseline
    baseline = [{"name": "a", "us_per_call": 100.0, "derived": ""},
                {"name": "b", "us_per_call": 100.0, "derived": ""},
                {"name": "gone", "us_per_call": 5.0, "derived": ""}]
    records = [{"name": "a", "us_per_call": 200.0, "derived": ""},
               {"name": "b", "us_per_call": 120.0, "derived": ""},
               {"name": "new", "us_per_call": 1.0, "derived": ""}]
    lines, regs = compare_to_baseline(records, baseline, threshold=0.5)
    assert regs == ["a"]                 # +100% > 50%; +20% is ok
    text = "\n".join(lines)
    assert "REGRESSED" in text and "+100.0%" in text
    assert "new" in text and "gone" in text
    # Everything passes under a permissive threshold.
    _, regs_loose = compare_to_baseline(records, baseline, threshold=1.5)
    assert regs_loose == []
