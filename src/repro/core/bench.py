"""Benchmarking protocol (paper §III-C3).

A *measurement* = keep invoking the program (each invocation is a
*sample*) until t_measure = 0.01 s has elapsed; the program time estimate
is elapsed / n_samples, and for multi-rank programs the reported time is
the max across ranks. Here "ranks" are mesh devices; on the CPU container
the executor runs all shards in one process, so the max is implicit.
"""
from __future__ import annotations

import time
from typing import Callable

T_MEASURE_S = 0.01


def measure(fn: Callable[[], object], t_measure_s: float = T_MEASURE_S,
            min_samples: int = 1) -> float:
    """One paper-style measurement of ``fn``; returns seconds/sample."""
    # Warm-up (compilation etc.) excluded, as any wall-clock benchmark must.
    fn()
    n = 0
    start = time.perf_counter()
    elapsed = 0.0
    while elapsed < t_measure_s or n < min_samples:
        fn()
        n += 1
        elapsed = time.perf_counter() - start
    return elapsed / n


# Measurement-noise injection for labeling-robustness studies lives in
# the evaluation engine (repro.engine, noise_sigma=...): noise is drawn
# per evaluation, after the memo cache — seeded per (canonical key,
# draw index), so it is independent of batch order and backend —
# matching how re-running a real benchmark behaves.
