"""Optimizer, data pipeline, train step, checkpoint, fault tolerance."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointStore
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, batch_for, lm_batch, \
    packed_batch
from repro.ft.restart import LoopConfig, TrainLoop
from repro.ft.straggler import StragglerMonitor
from repro.models.model import LM
from repro.optim.adamw import AdamW, apply_updates, global_norm, \
    warmup_cosine
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


# -- optimizer ----------------------------------------------------------------

def test_adamw_matches_reference_math():
    opt = AdamW(learning_rate=0.1, b1=0.9, b2=0.99, eps=1e-8,
                weight_decay=0.0, grad_clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = opt.init(p)
    up, st = opt.update(g, st, p)
    # step 1: mu = .1g, nu = .01g^2; bias-corrected ratio = g/|g|
    expect = -0.1 * np.asarray(g["w"]) / (np.abs(g["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(up["w"]), expect, rtol=1e-5)


def test_adamw_weight_decay_decoupled():
    opt = AdamW(learning_rate=0.1, weight_decay=0.5,
                grad_clip_norm=None)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([0.0])}
    st = opt.init(p)
    up, _ = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(up["w"]), [-0.1 * 0.5 * 2.0],
                               rtol=1e-5)


def test_grad_clipping_bounds_norm():
    opt = AdamW(grad_clip_norm=1.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st = opt.init(p)
    _, st = opt.update(g, st, p)
    assert float(global_norm(st["mu"])) <= 0.1 * 200.0 + 1e-3


def test_warmup_cosine_schedule():
    sch = warmup_cosine(1.0, 10, 100)
    assert float(sch(jnp.asarray(0))) == 0.0
    assert float(sch(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sch(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
    mid = float(sch(jnp.asarray(55)))
    assert 0.1 < mid < 1.0


# -- data ---------------------------------------------------------------------

def test_data_deterministic_and_stateless():
    cfg = DataConfig(seed=7, seq_len=32, global_batch=4, vocab=100)
    b1 = lm_batch(cfg, 5)
    b2 = lm_batch(cfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = lm_batch(cfg, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_packed_batch_masks_boundaries():
    cfg = DataConfig(seq_len=256, global_batch=2, vocab=100,
                     mean_doc_len=16, packed=True)
    b = packed_batch(cfg, 0)
    labels = np.asarray(b["labels"])
    assert (labels == -1).any()            # some masked targets
    assert (labels != -1).any()
    assert labels.max() < 100


def test_frontend_stub_batches():
    cfg = get_reduced("internvl2-2b")
    dcfg = DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab)
    b = batch_for(dcfg, 0, cfg)
    assert b["frontend"].shape == (2, cfg.frontend.n_positions,
                                   cfg.frontend.d_frontend)


# -- train step ----------------------------------------------------------------

def test_microbatched_step_matches_single_batch():
    cfg = get_reduced("granite-3-8b")
    m = LM(cfg)
    params = m.init(KEY)
    opt = AdamW(learning_rate=1e-3)
    dcfg = DataConfig(seq_len=16, global_batch=8, vocab=cfg.vocab)
    batch = batch_for(dcfg, 0, cfg)
    s1 = jax.jit(make_train_step(m, opt, microbatches=1))
    s4 = jax.jit(make_train_step(m, opt, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    # Identical average loss; Adam's sign normalization amplifies bf16
    # reorder noise in near-zero grads to ~±2*lr, so params compare at
    # that scale.
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-3)


def test_loss_decreases_under_training():
    cfg = get_reduced("smollm-360m")
    m = LM(cfg)
    params = m.init(KEY)
    opt = AdamW(learning_rate=3e-3)
    ostate = opt.init(params)
    dcfg = DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab)
    step = jax.jit(make_train_step(m, opt))
    losses = []
    for s in range(12):
        params, ostate, metrics = step(params, ostate,
                                       batch_for(dcfg, s % 2, cfg))
        losses.append(float(metrics["loss"]))
    assert min(losses[-4:]) < losses[0]


# -- checkpoint / fault tolerance -----------------------------------------------

def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d, keep_last=2)
        state = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                 "nested": {"b": np.asarray(3)}}
        for s in (10, 20, 30):
            store.save(s, state)
        assert store.steps() == [20, 30]      # gc keeps last 2
        assert store.latest_step() == 30
        step, out = store.restore(state)
        assert step == 30
        np.testing.assert_array_equal(out["a"], state["a"])
        np.testing.assert_array_equal(out["nested"]["b"], 3)


def test_checkpoint_async_then_wait():
    with tempfile.TemporaryDirectory() as d:
        store = CheckpointStore(d)
        store.save(1, {"x": np.ones(4)}, blocking=False)
        store.wait()
        assert store.latest_step() == 1


def test_restart_is_bit_exact():
    cfg = get_reduced("smollm-360m")
    m = LM(cfg)
    params = m.init(KEY)
    opt = AdamW(learning_rate=1e-3)
    ostate = opt.init(params)
    dcfg = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab)
    step = jax.jit(make_train_step(m, opt))
    bf = lambda s: batch_for(dcfg, s, cfg)  # noqa: E731
    with tempfile.TemporaryDirectory() as d:
        loop = TrainLoop(step, bf, CheckpointStore(d),
                         LoopConfig(total_steps=8, ckpt_every=3))
        with pytest.raises(RuntimeError, match="injected failure"):
            loop.run(params, ostate, fail_at=5)
        p1, _ = loop.resume(params, ostate)
        ref = TrainLoop(step, bf, CheckpointStore(d + "r"),
                        LoopConfig(total_steps=8, ckpt_every=100))
        p2, _ = ref.run(params, ostate)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_slow_rank():
    mon = StragglerMonitor(threshold=1.5, min_observations=3)
    for step in range(6):
        for rank in range(8):
            mon.record(rank, step, 0.1 if rank != 5 else 0.25)
    rep = mon.report()
    assert rep is not None
    assert list(rep.slow_ranks) == [5]


def test_straggler_monitor_quiet_when_uniform():
    mon = StragglerMonitor(min_observations=3)
    for step in range(5):
        for rank in range(4):
            mon.record(rank, step, 0.1)
    assert mon.report() is None
