"""End-to-end smoke gate (select with ``pytest -m smoke``)."""
import pytest

from benchmarks.smoke import (run_autotune_smoke, run_backend_smoke,
                              run_ooc_smoke, run_rpc_smoke, run_smoke,
                              run_store_smoke)


@pytest.mark.smoke
def test_smoke_search_to_rules_end_to_end():
    out = run_smoke(budget=200, seed=0)
    assert out["wall_s"] < 30.0
    assert out["n_evaluations"] == 200
    assert 1 <= out["n_schedules"] <= 200
    assert out["spread"] > 1.1          # schedule choice matters
    assert out["n_classes"] >= 1
    assert out["n_rulesets"] >= 1
    assert out["training_error"] <= 0.05


@pytest.mark.smoke
def test_smoke_every_evaluation_backend():
    """Fast path through all engine backends: the analytic ones must be
    byte-identical, wallclock must complete with its value gate on."""
    out = run_backend_smoke(budget=48, seed=0)
    assert out["analytic_identical"]
    for backend in ("sim", "vectorized", "pool", "wallclock"):
        assert out[backend]["n_schedules"] >= 1
        assert out[backend]["best_us"] > 0.0
    assert out["pool"]["cache_misses"] == out["sim"]["cache_misses"]


@pytest.mark.smoke
def test_smoke_kernel_autotune(tmp_path):
    """Tiny kernel-space autotune: a 2-point spmv block sweep through
    the param-space wallclock backend on CPU, warm-started from the
    store on the second pass (the kernel-space CI warm-start gate)."""
    out = run_autotune_smoke(str(tmp_path / "autotune.evalstore"))
    assert out["n_candidates"] == 2
    assert out["best_us"] > 0.0
    assert "block_n=" in out["best"]
    assert not out["warm_cache_restored"]        # tmp file starts cold
    assert out["second"]["store_hits"] == 2
    assert out["second"]["misses"] == 0


@pytest.mark.smoke
def test_smoke_ooc_distill_memory_ceiling():
    """Out-of-core tree training under a hard RLIMIT_AS ceiling sized
    so the dense path cannot possibly fit: the histogram path must
    pass, the dense fit must MemoryError — proving the OOC peak really
    is independent of the corpus, not just smaller on average."""
    out = run_ooc_smoke()
    assert out["ooc_ok"]
    assert not out["dense_ok"]
    assert out["dense"]["memory_error"]


@pytest.mark.smoke
def test_smoke_rpc_fleet_warm_start(tmp_path):
    """Two localhost evaluation-server subprocesses sharing one store:
    the cold rpc search must match serial byte-for-byte, and a warm
    rpc search must replay from the shared store with zero
    measurements and zero engine.measure spans."""
    out = run_rpc_smoke(str(tmp_path / "rpc.evalstore"))
    assert out["hosts"] == 2
    assert out["rpc_identical_to_sim"]
    assert not out["warm_cache_restored"]        # tmp file starts cold
    assert out["warm"]["store_hits"] > 0
    assert out["warm"]["misses"] == 0
    assert out["warm"]["measure_spans"] == 0


@pytest.mark.smoke
def test_smoke_store_warm_start(tmp_path):
    """Cold search warms the store; a fresh evaluator replays it from
    disk with zero measurements (the CI warm-start gate, minus the
    workflow cache)."""
    out = run_store_smoke(str(tmp_path / "smoke.evalstore"))
    assert not out["warm_cache_restored"]        # tmp file starts cold
    assert out["second"]["store_hits"] > 0
    assert out["second"]["misses"] == 0
