"""Fault-tolerant training loop: checkpoint/restart with bit-exact
resume.

Because the data pipeline is stateless (batch = f(seed, step)), the
checkpoint needs only (params, opt_state) and the step counter; a
restarted run replays from the last complete step and produces the same
trajectory as an uninterrupted run (asserted by tests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

from repro.checkpoint.store import CheckpointStore
from repro.ft.straggler import StragglerMonitor


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    async_ckpt: bool = True
    log_every: int = 10


class TrainLoop:
    """Drives train_step with periodic checkpoints; resumable."""

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 store: CheckpointStore, cfg: LoopConfig,
                 monitor: StragglerMonitor | None = None):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.store = store
        self.cfg = cfg
        self.monitor = monitor or StragglerMonitor()
        self.history: list[dict] = []

    def run(self, params, opt_state, start_step: int = 0,
            fail_at: int | None = None):
        """Run to total_steps. ``fail_at`` injects a crash (tests)."""
        step = start_step
        while step < self.cfg.total_steps:
            if fail_at is not None and step == fail_at:
                self.store.wait()
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(
                params, opt_state, batch)
            self.monitor.record(rank=0, step=step,
                                seconds=time.perf_counter() - t0)
            step += 1
            if step % self.cfg.log_every == 0 or \
                    step == self.cfg.total_steps:
                self.history.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
            if step % self.cfg.ckpt_every == 0 or \
                    step == self.cfg.total_steps:
                self.store.save(
                    step, {"params": params, "opt": opt_state},
                    blocking=not self.cfg.async_ckpt)
        self.store.wait()
        return params, opt_state

    def resume(self, params_like, opt_like, fail_at: int | None = None):
        """Restore the latest checkpoint and continue."""
        step, state = self.store.restore(
            {"params": params_like, "opt": opt_like})
        return self.run(state["params"], state["opt"],
                        start_step=step, fail_at=fail_at)
