"""Batched, memoized cost-model evaluation.

The search strategies propose thousands of candidate schedules; most of
the simulation work is repeated: op durations depend only on (graph,
machine), and stream-bijection-equivalent or re-proposed schedules have
identical makespans. :class:`BatchEvaluator` amortizes both:

  * op durations are computed once per (graph, machine) and reused by
    every simulation in the batch (the roofline division per op is the
    inner-loop cost of :func:`repro.core.costmodel.simulate`);
  * a transposition/memo cache keyed on the *canonical* schedule hash
    (stream-bijection normal form, §III-C2) simulates each distinct
    implementation exactly once — duplicates within a batch and across
    batches are cache hits.

Results are bit-identical to per-schedule
:func:`repro.core.costmodel.makespan` (see tests/test_batch_evaluator.py).

``noise_sigma`` adds seeded multiplicative Gaussian noise *after* the
cache, mimicking wall-clock measurement jitter: the underlying makespan
is memoized, but every evaluation call draws fresh noise — matching how
re-benchmarking a real program behaves.

``cache_misses`` counts actual discrete-event simulations and is the
meter behind ``run_search(sim_budget=N)``: equal-simulation
comparisons between screened (surrogate) and unscreened strategies
read it, so duplicates and surrogate-filtered candidates are free.
"""
from __future__ import annotations

import random
from typing import Sequence

from repro.core.costmodel import Machine, op_durations, simulate
from repro.core.dag import Graph, Schedule, canonicalize_streams


def canonical_key(schedule: Schedule) -> tuple:
    """Hashable identity under stream relabeling (transposition key)."""
    return tuple((i.name, i.stream)
                 for i in canonicalize_streams(schedule.items))


class BatchEvaluator:
    """Evaluate batches of schedules against the analytic machine model."""

    def __init__(self, graph: Graph, machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0):
        self.graph = graph
        self.machine = machine or Machine()
        self.noise_sigma = noise_sigma
        self._noise_rng = random.Random(noise_seed)
        self._durations = op_durations(graph, self.machine)
        self._cache: dict[tuple, float] = {}
        self.cache_hits = 0
        self.cache_misses = 0

    def __len__(self) -> int:
        return len(self._cache)

    def evaluate_keyed(self, schedules: Sequence[Schedule]
                       ) -> list[tuple[tuple, float]]:
        """(canonical key, makespan) per schedule, in order; one
        simulation per distinct canonical schedule across the
        evaluator's lifetime. The key is returned so callers that also
        need it (run_search dedup) don't re-canonicalize."""
        out: list[tuple[tuple, float]] = []
        for s in schedules:
            key = canonical_key(s)
            t = self._cache.get(key)
            if t is None:
                self.cache_misses += 1
                t = simulate(self.graph, s, self.machine,
                             durations=self._durations).makespan
                self._cache[key] = t
            else:
                self.cache_hits += 1
            if self.noise_sigma:
                t *= max(0.1, 1.0 + self._noise_rng.gauss(
                    0.0, self.noise_sigma))
            out.append((key, t))
        return out

    def evaluate(self, schedules: Sequence[Schedule]) -> list[float]:
        """Makespan per schedule, in order (see :meth:`evaluate_keyed`)."""
        return [t for _, t in self.evaluate_keyed(schedules)]

    def evaluate_one(self, schedule: Schedule) -> float:
        return self.evaluate([schedule])[0]
