"""Compatibility shim: labeling now lives in :mod:`repro.rules.labels`.

The §IV-A performance-class labeling moved into the rules distillation
subsystem — :mod:`repro.rules` — where it shares the labels -> trees ->
rulesets pipeline (:func:`repro.rules.distill`) with the vectorized
tree trainer and the design-rule renderer. Import from
:mod:`repro.rules` (or keep importing from here / :mod:`repro.core`;
both stay supported, with a :class:`DeprecationWarning` so the shim
can eventually be deleted — every name here *is* the
:mod:`repro.rules.labels` object, asserted by tests/test_shims.py).
"""
import warnings

warnings.warn(
    "repro.core.labels is a deprecated shim; import label_times/"
    "Labeling/... from repro.rules (new home: repro.rules.labels)",
    DeprecationWarning, stacklevel=2)

from repro.rules.labels import (Labeling, find_peaks, label_times,
                                peak_prominences, peak_prominences_loop,
                                step_convolve)

__all__ = ["Labeling", "find_peaks", "label_times", "peak_prominences",
           "peak_prominences_loop", "step_convolve"]
