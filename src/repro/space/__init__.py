"""Design spaces: the candidate-type seam of the whole stack.

``repro.space`` defines what a search needs from a space
(:class:`DesignSpace`), the paper's schedule spaces as its first
registered instance (:class:`ScheduleSpace` — bit-compatible with the
pre-protocol pipeline), parameter grids (:class:`ParamSpace`) for
tunable knobs like kernel block sizes, and a name registry
(:func:`register_space` / :func:`make_space`) so examples and CLIs
select spaces by name.

The kernel parameter spaces (``flash_attention``, ``spmv_mulsum``,
``pack`` — :mod:`repro.kernels.autotune`) are registered through lazy
factories: importing this package never imports JAX.
"""
from repro.space.base import (SPACES, DesignSpace, as_space, make_space,
                              register_space)
from repro.space.params import (KernelRunner, ParamFeature, ParamSpace,
                                demo_param_space)
from repro.space.schedule import (ScheduleSpace, canonical_key,
                                  eligible_items, random_schedule)

__all__ = [
    "DesignSpace", "ScheduleSpace", "ParamSpace", "ParamFeature",
    "KernelRunner", "SPACES", "register_space", "make_space",
    "as_space", "canonical_key", "eligible_items", "random_schedule",
    "demo_param_space",
]


def _schedule_factory(builder):
    def make(n_streams: int = 2, **kwargs) -> ScheduleSpace:
        return ScheduleSpace(builder(**kwargs), n_streams)
    return make


def _spmv(**kw):
    from repro.core.dag import spmv_dag
    return spmv_dag(**kw)


def _spmv_fine(**kw):
    from repro.core.dag import spmv_dag_fine
    return spmv_dag_fine(**kw)


def _halo3d(**kw):
    from repro.core.dag import halo3d_dag
    return halo3d_dag(**kw)


def _kernel_factory(name):
    def make(**kwargs) -> ParamSpace:
        import repro.kernels.autotune as autotune
        return getattr(autotune, name)(**kwargs)
    return make


# The paper's DAG schedule spaces.
register_space("spmv", _schedule_factory(_spmv))
register_space("spmv_fine", _schedule_factory(_spmv_fine))
register_space("halo3d", _schedule_factory(_halo3d))
# The repo's own Pallas kernel grids (lazy: factories import JAX).
register_space("flash_attention", _kernel_factory("flash_attention_space"))
register_space("spmv_mulsum", _kernel_factory("spmv_mulsum_space"))
register_space("pack", _kernel_factory("pack_space"))
# Analytic demo grid (tests, smoke runs; no JAX).
register_space("demo", demo_param_space)
