"""jit'd public wrappers for the ELL SpMV kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spmv import kernel as _k
from repro.kernels.spmv.ref import ell_matvec_ref  # re-export for callers

__all__ = ["ell_matvec", "ell_matvec_onehot", "ell_matvec_ref"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_matvec(vals: jax.Array, cols: jax.Array, x: jax.Array,
               block_n: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """y = A x for ELL (vals, cols) row-major (N, K) and dense x.

    Gather via XLA's gather HLO (TPU-native for wide/irregular column
    sets), fused multiply-reduce in Pallas (ELL-T layout).
    """
    interpret = _interpret_default() if interpret is None else interpret
    xg_t = x[cols].T          # (K, N)
    vals_t = vals.T
    return _k.ell_mulsum(vals_t, xg_t, block_n=block_n,
                         interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("half_bandwidth", "block_r",
                                    "interpret"))
def ell_matvec_onehot(vals: jax.Array, cols: jax.Array, x: jax.Array,
                      half_bandwidth: int, block_r: int = 256,
                      interpret: bool | None = None) -> jax.Array:
    """Narrow-band ELL SpMV with the in-kernel one-hot gather.

    Valid when every column is within ``half_bandwidth`` of its row
    (circular metric). Window width = 2*half_bandwidth + block_r.
    """
    interpret = _interpret_default() if interpret is None else interpret
    n, k = vals.shape
    hb = half_bandwidth
    pad_n = (-n) % block_r
    if pad_n:
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad_n, k), vals.dtype)], axis=0)
        cols = jnp.concatenate(
            [cols, jnp.arange(n, n + pad_n, dtype=cols.dtype)[:, None]
             .repeat(k, 1) % x.shape[0]], axis=0)
    np_ = n + pad_n
    n_x = x.shape[0]

    # Wrap-padded x: index p = original + hb.
    x_pad = jnp.concatenate([x[n_x - hb:], x, x[:hb]])
    w = 2 * hb + block_r
    nblocks = np_ // block_r
    starts = jnp.arange(nblocks) * block_r
    x_windows = jax.vmap(
        lambda s: jax.lax.dynamic_slice(x_pad, (s,), (w,)))(starts)

    # Window-relative columns: offset in [-hb, hb] circularly, then
    # position within the block's window.
    rows = jnp.arange(np_, dtype=jnp.int32)[:, None]
    offset = (cols.astype(jnp.int32) - rows % n_x + hb) % n_x - hb
    block_start = (rows // block_r) * block_r
    cols_win = offset + hb + (rows - block_start)

    y = _k.ell_onehot_mv(vals.T, cols_win.T.astype(jnp.int32), x_windows,
                         block_r=block_r, interpret=interpret)
    return y[:n]
