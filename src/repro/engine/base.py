"""The evaluator contract: shared memo-cache / noise / budget layer.

Every evaluation backend — serial discrete-event simulation, the numpy
batch simulator, the process pool, the wall-clock executor — subclasses
:class:`EvaluatorBase` and implements exactly one hook::

    _measure_batch(schedules) -> list[float]

called only with *canonical-unique cache misses*, in first-appearance
order. Everything search-visible lives in the base class and is
therefore identical across backends:

  * the transposition/memo cache keyed on the canonical schedule hash
    (stream-bijection normal form, §III-C2) — each distinct
    implementation is measured exactly once;
  * the optional persistent store seam (``store=`` / ``store_path=``,
    :mod:`repro.engine.store`): looked up *between* the in-memory
    cache and ``_measure_batch``, written back after every
    measurement, so a search against a warmed store replays without
    measuring anything — while a cold run with a store attached stays
    byte-identical to a storeless run;
  * ``cache_hits`` / ``store_hits`` / ``cache_misses`` accounting —
    the three-way meter behind ``run_search(sim_budget=N)`` and the
    service QoS/billing signal, so equal-simulation comparisons mean
    the same thing no matter which backend ran them;
  * measurement noise: with ``noise_sigma`` set, every evaluation draws
    multiplicative Gaussian jitter seeded per **(canonical key, draw
    index)** — *not* from one shared RNG stream — so noisy results are
    a function of what was evaluated, never of batch order, worker
    sharding, or vectorization. The j-th evaluation of a given
    implementation returns the same noisy value on every backend.

The serial reference backend (:class:`BatchEvaluator`, registry name
``"sim"``) lives here too: it is the behavior every other backend is
bit-locked against (see tests/test_batch_evaluator.py and
tests/test_engine_vectorized.py).
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Iterator, Sequence

import numpy as np

from repro import obs
from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.engine.store import EvalStore
from repro.space.base import DesignSpace, as_space
from repro.space.schedule import canonical_key  # noqa: F401  (re-export)


def _noise_gauss(noise_seed: int, key: bytes, draw: int) -> float:
    """A standard-normal draw seeded purely by what is being evaluated.

    ``repr`` of a canonical cache key (bytes) is deterministic, and
    blake2b is stable across processes and ``PYTHONHASHSEED`` values —
    so pooled, vectorized, and permuted evaluation all see the
    identical noise for the j-th draw of a given implementation.
    """
    payload = repr((noise_seed, key, draw)).encode()
    seed = int.from_bytes(
        hashlib.blake2b(payload, digest_size=8).digest(), "big")
    return random.Random(seed).gauss(0.0, 1.0)


@dataclasses.dataclass
class EvalBatch:
    """One evaluated proposal batch — the record streamed to sinks.

    The batch-iterator contract between evaluators and the search
    driver (:mod:`repro.driver`): every round of evaluation yields one
    :class:`EvalBatch` with aligned ``schedules`` / canonical ``keys``
    / ``times``, exactly the ``(key, time)`` pairs
    :meth:`EvaluatorBase.evaluate_keyed` returns, in proposal order
    (duplicates included — run-level dedup is the consumer's choice,
    not the evaluator's). Iterating yields ``(schedule, key, time)``
    triples.
    """

    schedules: list[Schedule]
    keys: list[bytes]
    times: np.ndarray                    # float64, aligned

    def __len__(self) -> int:
        return len(self.schedules)

    def __iter__(self) -> Iterator[tuple[Schedule, bytes, float]]:
        return iter(zip(self.schedules, self.keys, self.times))


class EvaluatorBase:
    """Batched, memoized schedule evaluation (backend-agnostic layer)."""

    backend = "abstract"

    def __init__(self, graph: "Graph | DesignSpace",
                 machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0,
                 store: EvalStore | None = None,
                 store_path: "str | None" = None,
                 store_tag: str = ""):
        if store is not None and store_path is not None:
            raise ValueError(
                "pass store= (a shared EvalStore) or store_path= "
                "(a file the evaluator opens and owns), not both")
        self.space = as_space(graph)
        # Schedule spaces expose their graph; param spaces have none.
        self.graph = getattr(self.space, "graph", None)
        self.machine = machine or Machine()
        self.noise_sigma = noise_sigma
        self.noise_seed = noise_seed
        self._noise_draws: dict[bytes, int] = {}
        self._durations = self.space.durations(self.machine)
        self._cache: dict[bytes, float] = {}
        self._salvaged: set[bytes] = set()
        self.cache_hits = 0
        self.store_hits = 0
        self.cache_misses = 0
        self._owns_store = store_path is not None
        self.store = EvalStore(store_path) if store_path is not None \
            else store
        self.store_tag = store_tag
        self._fingerprint: bytes | None = None

    def __len__(self) -> int:
        return len(self._cache)

    # -- persistent store --------------------------------------------------
    def _objective_key(self) -> str:
        """What quantity ``_measure_batch`` estimates.

        The bit-identical analytic family (sim/vectorized/pool) shares
        ``"analytic"`` on purpose — their stored times are
        interchangeable, so they warm-start each other. Backends with a
        different objective (wallclock) must override so their results
        can never collide with analytic ones.
        """
        return "analytic"

    @property
    def store_fingerprint(self) -> bytes:
        """Content address of this evaluator's measurement semantics
        (the space's :meth:`~repro.space.base.DesignSpace.fingerprint`
        over the resolved objective; schedule spaces delegate to
        :func:`repro.engine.store.store_fingerprint` unchanged); lazy
        so subclass ``__init__`` can finish configuring the objective."""
        if self._fingerprint is None:
            objective = self._objective_key()
            if self.store_tag:
                objective += f":{self.store_tag}"
            self._fingerprint = self.space.fingerprint(
                self.machine, self._durations, objective)
        return self._fingerprint

    def fresh_evals(self) -> int:
        """First-time evaluations of distinct implementations — paid
        measurements plus store warm hits. This is what
        ``run_search(sim_budget=)`` meters, so a warmed search replays
        the cold trajectory exactly instead of running unbounded."""
        return self.cache_misses + self.store_hits

    def stats(self) -> dict:
        """Cache traffic summary (the QoS/billing meter):
        {backend, memory_hits, store_hits, misses, size, hit_rate}."""
        served = self.cache_hits + self.store_hits
        total = served + self.cache_misses
        return {
            "backend": self.backend,
            "memory_hits": self.cache_hits,
            "store_hits": self.store_hits,
            "misses": self.cache_misses,
            "size": len(self._cache),
            "hit_rate": served / total if total else 0.0,
        }

    # -- the backend hook --------------------------------------------------
    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        """Measure canonical-unique cache misses (one time per schedule).

        Called with distinct implementations only, in first-appearance
        order; must return one float per input, in order. ``encoded``
        is the matching canonical encoding rows from
        :meth:`_encode_batch` (``(K, 2, N)`` int32 for schedule
        spaces, ``(K, D)`` value indices for parameter spaces) —
        backends that simulate in array form use it to skip
        re-encoding; others ignore it.
        """
        raise NotImplementedError

    # -- canonical encoding -------------------------------------------------
    def _encode_batch(self, schedules: Sequence[Schedule]
                      ) -> tuple[list[bytes], np.ndarray]:
        """(cache keys, canonical encoding) for a candidate batch —
        the space's :meth:`~repro.space.base.DesignSpace.encode_batch`
        (for schedule spaces, the vectorized first-use stream relabel
        that used to live here; see :meth:`repro.space.schedule.
        ScheduleSpace.encode_batch`)."""
        return self.space.encode_batch(schedules)

    # -- the shared evaluation path ----------------------------------------
    def _noisy(self, key: bytes, t: float) -> float:
        if not self.noise_sigma:
            return t
        draw = self._noise_draws.get(key, 0)
        self._noise_draws[key] = draw + 1
        g = _noise_gauss(self.noise_seed, key, draw)
        return t * max(0.1, 1.0 + self.noise_sigma * g)

    def evaluate_keyed(self, schedules: Sequence[Schedule]
                       ) -> list[tuple[bytes, float]]:
        """(canonical key, time) per schedule, in order; one measurement
        per distinct canonical schedule across the evaluator's lifetime.
        The key is returned so callers that also need an identity under
        stream relabeling (run_search dedup) don't re-canonicalize."""
        if not schedules:
            return []
        batch_span = obs.span("engine.batch", backend=self.backend,
                              n=len(schedules))
        batch_span.__enter__()
        hits0, store0 = self.cache_hits, self.store_hits
        misses0 = self.cache_misses
        try:
            out = self._evaluate_keyed(schedules)
        finally:
            batch_span.set(
                memory_hits=self.cache_hits - hits0,
                store_hits=self.store_hits - store0,
                misses=self.cache_misses - misses0,
                noise_draws=len(schedules) if self.noise_sigma else 0)
            batch_span.__exit__(None, None, None)
        return out

    def _evaluate_keyed(self, schedules: Sequence[Schedule]
                        ) -> list[tuple[bytes, float]]:
        keys, encoded = self._encode_batch(schedules)
        miss_keys: list[bytes] = []
        miss_rows: list[int] = []
        pending: set[bytes] = set()
        warm: set[bytes] = set()     # served by the persistent store
        for b, key in enumerate(keys):
            if key in self._cache or key in pending:
                continue
            if self.store is not None:
                t = self.store.get(self.store_fingerprint, key)
                if t is not None:
                    self._cache[key] = t
                    warm.add(key)
                    continue
            pending.add(key)
            miss_keys.append(key)
            miss_rows.append(b)
        if miss_rows:
            miss_scheds = [schedules[b] for b in miss_rows]
            with obs.span("engine.measure", backend=self.backend,
                          n=len(miss_scheds)):
                measured = self._measure_batch(miss_scheds,
                                               encoded[miss_rows])
            if len(measured) != len(miss_scheds):
                raise RuntimeError(
                    f"{type(self).__name__}._measure_batch returned "
                    f"{len(measured)} results for {len(miss_scheds)} "
                    "schedules")
            for key, t in zip(miss_keys, measured):
                self._cache[key] = float(t)
            if self.store is not None:
                self.store.put_many(
                    self.store_fingerprint,
                    [(key, self._cache[key]) for key in miss_keys])

        out: list[tuple[bytes, float]] = []
        for key in keys:
            if key in pending:       # first occurrence of a fresh miss
                pending.discard(key)
                self.cache_misses += 1
            elif key in warm:        # first occurrence of a store hit
                warm.discard(key)
                self.store_hits += 1
            elif key in self._salvaged:
                # A measurement salvaged from an aborted batch (e.g. a
                # wallclock value-gate failure) was paid but never
                # counted; its first post-salvage lookup is a miss, not
                # a hit, so sim_budget accounting stays honest.
                self._salvaged.discard(key)
                self.cache_misses += 1
            else:
                self.cache_hits += 1
            out.append((key, self._noisy(key, self._cache[key])))
        return out

    def evaluate(self, schedules: Sequence[Schedule]) -> list[float]:
        """Time per schedule, in order (see :meth:`evaluate_keyed`)."""
        return [t for _, t in self.evaluate_keyed(schedules)]

    def evaluate_batch(self, schedules: Sequence[Schedule]) -> EvalBatch:
        """One :class:`EvalBatch` record for ``schedules``.

        The streaming form of :meth:`evaluate_keyed` — same values,
        same cache/meter/noise semantics — packaged as the record the
        search driver hands to its sinks.
        """
        keyed = self.evaluate_keyed(schedules)
        return EvalBatch(
            schedules=list(schedules),
            keys=[k for k, _ in keyed],
            times=np.asarray([t for _, t in keyed], dtype=np.float64))

    def evaluate_one(self, schedule: Schedule) -> float:
        return self.evaluate([schedule])[0]

    def _salvage_partial(self, encoded: np.ndarray,
                         times: Sequence[float]) -> None:
        """Bank completed measurements from an aborted ``_measure_batch``.

        Backends whose measurements are expensive call this from their
        failure path (see :mod:`repro.engine.wallclock`): the finished
        ``(encoded row, time)`` pairs land in the memo cache — and the
        persistent store, they were paid for — so a retry does not
        re-measure them. The keys are remembered as *salvaged* so their
        first later lookup counts as a miss (the measurement was real
        work that no meter has seen yet), never as a free hit.
        """
        items = []
        for row, t in zip(encoded, times):
            key = row.tobytes()
            self._cache[key] = float(t)
            self._salvaged.add(key)
            items.append((key, float(t)))
        if self.store is not None and items:
            self.store.put_many(self.store_fingerprint, items)

    def close(self) -> None:
        """Release backend resources (worker pools, an owned store);
        idempotent. A store opened by this evaluator (``store_path=``)
        is closed; a shared ``store=`` stays the caller's."""
        if getattr(self, "_owns_store", False) and self.store is not None:
            self.store.close()
            self.store = None
            self._owns_store = False

    def __enter__(self) -> "EvaluatorBase":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class BatchEvaluator(EvaluatorBase):
    """The serial reference backend: one analytic-model evaluation per
    canonical-unique candidate (a discrete-event simulation for
    schedule spaces, the space's cost function otherwise)."""

    backend = "sim"

    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        return [self.space.analytic_cost(s, self.machine,
                                         self._durations)
                for s in schedules]
