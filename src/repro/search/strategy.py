"""The :class:`SearchStrategy` protocol and the stateless strategies.

All design-space exploration in this repo — the paper's MCTS (§III-C),
exhaustive enumeration (§III-C2 / Fig. 1), and the cheaper baselines —
speaks one interface:

    propose(budget) -> up to ``budget`` candidate Schedules
    observe(schedule, time)  -> feed one measured/simulated time back

The caller (:func:`repro.search.pipeline.run_search`) owns evaluation:
strategies never call the cost model on complete schedules themselves,
so evaluation can be batched, memoized, or replaced (wall-clock executor,
noisy objective, learned surrogate) without touching any strategy.
The two-stage surrogate-screened strategies live in
:mod:`repro.search.surrogate`; they speak this same protocol.

A strategy may return fewer schedules than asked — returning an empty
list means the space is exhausted and the search loop stops.
"""
from __future__ import annotations

import random
from typing import Iterator, Protocol, runtime_checkable

from repro.core.costmodel import Machine, op_durations, simulate
from repro.core.dag import BoundOp, Graph, Schedule
from repro.space.base import DesignSpace, as_space
from repro.space.schedule import (eligible_items,  # noqa: F401 (re-export)
                                  random_schedule)


@runtime_checkable
class SearchStrategy(Protocol):
    """Pluggable explorer of the (traversal x stream-binding) space."""

    def propose(self, budget: int) -> list[Schedule]:
        """Return up to ``budget`` candidate schedules (empty = done)."""
        ...

    def observe(self, schedule: Schedule, time: float) -> None:
        """Feed back the measured time of a proposed schedule."""
        ...


@runtime_checkable
class PoolSearchStrategy(SearchStrategy, Protocol):
    """The pool-proposal extension: screening split out of ``propose``.

    Two-stage strategies internally do *pool -> rank -> top-k*; this
    protocol exposes the stages so the acquisition-aware driver
    (:class:`repro.driver.SearchDriver`) can substitute its own
    ranking while the strategy keeps candidate generation, RNG state,
    and screening bookkeeping. A conforming strategy's ``propose(k)``
    must equal ``pad(screen(propose_pool(k), k, <default acq>), k)``
    whenever ``propose_pool`` returns a pool — so driving through
    either path is the same search.

    ``propose_pool(budget)``
        The raw candidate pool a ``propose(budget)`` call would screen
        (novel, canonical, deduped), or ``None`` when screening does
        not apply yet (warmup: the driver falls back to ``propose``).
    ``screen(pool, budget, acquisition)``
        Rank ``pool`` with ``acquisition(surrogate, pool, best=...)``
        and return the chosen ``<= budget`` schedules, recording
        whatever the strategy logs about screening (pending
        predictions, counters). Pools no larger than ``budget`` pass
        through unranked.
    ``pad(chosen, budget)``
        Fill ``chosen`` up to ``budget`` (e.g. with uniform rollouts)
        so the search loop is never starved.

    :class:`repro.search.surrogate.SurrogateGuided` is the reference
    implementation.
    """

    def propose_pool(self, budget: int) -> list[Schedule] | None:
        ...

    def screen(self, pool: list[Schedule], budget: int,
               acquisition) -> list[Schedule]:
        ...

    def pad(self, chosen: list[Schedule],
            budget: int) -> list[Schedule]:
        ...


class ExhaustiveSearch:
    """Full enumeration in the space's canonical order.

    Proposes :meth:`~repro.space.base.DesignSpace.enumerate_candidates`
    (:func:`repro.core.enumerate.enumerate_schedules` for schedule
    spaces); ``observe`` is a no-op. Exhausts after one full sweep.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 n_streams: int | None = None):
        self.space = as_space(graph, n_streams)
        self.graph = getattr(self.space, "graph", None)
        self.n_streams = getattr(self.space, "n_streams", None)
        self._iter: Iterator = self.space.enumerate_candidates()

    def propose(self, budget: int) -> list[Schedule]:
        out: list[Schedule] = []
        for s in self._iter:
            out.append(s)
            if len(out) >= budget:
                break
        return out

    def observe(self, schedule: Schedule, time: float) -> None:
        pass


class RandomSearch:
    """I.i.d. uniform rollouts — the paper's unguided baseline.

    Duplicates are possible (and cheap: the batch evaluator memoizes);
    the strategy never exhausts on its own, so the pipeline budget is the
    only stopping criterion.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 n_streams: int | None = None, seed: int = 0):
        self.space = as_space(graph, n_streams)
        self.graph = getattr(self.space, "graph", None)
        self.n_streams = getattr(self.space, "n_streams", None)
        self.rng = random.Random(seed)

    def propose(self, budget: int) -> list[Schedule]:
        return [self.space.random_candidate(self.rng)
                for _ in range(budget)]

    def observe(self, schedule: Schedule, time: float) -> None:
        pass


class GreedyCostModel:
    """Epsilon-greedy construction guided by prefix simulation.

    Each schedule is grown item by item; at every step the candidate
    extensions are scored by simulating the *partial* schedule under the
    analytic machine model and the arg-min is taken (ties broken by
    canonical item order). With probability ``epsilon`` a uniformly
    random extension is taken instead, so repeated proposals explore
    beyond the single pure-greedy trajectory. The first proposal of a
    run is always pure greedy (epsilon applies from the second on).

    Greedy construction pays *prefix* simulations that bypass the
    pipeline's :class:`BatchEvaluator` (and therefore the
    ``run_search(sim_budget=)`` meter); ``n_prefix_sims`` counts them
    so budget-accounting callers can report or charge the hidden cost.
    """

    def __init__(self, graph: Graph, n_streams: int,
                 machine: Machine | None = None,
                 epsilon: float = 0.25, seed: int = 0):
        self.graph = graph
        self.n_streams = n_streams
        self.machine = machine or Machine()
        self.epsilon = epsilon
        self.rng = random.Random(seed)
        self._n_proposed = 0
        self._durations = op_durations(graph, self.machine)
        self.n_prefix_sims = 0

    def _prefix_cost(self, prefix: list[BoundOp]) -> float:
        self.n_prefix_sims += 1
        return simulate(self.graph, Schedule(tuple(prefix)),
                        self.machine,
                        durations=self._durations).makespan

    def _build(self, greedy_only: bool) -> Schedule:
        prefix: list[BoundOp] = []
        while True:
            options = eligible_items(self.graph, prefix, self.n_streams)
            if not options:
                return Schedule(tuple(prefix))
            if not greedy_only and self.rng.random() < self.epsilon:
                prefix.append(self.rng.choice(options))
                continue
            best = min(options, key=lambda o: self._prefix_cost(prefix + [o]))
            prefix.append(best)

    def propose(self, budget: int) -> list[Schedule]:
        out: list[Schedule] = []
        for _ in range(budget):
            out.append(self._build(greedy_only=self._n_proposed == 0))
            self._n_proposed += 1
        return out

    def observe(self, schedule: Schedule, time: float) -> None:
        pass
