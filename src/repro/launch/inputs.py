"""Per-cell model configs, sharding rules, and abstract input specs.

``build_cell(arch, shape, mesh)`` assembles everything the dry-run needs
for one (architecture x input-shape x mesh) cell: the step function, the
ShapeDtypeStruct stand-ins for every input (weak-type-correct, shardable,
no device allocation), and in/out shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as cfgs
from repro.configs.shapes import SHAPES, ShapeCell, applicable
from repro.dist import sharding as shd
from repro.models.config import ModelConfig
from repro.models.model import LM
from repro.optim.adamw import AdamW
from repro.serve.engine import cache_axes, make_serve_step
from repro.train.step import make_train_step


def cell_config(arch: str, shape: str, mesh: Mesh) -> ModelConfig:
    """Full config, transformed for the cell (head padding, windows,
    serve dtypes)."""
    cfg = cfgs.get_config(arch)
    cell = SHAPES[shape]
    tp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    over: dict[str, Any] = {"head_pad_to": tp}
    if cell.kind != "train":
        over["param_dtype"] = "bfloat16"   # serving weights
        over["remat"] = False
    if arch == "jamba-v0.1-52b" and shape == "long_500k":
        # Hybrid long-context posture: windowed attention layers, mamba
        # layers carry the unbounded context (DESIGN.md §4).
        over["attn_window"] = 4096
    return dataclasses.replace(cfg, **over)


def rules_for(cfg: ModelConfig, kind: str, mesh: Mesh) -> dict:
    """Logical-axis rules for one cell (see DESIGN.md §5).

    Baseline scheme: TP over "model" (heads/d_ff/vocab/experts), batch
    over ("pod","data"), FSDP (params + optimizer over "data") for
    training. KV-head fallbacks when kv_heads doesn't divide the model
    axis: row-parallel KV weights (train/prefill) or head_dim-sharded
    caches (decode).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    rules: dict[str, Any] = {}
    # Params + AdamW moments in f32 = 12 bytes/param, TP-sharded.
    param_gb_per_chip = cfg.param_count() * 12 / tp / 1e9
    if kind == "train" and param_gb_per_chip > 5.0:
        # Fused FSDP+TP (maxtext-style) for models whose optimizer state
        # does not fit TP-only: parameter *output* dims shard over
        # (model, data) jointly (ZeRO-3 semantics). Small models skip
        # FSDP entirely — pure DP+TP costs one gradient all-reduce per
        # step instead of per-layer weight gathers (see EXPERIMENTS
        # §Perf iterations 1-3).
        rules.update({
            "d_ff": ("model", "data"),
            "vocab": ("model", "data"),
            "d_inner": ("model", "data"),
            "heads_x_dim": ("model", "data"),
            "head_dim": "data",            # FSDP for attention weights
        })
    if cfg.n_kv_heads % tp != 0:
        # True-KV weight dim can't shard; the stored-KV (duplicated)
        # activations/caches shard via the "kv_stored" rule instead.
        rules["kv_heads"] = None
    if kind == "decode" and cfg.attn_window is not None:
        # Windowed decode dynamic-slices the cache along kv_seq; a
        # seq-sharded cache would force a full-cache all-gather per
        # layer. Shard on kv_stored instead.
        rules["kv_seq"] = None
    return rules


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    fn: Callable
    args: tuple            # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    meta: dict


def _with_activation_ctx(fn: Callable, mesh: Mesh,
                         rules: dict) -> Callable:
    """Trace ``fn`` under the logical activation-sharding context so
    model-internal ``constrain()`` calls bind to this cell's rules."""

    def wrapped(*args):
        with shd.activation_sharding(mesh, rules):
            return fn(*args)

    return wrapped


def _abstract_batch(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                    rules: dict, with_labels: bool) -> tuple[dict, dict]:
    gb, s = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    batch = {"tokens": jax.ShapeDtypeStruct((gb, s), i32)}
    # spec_for drops non-divisible dims (e.g. long_500k's batch of 1).
    bsh = {"tokens": NamedSharding(mesh, shd.spec_for(
        (gb, s), ("batch", "seq"), mesh, rules))}
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct((gb, s), i32)
        bsh["labels"] = bsh["tokens"]
    if cfg.frontend is not None:
        fe = cfg.frontend
        batch["frontend"] = jax.ShapeDtypeStruct(
            (gb, fe.n_positions, fe.d_frontend), jnp.float32)
        bsh["frontend"] = NamedSharding(mesh, shd.spec_for(
            (gb, fe.n_positions, fe.d_frontend),
            ("batch", None, None), mesh, rules))
    return batch, bsh


def _default_microbatches(cfg: ModelConfig, cell: ShapeCell,
                          mesh: Mesh) -> int:
    """Grad-accumulation depth: keep saved per-layer boundary
    activations under ~3 GB/chip."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = sizes.get("data", 1) * sizes.get("pod", 1)
    b_dev = max(1, cell.global_batch // dp)
    saved = b_dev * cell.seq_len * cfg.d_model * 2 * cfg.n_layers
    mb = 1
    while saved / mb > 3e9 and mb < b_dev:
        mb *= 2
    return mb


def build_cell(arch: str, shape: str, mesh: Mesh,
               rules_override: dict | None = None,
               microbatches: int | None = None,
               moe_dispatch: str | None = None,
               bf16_params: bool = False) -> Cell:
    assert applicable(arch, shape), f"{arch} x {shape} is a skip cell"
    cell = SHAPES[shape]
    cfg = cell_config(arch, shape, mesh)
    if bf16_params and cell.kind == "train":
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if moe_dispatch is not None and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    rules = rules_for(cfg, cell.kind, mesh)
    if rules_override:
        rules.update(rules_override)
    model = LM(cfg)
    p_abs = model.abstract_params()
    p_axes = model.param_axes()
    p_sh = shd.tree_shardings(p_axes, mesh, rules, p_abs)
    meta = {"arch": arch, "shape": shape, "kind": cell.kind,
            "global_batch": cell.global_batch, "seq_len": cell.seq_len,
            "n_params": model.n_params(), "rules": {
                k: v for k, v in rules.items()}}

    if cell.kind == "train":
        use_master = bf16_params
        opt = AdamW(master_weights=use_master)
        f32like = lambda: jax.tree.map(  # noqa: E731
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), p_abs)
        o_abs = {"mu": f32like(), "nu": f32like(),
                 "count": jax.ShapeDtypeStruct((), jnp.int32)}
        o_sh = {"mu": p_sh, "nu": p_sh,
                "count": NamedSharding(mesh, P())}
        if use_master:
            o_abs["master"] = f32like()
            o_sh["master"] = p_sh
        batch, b_sh = _abstract_batch(cfg, cell, mesh, rules, True)
        mb = microbatches if microbatches is not None else \
            _default_microbatches(cfg, cell, mesh)
        meta["microbatches"] = mb
        fn = _with_activation_ctx(
            make_train_step(model, opt, microbatches=mb,
                            rwkv_chunk=_rwkv_chunk(cfg, cell)),
            mesh, rules)
        return Cell(arch, shape, cfg, fn, (p_abs, o_abs, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, None), meta)

    if cell.kind == "prefill":
        batch, b_sh = _abstract_batch(cfg, cell, mesh, rules, False)
        t_max = cell.seq_len
        if cfg.family == "vlm":
            t_max += cfg.frontend.n_positions  # patch tokens prepended
        fn = _with_activation_ctx(
            lambda p, b: model.prefill(
                p, b, t_max, rwkv_chunk=_rwkv_chunk(cfg, cell)),
            mesh, rules)
        return Cell(arch, shape, cfg, fn, (p_abs, batch),
                    (p_sh, b_sh), None, meta)

    # decode: one new token against a seq_len cache.
    gb = cell.global_batch
    t_max = cell.seq_len
    n_memory = cfg.frontend.n_positions if cfg.family == "encdec" else 0
    c_abs = jax.eval_shape(
        lambda: model.init_caches(gb, t_max, n_memory=n_memory))
    c_axes = cache_axes(model)
    c_sh = shd.tree_shardings(c_axes, mesh, rules, c_abs)
    tokens = jax.ShapeDtypeStruct((gb, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, shd.spec_for(
        (gb, 1), ("batch", "seq"), mesh, rules))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    fn = _with_activation_ctx(make_serve_step(model), mesh, rules)
    return Cell(arch, shape, cfg, fn,
                (p_abs, c_abs, tokens, pos),
                (p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
                (tok_sh, None, c_sh), meta)


def _rwkv_chunk(cfg: ModelConfig, cell: ShapeCell) -> int | None:
    """Chunked (block-parallel) RWKV for full-sequence cells."""
    if cfg.family != "ssm" or cell.kind == "decode":
        return None
    return 256 if cell.seq_len % 256 == 0 else None
