"""The language model: embeddings -> scanned period stacks -> logits.

Design notes (scale posture):

  * All layer stacks are ``lax.scan`` over *periods* with stacked
    parameters, so lowering/compile cost is O(period), not O(depth) —
    required for the 64-layer qwen2.5-32b dry-run on one CPU core.
  * Heterogeneous architectures (Jamba's 1:7 attn:mamba interleave with
    MoE every other layer) unroll the repeating pattern *inside* the
    scanned period.
  * The vocab is padded up to a multiple of ``VOCAB_PAD`` so the
    embedding/lm_head shard evenly on the model axis (Megatron-style);
    labels never index the padding.
  * Modality frontends ([audio]/[vlm]) are stubs per the brief: the
    batch carries precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models import params as prm
from repro.models.blocks import (LayerDesc, block_decode, block_forward,
                                 block_prefill, block_specs, init_cache)
from repro.models.config import ModelConfig
from repro.models.layers import (embed_specs, embed_tokens, logits_out,
                                 norm_spec, rmsnorm)
from repro.models.params import Spec, stack_specs

VOCAB_PAD = 2048


@dataclasses.dataclass(frozen=True)
class Stage:
    name: str
    descs: tuple[LayerDesc, ...]
    n_periods: int
    causal: bool = True


def _padded_vocab(v: int) -> int:
    return (v + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


def _period_layout(cfg: ModelConfig) -> tuple[LayerDesc, ...]:
    """Repeating layer pattern (length divides n_layers)."""
    kinds = cfg.block_kinds()
    period = len(cfg.pattern) if cfg.pattern else 1
    if cfg.moe is not None:
        # MoE cadence must align with the period.
        import math
        period = math.lcm(period, cfg.moe_every)
    assert cfg.n_layers % period == 0
    descs = []
    for i in range(period):
        descs.append(LayerDesc(kind=kinds[i], moe=cfg.is_moe_layer(i),
                               cross=cfg.family == "encdec",
                               causal=True))
    return tuple(descs)


class LM:
    """Decoder LM; also hosts enc-dec (whisper) and VLM variants."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = dataclasses.replace(cfg, vocab=_padded_vocab(cfg.vocab))
        self.vocab_real = cfg.vocab
        descs = _period_layout(self.cfg)
        self.stages = [Stage("decoder", descs,
                             self.cfg.n_layers // len(descs))]
        if self.cfg.family == "encdec":
            enc_desc = (LayerDesc(kind="attn", causal=False),)
            self.enc_stage = Stage("encoder", enc_desc,
                                   self.cfg.n_encoder_layers,
                                   causal=False)
        else:
            self.enc_stage = None

    # -- parameters --------------------------------------------------------
    def specs(self) -> dict:
        cfg = self.cfg
        out: dict = {"embed": embed_specs(cfg)}
        for st in [s for s in [self.enc_stage] if s] + self.stages:
            period = {str(i): block_specs(cfg, d)
                      for i, d in enumerate(st.descs)}
            out[st.name] = stack_specs(period, st.n_periods)
        out["final_norm"] = norm_spec(cfg.d_model)
        if self.enc_stage:
            out["enc_norm"] = norm_spec(cfg.d_model)
        return out

    def init(self, key: jax.Array) -> dict:
        return prm.init(self.specs(), key,
                        dtype=jnp.dtype(self.cfg.param_dtype))

    def param_axes(self) -> dict:
        return prm.axes(self.specs())

    def abstract_params(self) -> dict:
        return prm.abstract(self.specs(),
                            dtype=jnp.dtype(self.cfg.param_dtype))

    def n_params(self) -> int:
        return prm.count(self.specs())

    # -- stacks --------------------------------------------------------------
    def _run_stage(self, stage: Stage, p_stage: dict, x: jax.Array,
                   positions: jax.Array,
                   memory: jax.Array | None = None,
                   memory_valid: jax.Array | None = None,
                   rwkv_chunk: int | None = None):
        cfg = self.cfg

        def period_fn(x, p_period):
            aux = 0.0
            for i, desc in enumerate(stage.descs):
                d = dataclasses.replace(desc, causal=stage.causal)
                x, a = block_forward(
                    p_period[str(i)], x, cfg, d, positions,
                    memory=memory, memory_valid=memory_valid,
                    rwkv_chunk=rwkv_chunk)
                aux = aux + a
            return x, aux

        if cfg.remat:
            period_fn = jax.checkpoint(period_fn)

        def scan_body(carry, p_period):
            x, aux = carry
            x, a = period_fn(x, p_period)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, 0.0), p_stage)
        return x, aux

    # -- embedding frontends ----------------------------------------------------
    def _embed_inputs(self, params: dict, batch: dict):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        tok = embed_tokens(params["embed"], batch["tokens"], dt)
        n_front = 0
        if cfg.family == "vlm":
            fe = batch["frontend"].astype(dt) @ \
                params["embed"]["frontend_proj"].astype(dt)
            tok = jnp.concatenate([fe, tok], axis=1)
            n_front = fe.shape[1]
        return tok, n_front

    def _encode(self, params: dict, batch: dict):
        """Encoder side (whisper): frontend embeddings -> memory."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = batch["frontend"].astype(dt) @ \
            params["embed"]["frontend_proj"].astype(dt)
        pos = jnp.arange(x.shape[1])
        x, _ = self._run_stage(self.enc_stage, params["encoder"], x, pos)
        return rmsnorm(x, params["enc_norm"], cfg.rms_eps)

    # -- training forward ----------------------------------------------------
    def forward(self, params: dict, batch: dict,
                rwkv_chunk: int | None = None):
        """Returns (logits over text positions, aux losses)."""
        cfg = self.cfg
        memory = None
        if self.enc_stage is not None:
            memory = self._encode(params, batch)
        x, n_front = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        x, aux = self._run_stage(self.stages[0], params["decoder"], x,
                                 positions, memory=memory,
                                 rwkv_chunk=rwkv_chunk)
        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        if n_front:
            x = x[:, n_front:]
        return logits_out(params["embed"], x, cfg), aux

    def loss(self, params: dict, batch: dict,
             rwkv_chunk: int | None = None):
        """Next-token CE (+ z-loss + MoE aux). labels: (B, S) int32,
        -1 = ignore."""
        cfg = self.cfg
        logits, aux = self.forward(params, batch, rwkv_chunk=rwkv_chunk)
        labels = batch["labels"]
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(
            lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        ce = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        zl = cfg.z_loss * ((lse ** 2) * mask).sum() / \
            jnp.maximum(mask.sum(), 1.0)
        total = ce + zl + aux
        return total, {"ce": ce, "z_loss": zl, "aux": aux}

    # -- serving ----------------------------------------------------------------
    def init_caches(self, batch: int, t_max: int,
                    n_memory: int = 0) -> list:
        """Stacked (n_periods-leading) cache pytrees per stage."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        st = self.stages[0]

        def one(desc):
            return init_cache(cfg, desc, batch, t_max, n_memory, dt)

        period = {str(i): one(d) for i, d in enumerate(st.descs)}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (st.n_periods, *a.shape)).copy(), period)

    def prefill(self, params: dict, batch: dict, t_max: int,
                rwkv_chunk: int | None = None):
        """Run the prompt; returns (last-position logits, caches)."""
        cfg = self.cfg
        memory = None
        if self.enc_stage is not None:
            memory = self._encode(params, batch)
        x, n_front = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        st = self.stages[0]

        def period_fn(x, p_period):
            caches = {}
            for i, desc in enumerate(st.descs):
                x, _, c = block_prefill(
                    p_period[str(i)], x, cfg, desc, positions, t_max,
                    memory=memory, rwkv_chunk=rwkv_chunk)
                caches[str(i)] = c
            return x, caches

        def scan_body(x, p_period):
            return period_fn(x, p_period)

        x, caches = jax.lax.scan(scan_body, x, params["decoder"])
        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        logits = logits_out(params["embed"], x[:, -1:], cfg)
        return logits, caches

    def decode_step(self, params: dict, tokens: jax.Array,
                    pos: jax.Array, caches):
        """One token for every sequence. tokens: (B, 1). pos: scalar
        (position of the new token). Returns (logits, new caches)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = embed_tokens(params["embed"], tokens, dt)
        st = self.stages[0]

        def scan_body(x, per):
            p_period, cache_period = per
            new_caches = {}
            for i, desc in enumerate(st.descs):
                x, c = block_decode(p_period[str(i)], x, cfg, desc,
                                    pos, cache_period[str(i)])
                new_caches[str(i)] = c
            return x, new_caches

        x, new_caches = jax.lax.scan(scan_body, x,
                                     (params["decoder"], caches))
        x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
        return logits_out(params["embed"], x, cfg), new_caches
