"""The evaluation service host: ``python -m repro.engine.server``.

One :class:`EvalServer` turns any registered evaluation backend into a
network service speaking the :mod:`repro.engine.rpc` wire protocol:
it accepts TCP connections, performs the ``store_fingerprint``
handshake (a server only evaluates for clients whose graph / machine /
objective content-address matches its own — mismatches are *refused*,
never silently mis-served), then answers ``EVAL`` frames of canonical
``(k, 2, N)`` int32 encodings with ``RESULT`` frames of base times.

The inner evaluator is an ordinary :func:`repro.engine.make_evaluator`
backend (``sim`` / ``vectorized`` / a ``pool`` of workers), so a host
gets the full evaluator contract for free: its own memo cache (a key
two clients both miss is simulated once), and — with ``--store`` — the
shared persistent :class:`~repro.engine.store.EvalStore`: every host
in a fleet can point at one store file, because appends are whole
O_APPEND records (concurrent-writer safe) and duplicate keys resolve
first-record-wins. Base times only ever travel the wire — measurement
noise stays client-side, seeded per (canonical key, draw index) — so a
fleet-evaluated search is bit-identical to a local one.

Run a host::

    PYTHONPATH=src python -m repro.engine.server \\
        --space halo3d --backend vectorized --port 9876 \\
        --store /shared/halo3d.evalstore

and point a search at the fleet::

    python examples/schedule_search.py --space halo3d --backend rpc \\
        --hosts hostA:9876,hostB:9876

``--port 0`` binds an ephemeral port; the chosen address is printed as
the first stdout line (``repro-eval-server listening on HOST:PORT``),
which :func:`spawn_server_process` parses — the CI smoke job and the
benchmarks spin up localhost fleets this way. ``--delay`` injects
artificial per-request latency (a deterministic straggler) for testing
the client's hedging and deadline paths.
"""
from __future__ import annotations

import argparse
import os
import re
import socket
import subprocess
import sys
import threading
import time

from repro.engine.rpc import (MSG_EVAL, MSG_HELLO, decode_eval,
                              decode_hello, encode_error, encode_refuse,
                              encode_result, encode_welcome, recv_frame,
                              send_frame, RpcProtocolError)

_LISTEN_RE = re.compile(
    r"repro-eval-server listening on (\S+:\d+)")


class EvalServer:
    """One evaluation host: a TCP front over a local backend.

    ``space`` is anything :func:`repro.space.base.as_space` accepts;
    ``backend`` / ``backend_kwargs`` / ``store`` / ``store_path`` are
    forwarded to :func:`repro.engine.make_evaluator`. ``port=0`` binds
    an ephemeral port (read :attr:`addr` after construction).
    Connections are served one thread each; evaluation is serialized
    under one lock (fleet parallelism comes from running many server
    *processes*, not threads — see :func:`spawn_server_process`).
    ``delay`` sleeps that many seconds before each evaluation, turning
    the host into a deterministic straggler for hedging tests.
    """

    def __init__(self, space, backend: str = "sim",
                 host: str = "127.0.0.1", port: int = 0,
                 machine=None, backend_kwargs: dict | None = None,
                 store=None, store_path: "str | None" = None,
                 delay: float = 0.0):
        from repro.engine import make_evaluator
        from repro.space.base import as_space

        self.space = as_space(space)
        kwargs = dict(backend_kwargs or {})
        if store is not None:
            kwargs["store"] = store
        if store_path is not None:
            kwargs["store_path"] = store_path
        self.backend = backend
        self.evaluator = make_evaluator(self.space, backend,
                                        machine=machine, **kwargs)
        self.delay = delay
        self._eval_lock = threading.Lock()
        self._lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._lsock.bind((host, port))
        self._lsock.listen(16)
        self.host, self.port = self._lsock.getsockname()[:2]
        self.addr = f"{self.host}:{self.port}"
        self._closed = False
        self._conns: set[socket.socket] = set()
        self._accept_thread: threading.Thread | None = None
        # service meters (per-host half of the fleet's QoS signal):
        self.n_connections = 0
        self.n_refused = 0
        self.n_requests = 0
        self.n_evaluated = 0

    # -- serving -------------------------------------------------------------
    def start(self) -> "EvalServer":
        """Serve in a background thread (in-process hosts for tests)."""
        t = threading.Thread(target=self.serve_forever, daemon=True)
        t.start()
        self._accept_thread = t
        return self

    def serve_forever(self) -> None:
        while not self._closed:
            try:
                conn, _ = self._lsock.accept()
            except OSError:
                break                      # listener closed
            t = threading.Thread(target=self._serve_conn, args=(conn,),
                                 daemon=True)
            t.start()

    def _serve_conn(self, conn: socket.socket) -> None:
        self._conns.add(conn)
        self.n_connections += 1
        try:
            conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            mtype, body = recv_frame(conn)
            if mtype != MSG_HELLO:
                send_frame(conn, encode_refuse(
                    f"expected HELLO, got message type {mtype}"))
                self.n_refused += 1
                return
            fp = decode_hello(body)
            mine = self.evaluator.store_fingerprint
            if fp != mine:
                send_frame(conn, encode_refuse(
                    f"fingerprint mismatch: client {fp.hex()} vs "
                    f"server {mine.hex()} (space {self.space.name!r}, "
                    f"backend {self.backend!r}) — different graph, "
                    "machine, or objective"))
                self.n_refused += 1
                return
            send_frame(conn, encode_welcome({
                "space": self.space.name, "backend": self.backend,
                "pid": os.getpid()}))
            while not self._closed:
                mtype, body = recv_frame(conn)
                if mtype != MSG_EVAL:
                    raise RpcProtocolError(
                        f"expected EVAL, got message type {mtype}")
                sid, enc = decode_eval(body)
                self.n_requests += 1
                try:
                    if self.delay:
                        time.sleep(self.delay)
                    candidates = self.space.decode_batch(enc)
                    with self._eval_lock:
                        times = self.evaluator.evaluate(candidates)
                except Exception as e:      # answer, don't die: the
                    send_frame(conn, encode_error(   # client retries
                        sid, f"{type(e).__name__}: {e}"))
                    continue
                self.n_evaluated += len(times)
                send_frame(conn, encode_result(sid, times))
        except (ConnectionError, OSError, RpcProtocolError):
            pass                           # client went away / garbage
        finally:
            self._conns.discard(conn)
            try:
                conn.close()
            except OSError:
                pass

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop accepting, reset live connections, release the backend.
        Idempotent. In-flight clients see a connection error and fail
        over (the client's retry / hedging path, not data loss)."""
        if self._closed:
            return
        self._closed = True
        try:
            self._lsock.close()
        except OSError:
            pass
        for conn in list(self._conns):
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                conn.close()
            except OSError:
                pass
        self.evaluator.close()

    def __enter__(self) -> "EvalServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# -- multi-process fleets -----------------------------------------------------

class ServerProcess:
    """Handle on a ``python -m repro.engine.server`` subprocess."""

    def __init__(self, proc: subprocess.Popen, addr: str):
        self.proc = proc
        self.addr = addr

    def terminate(self) -> None:
        """Kill the host (the "server dies mid-search" event)."""
        if self.proc.poll() is None:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(timeout=10)

    close = terminate

    def __enter__(self) -> "ServerProcess":
        return self

    def __exit__(self, *exc) -> None:
        self.terminate()


def spawn_server_process(space: str, *, backend: str = "sim",
                         n_streams: int | None = None,
                         store_path: "str | None" = None,
                         delay: float = 0.0, host: str = "127.0.0.1",
                         startup_timeout: float = 120.0
                         ) -> ServerProcess:
    """Launch one evaluation host as a subprocess on an ephemeral port.

    ``space`` is a registry name (``repro.space.SPACES``). Blocks until
    the child prints its listen address, then returns a handle whose
    ``addr`` goes straight into ``RpcEvaluator(hosts=[...])``. The
    child inherits this interpreter and a ``PYTHONPATH`` covering the
    ``repro`` package, so it works from a source checkout and CI alike.
    """
    import repro

    # repro is a namespace package (__file__ is None): its search path
    # lists the package directories; the import root is one level up.
    src = os.path.dirname(os.path.abspath(next(iter(repro.__path__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.engine.server",
           "--space", space, "--backend", backend,
           "--host", host, "--port", "0"]
    if n_streams is not None:
        cmd += ["--n-streams", str(n_streams)]
    if store_path is not None:
        cmd += ["--store", store_path]
    if delay:
        cmd += ["--delay", str(delay)]
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE, text=True,
                            env=env)
    deadline = time.monotonic() + startup_timeout
    line = ""
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break
        m = _LISTEN_RE.search(line)
        if m:
            return ServerProcess(proc, m.group(1))
    proc.terminate()
    raise RuntimeError(
        f"evaluation server for space {space!r} never announced its "
        f"address (last stdout line: {line!r})")


# -- CLI ----------------------------------------------------------------------

def main(argv: "list[str] | None" = None) -> None:
    ap = argparse.ArgumentParser(
        prog="python -m repro.engine.server",
        description="Host one evaluation backend as a TCP service "
                    "speaking the repro.engine.rpc protocol.")
    ap.add_argument("--space", required=True,
                    help="registered design space to evaluate "
                         "(repro.space registry, e.g. halo3d)")
    ap.add_argument("--backend", default="sim",
                    help="inner evaluation backend (repro.engine "
                         "registry; default sim)")
    ap.add_argument("--n-streams", type=int, default=None,
                    help="stream count for schedule spaces (default 2, "
                         "the paper's setting)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port; the chosen "
                         "address is printed on the first stdout line")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="shared persistent EvalStore path (safe to "
                         "point every host in the fleet at one file)")
    ap.add_argument("--delay", type=float, default=0.0,
                    help="artificial seconds of latency per request "
                         "(a deterministic straggler, for testing "
                         "client hedging)")
    args = ap.parse_args(argv)

    from repro.space import make_space

    try:
        space = make_space(args.space, n_streams=args.n_streams) \
            if args.n_streams is not None else make_space(args.space)
    except TypeError:                  # parameter grids take no streams
        space = make_space(args.space)
    server = EvalServer(space, backend=args.backend, host=args.host,
                        port=args.port, store_path=args.store,
                        delay=args.delay)
    fp = server.evaluator.store_fingerprint.hex()
    print(f"repro-eval-server listening on {server.addr} "
          f"space={server.space.name} backend={args.backend} "
          f"fingerprint={fp}", flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.close()


if __name__ == "__main__":
    main()
