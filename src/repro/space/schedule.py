"""The paper's (traversal order x stream binding) spaces as a
:class:`~repro.space.base.DesignSpace`.

This is the first registered instance of the protocol and the
bit-compatibility anchor of the refactor: every method reproduces the
behavior that used to live inline in the evaluator/strategy stack —
``encode_batch`` is the evaluator's vectorized canonical encoding,
``moves`` is the strategies' ``eligible_items``, ``random_candidate``
/ ``mutate`` consume the RNG exactly like the historical helpers,
``fingerprint`` delegates to the graph hash of
:func:`repro.engine.store.store_fingerprint` unchanged — so searches
over schedule spaces are byte-identical to the pre-protocol pipeline
(cache keys, store addresses, features, trajectories; locked by
tests/test_design_space.py).

The module also hosts the canonical-identity helpers themselves
(:func:`canonical_key`, :func:`eligible_items`,
:func:`random_schedule`); :mod:`repro.engine.base` and
:mod:`repro.search.strategy` re-export them from here.
"""
from __future__ import annotations

import random
from typing import Iterator, Sequence

import numpy as np

from repro.core.costmodel import op_durations, simulate
from repro.core.dag import BoundOp, Graph, OpKind, Schedule
from repro.core.enumerate import enumerate_schedules
from repro.core.features import (FeatureBasis, FeatureMatrix,
                                 FeatureUniverse, apply_features,
                                 featurize)
from repro.space.base import DesignSpace


def canonical_key(schedule: Schedule) -> tuple:
    """Hashable identity under stream relabeling (transposition key).

    Inlines :func:`~repro.core.dag.canonicalize_streams`' first-use
    relabeling without building intermediate ``BoundOp`` objects. The
    evaluator hot path does NOT go through here — it derives the same
    identity for a whole batch at once in
    :meth:`ScheduleSpace.encode_batch` (whose relabel must stay
    equivalent to this one; the bijection-awareness tests lock both).
    This function is the per-schedule form for everyone else: surrogate
    pool dedup, benchmarks, tests.
    """
    mapping: dict[int, int] = {}
    out = []
    for it in schedule.items:
        s = it.stream
        if s is None:
            out.append((it.name, None))
        else:
            c = mapping.get(s)
            if c is None:
                c = mapping[s] = len(mapping)
            out.append((it.name, c))
    return tuple(out)


def eligible_items(graph: Graph, prefix: list[BoundOp],
                   n_streams: int) -> list[BoundOp]:
    """Eligible next items from a prefix, stream-bijection pruned.

    GPU ops may bind to any stream already in use, or the lowest-numbered
    unused stream — the canonical first-use labeling of §III-C2, so every
    complete schedule built through this helper is canonical by
    construction. Shared by MCTS expansion, random rollouts, and greedy
    completion.
    """
    scheduled = {b.name for b in prefix}
    used = sorted({b.stream for b in prefix if b.stream is not None})
    options: list[BoundOp] = []
    for name in graph.eligible(scheduled):
        if graph.ops[name].kind is OpKind.GPU:
            for s in used:
                options.append(BoundOp(name, s))
            if len(used) < n_streams:
                options.append(BoundOp(name, len(used)))
        else:
            options.append(BoundOp(name))
    return options


def random_schedule(graph: Graph, n_streams: int,
                    rng: random.Random) -> Schedule:
    """Uniform random canonical schedule (the MCTS rollout policy)."""
    prefix: list[BoundOp] = []
    while True:
        options = eligible_items(graph, prefix, n_streams)
        if not options:
            return Schedule(tuple(prefix))
        prefix.append(rng.choice(options))


class ScheduleSpace(DesignSpace):
    """Schedules of ``graph`` over ``n_streams`` streams (§III-C)."""

    def __init__(self, graph: Graph, n_streams: int = 2,
                 name: str | None = None):
        if n_streams < 1:
            raise ValueError("n_streams must be >= 1")
        self.graph = graph
        self.n_streams = n_streams
        self.name = name if name is not None else \
            f"schedule:{graph.n_vertices()}ops:{n_streams}streams"
        self._op_id = {n: i for i, n in enumerate(graph.ops)}

    # -- identity ----------------------------------------------------------
    def encode_batch(self, schedules: Sequence[Schedule]
                     ) -> tuple[list[bytes], np.ndarray]:
        """(keys, encoding) for a batch of complete schedules.

        The encoding is ``(B, 2, N)`` int32: ``enc[b, 0]`` the op id
        per position, ``enc[b, 1]`` the *canonical* (first-use-
        relabeled, §III-C2) stream per position, -1 for CPU ops; each
        row's bytes are the schedule's cache key — the same identity
        :func:`canonical_key` computes, in a form the whole batch
        shares with the array backends. The first-use relabel is itself
        vectorized (first-occurrence position per stream,
        stable-argsorted into ranks) over the *distinct* stream ids
        present in the batch — never ``max(id) + 1`` slots — so sparse
        ids (stream ``10**6``) cost what dense ids cost instead of
        allocating gigabytes.
        """
        op_id = self._op_id
        n = len(op_id)
        b_n = len(schedules)
        ids: list[int] = []
        sts: list[int] = []
        ext_i, ext_s = ids.extend, sts.extend
        for sched in schedules:
            items = sched.items
            if len(items) != n:
                raise ValueError(
                    f"evaluators require complete schedules: got "
                    f"{len(items)} items for a {n}-op graph")
            ext_i([op_id[i.name] for i in items])
            ext_s([-1 if i.stream is None else i.stream for i in items])
        enc = np.empty((b_n, 2, n), dtype=np.int32)
        enc[:, 0, :] = np.fromiter(ids, np.int32,
                                   count=b_n * n).reshape(b_n, n)
        enc[:, 1, :] = np.fromiter(sts, np.int32,
                                   count=b_n * n).reshape(b_n, n)
        streams = enc[:, 1, :]
        uniq = np.unique(streams)
        uniq = uniq[uniq >= 0]               # distinct real ids, sorted
        if uniq.size:
            d = uniq.size
            pos = np.arange(n, dtype=np.int32)
            first = np.where(
                streams[:, :, None] == uniq[None, None, :],
                pos[None, :, None], n).min(axis=1)      # (B, D)
            # Ids absent from a row have first == n and stable-sort
            # last, so present ids get ranks 0..p-1 in first-use order
            # (same labels the dense 0..max relabel assigned) and the
            # padding ranks are never looked up.
            by_first = np.argsort(first, axis=1, kind="stable")
            label = np.empty_like(by_first)
            np.put_along_axis(
                label, by_first,
                np.arange(d)[None, :], axis=1)
            col = np.searchsorted(
                uniq, np.where(streams < 0, uniq[0], streams))
            row_base = (np.arange(b_n) * d)[:, None]
            enc[:, 1, :] = np.where(
                streams >= 0,
                label.ravel()[row_base + col],
                -1)
        return [row.tobytes() for row in enc], enc

    def decode_batch(self, enc: np.ndarray) -> list[Schedule]:
        """Schedules back from ``encode_batch`` rows.

        Accepts ``(B, 2, N)`` or per-row flattened ``(B, 2*N)`` int32
        (the cache-key bytes reinterpreted). Streams come back exactly
        as encoded — the canonical first-use labels — so the result is
        each row's canonical representative schedule: identical cache
        key, identical expanded sequence and feature vector (sync
        insertion depends only on same-stream relations, never on
        stream *ids*).
        """
        enc = np.asarray(enc, dtype=np.int32)
        names = list(self._op_id)
        n = len(names)
        enc = enc.reshape(-1, 2, n)
        out: list[Schedule] = []
        for row in enc:
            out.append(Schedule(tuple(
                BoundOp(names[int(o)], None if s < 0 else int(s))
                for o, s in zip(row[0], row[1]))))
        return out

    def candidate_key(self, schedule: Schedule) -> tuple:
        return canonical_key(schedule)

    def tie_key(self, schedule: Schedule) -> tuple:
        """Canonical item sequence with ``None`` streams as -1, so
        tuples compare without type errors (CPU ops sort first)."""
        return tuple((name, -1 if s is None else s)
                     for name, s in canonical_key(schedule))

    def describe(self, schedule: Schedule) -> str:
        return " ".join(str(i) for i in schedule.items)

    # -- moves -------------------------------------------------------------
    def moves(self, prefix: list[BoundOp]) -> list[BoundOp]:
        return eligible_items(self.graph, prefix, self.n_streams)

    def move_key(self, move: BoundOp) -> tuple:
        return (move.name, move.stream)

    def finalize(self, prefix: list[BoundOp]) -> Schedule:
        return Schedule(tuple(prefix))

    def candidate_moves(self, schedule: Schedule) -> Sequence[BoundOp]:
        return schedule.items

    def enumerate_candidates(self) -> Iterator[Schedule]:
        return enumerate_schedules(self.graph, self.n_streams)

    # -- featurization (§IV-B order/stream pairs) --------------------------
    def feature_basis(self) -> FeatureBasis:
        return FeatureBasis(self.graph)

    def featurize(self, schedules: Sequence[Schedule]) -> FeatureMatrix:
        return featurize(self.graph, list(schedules))

    def apply_features(self, schedules: Sequence[Schedule],
                       features: list) -> np.ndarray:
        return apply_features(self.graph, list(schedules), features)

    def feature_universe(self) -> FeatureUniverse:
        return FeatureUniverse(self.graph)

    # -- evaluation support ------------------------------------------------
    def durations(self, machine) -> dict:
        return op_durations(self.graph, machine)

    def fingerprint(self, machine, durations: dict,
                    objective: str) -> bytes:
        # The graph hash is the pre-protocol content address; delegating
        # keeps every existing store file warm. Runtime import: the
        # engine package imports this module at load time.
        from repro.engine.store import store_fingerprint
        return store_fingerprint(self.graph, machine, durations,
                                 objective)

    def analytic_cost(self, schedule: Schedule, machine,
                      durations: dict) -> float:
        return simulate(self.graph, schedule, machine,
                        durations=durations).makespan
