"""Compatibility shim: rulesets now live in :mod:`repro.rules.rulesets`.

The §IV-D/§V design-rule generation moved into the rules distillation
subsystem — :mod:`repro.rules` — next to the tree trainer it consumes
and the :func:`repro.rules.distill` pipeline that renders
:class:`~repro.rules.pipeline.RuleReport`. Import from
:mod:`repro.rules` (or keep importing from here / :mod:`repro.core`;
both stay supported, with a :class:`DeprecationWarning` so the shim
can eventually be deleted — every name here *is* the
:mod:`repro.rules.rulesets` object, asserted by tests/test_shims.py).
"""
import warnings

warnings.warn(
    "repro.core.rules is a deprecated shim; import RuleSet/"
    "extract_rulesets/... from repro.rules (new home: "
    "repro.rules.rulesets)",
    DeprecationWarning, stacklevel=2)

from repro.rules.rulesets import (Rule, RuleSet, annotate_vs_canonical,
                                  class_range_accuracy,
                                  class_range_accuracy_loop,
                                  extract_rulesets, render_rules_table,
                                  rules_by_class)

__all__ = ["Rule", "RuleSet", "annotate_vs_canonical",
           "class_range_accuracy", "class_range_accuracy_loop",
           "extract_rulesets", "render_rules_table", "rules_by_class"]
