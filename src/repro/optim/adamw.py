"""AdamW with decoupled weight decay, global-norm clipping, and
warmup+cosine schedule — from scratch (no optax in the container)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step: jax.Array) -> jax.Array:
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(1, warmup_steps)
        frac = (step - warmup_steps) / max(1, total_steps - warmup_steps)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac) *
                         0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float | None = 1.0
    # bf16 params + f32 master copies (kept in the optimizer state):
    # halves every weight all-gather / TP collective while keeping
    # full-precision accumulation. Use with ModelConfig.param_dtype =
    # "bfloat16".
    master_weights: bool = False

    def init(self, params) -> dict:
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state = {"mu": zeros(), "nu": zeros(),
                 "count": jnp.zeros((), jnp.int32)}
        if self.master_weights:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def _lr(self, count: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(count)
        return jnp.asarray(self.learning_rate, jnp.float32)

    def update(self, grads, state: dict, params):
        """Returns (updates, new_state); apply with params + updates."""
        count = state["count"] + 1
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm /
                                jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) *
            jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - b1 ** c
        bc2 = 1 - b2 ** c
        lr = self._lr(count)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        anchor = state.get("master", params)
        updates = jax.tree.map(upd, anchor, mu, nu)
        new_state = {"mu": mu, "nu": nu, "count": count}
        if self.master_weights:
            new_state["master"] = jax.tree.map(
                lambda m, u: m + u, state["master"], updates)
        return updates, new_state

    def step(self, grads, state: dict, params):
        """(new_params, new_state) — handles master-weight casting."""
        updates, new_state = self.update(grads, state, params)
        if self.master_weights:
            new_params = jax.tree.map(
                lambda m, p: m.astype(p.dtype), new_state["master"],
                params)
        else:
            new_params = apply_updates(params, updates)
        return new_params, new_state


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                        params, updates)
