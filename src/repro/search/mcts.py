"""Monte-Carlo tree search as a :class:`SearchStrategy` (paper §III-C).

Tree nodes are schedule prefixes P_k. The four phases:

  selection      recursively maximize (exploration + exploitation):
                   exploration  = c * sqrt(ln N / n),  c = sqrt(2)
                                  (-inf once the child subtree is fully
                                   explored)
                   exploitation = (t_max^c - t_min^c) / (t_max^p - t_min^p)
                                  when both child and parent have >= 2
                                  rollouts, else 1
                 i.e. favor children whose subtree *covers* more of the
                 parent's observed time range — regions where decisions
                 matter — not children that are merely fast. Recursion
                 stops at any node with a zero-rollout child.
  expansion      materialize one zero-rollout child of the selected node
                 (children are the DAG-eligible next ops; GPU ops are bound
                 to a stream, with stream-bijection duplicates pruned via
                 canonical first-use labeling).
  rollout        complete the prefix uniformly at random and add the
                 rollout path to the tree.
  backprop       update t_min/t_max on every node along the path.

The strategy split: ``propose`` runs selection + expansion + rollout and
returns the completed candidates; ``observe`` backpropagates the
measured time along the stored rollout path. With ``propose(1)`` per
evaluation this is exactly the paper's loop; larger proposal batches
trade a little selection fidelity (tree statistics lag by up to one
batch) for batched evaluation throughput.

The tree is space-generic: "prefixes" are move sequences of any
:class:`~repro.space.base.DesignSpace` (DAG-eligible ``BoundOp``\\ s
for schedule spaces, per-dimension value assignments for parameter
grids), expanded through ``space.moves`` and keyed by
``space.move_key``.
"""
from __future__ import annotations

import math
import random

from repro.core.dag import BoundOp, Graph, Schedule
from repro.space.base import DesignSpace, as_space

EXPLORATION_C = math.sqrt(2.0)


class Node:
    __slots__ = ("item", "parent", "children", "n_rollouts",
                 "t_min", "t_max", "fully_explored", "_expandable")

    def __init__(self, item: BoundOp | None, parent: "Node | None"):
        self.item = item
        self.parent = parent
        self.children: dict[tuple, Node] = {}
        self.n_rollouts = 0
        self.t_min = math.inf
        self.t_max = -math.inf
        self.fully_explored = False
        self._expandable: list[BoundOp] | None = None  # lazily computed

    def prefix(self) -> list[BoundOp]:
        out: list[BoundOp] = []
        node = self
        while node.parent is not None:
            out.append(node.item)
            node = node.parent
        out.reverse()
        return out


class MCTSSearch:
    """Paper-faithful MCTS behind the strategy protocol."""

    def __init__(self, graph: "Graph | DesignSpace",
                 n_streams: int | None = None, seed: int = 0):
        self.space = as_space(graph, n_streams)
        self.graph = getattr(self.space, "graph", None)
        self.n_streams = getattr(self.space, "n_streams", None)
        self.rng = random.Random(seed)
        self.root = Node(None, None)
        # Rollout leaves awaiting their observation, by candidate key.
        self._pending: dict[tuple, Node] = {}

    # -- phase 1: selection ------------------------------------------------
    def _value(self, parent: Node, child: Node) -> float:
        if child.fully_explored:
            explore = -math.inf
        elif child.n_rollouts == 0:
            explore = math.inf
        else:
            explore = EXPLORATION_C * math.sqrt(
                math.log(parent.n_rollouts) / child.n_rollouts)
        if child.n_rollouts >= 2 and parent.n_rollouts >= 2 and \
                parent.t_max > parent.t_min:
            exploit = (child.t_max - child.t_min) / \
                (parent.t_max - parent.t_min)
        else:
            exploit = 1.0
        return explore + exploit

    def _select(self) -> Node:
        node = self.root
        while True:
            opts = self._expandable(node)
            # Terminate at any node that still has an unmaterialized or
            # zero-rollout child.
            if any(key not in node.children or
                   node.children[key].n_rollouts == 0
                   for key in (self.space.move_key(o) for o in opts)):
                return node
            if not node.children:
                return node  # complete leaf (shouldn't be selected; guard)
            node = max(node.children.values(),
                       key=lambda ch: self._value(node, ch))

    def _expandable(self, node: Node) -> list[BoundOp]:
        if node._expandable is None:
            node._expandable = self.space.moves(node.prefix())
        return node._expandable

    # -- phase 2: expansion ------------------------------------------------
    def _expand(self, node: Node) -> Node:
        opts = self._expandable(node)
        move_key = self.space.move_key
        fresh = [o for o in opts
                 if move_key(o) not in node.children or
                 node.children[move_key(o)].n_rollouts == 0]
        if not fresh:  # fully rolled-out interior node: descend randomly
            return node
        choice = self.rng.choice(fresh)
        key = move_key(choice)
        if key not in node.children:
            node.children[key] = Node(choice, node)
        return node.children[key]

    # -- phase 3: rollout --------------------------------------------------
    def _rollout(self, node: Node) -> tuple[Node, Schedule]:
        """Complete the prefix randomly, materializing path nodes."""
        cur = node
        while True:
            opts = self._expandable(cur)
            if not opts:
                break
            choice = self.rng.choice(opts)
            key = self.space.move_key(choice)
            if key not in cur.children:
                cur.children[key] = Node(choice, cur)
            cur = cur.children[key]
        return cur, self.space.finalize(cur.prefix())

    # -- phase 4: backpropagation -------------------------------------------
    def _backprop(self, leaf: Node, t: float) -> None:
        node: Node | None = leaf
        while node is not None:
            node.n_rollouts += 1
            node.t_min = min(node.t_min, t)
            node.t_max = max(node.t_max, t)
            node = node.parent
        # Mark fully-explored subtrees bottom-up.
        node = leaf
        node.fully_explored = True  # complete program leaf
        node = node.parent
        while node is not None:
            opts = self._expandable(node)
            node.fully_explored = (
                len(node.children) == len(opts) and
                all(c.fully_explored for c in node.children.values()))
            if not node.fully_explored:
                break
            node = node.parent

    def _materialize(self, schedule: Schedule) -> Node:
        """Walk (creating as needed) the tree path for ``schedule``."""
        node = self.root
        for item in self.space.candidate_moves(schedule):
            key = self.space.move_key(item)
            if key not in node.children:
                node.children[key] = Node(item, node)
            node = node.children[key]
        return node

    # -- strategy protocol ---------------------------------------------------
    def propose(self, budget: int) -> list[Schedule]:
        out: list[Schedule] = []
        for _ in range(budget):
            if self.root.fully_explored:
                break
            node = self._select()
            node = self._expand(node)
            leaf, schedule = self._rollout(node)
            self._pending[self.space.candidate_key(schedule)] = leaf
            out.append(schedule)
        return out

    def observe(self, schedule: Schedule, time: float) -> None:
        leaf = self._pending.pop(self.space.candidate_key(schedule),
                                 None)
        if leaf is None:
            # Re-observation or an externally produced schedule: its tree
            # path is the schedule itself.
            leaf = self._materialize(schedule)
        self._backprop(leaf, time)

    def exhausted(self) -> bool:
        return self.root.fully_explored
