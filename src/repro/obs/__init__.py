"""Unified telemetry: spans, counters, and Perfetto traces for the
search → engine → rules pipeline.

Zero-dependency observability layer threaded through every subsystem:
the search driver's round loop, the evaluator batch path, the
persistent evaluation store, the kernel wallclock measurement phases,
and the rules distillation stages all emit hierarchical spans and
typed counters/gauges into one process-wide :class:`Telemetry`
registry with pluggable exporters (JSONL event log, Chrome
trace-event / Perfetto JSON, in-memory, plus a human
:meth:`~repro.obs.telemetry.Telemetry.summary` table).

The default registry is *disabled*: instrumentation points cost one
attribute check + a no-op call, and telemetry never feeds back into
what it observes — search results are byte-identical with or without
an exporter attached (locked by tests/test_obs.py). See README.md in
this package for the span taxonomy and how to open a trace in
Perfetto.
"""
from repro.obs.exporters import (Exporter, JsonlExporter, MemoryExporter,
                                 PerfettoExporter, load_trace)
from repro.obs.telemetry import (DISABLED, Counter, Gauge, Span,
                                 Telemetry, counter, current, enabled,
                                 event, gauge, set_current, span, use)

__all__ = [
    "Telemetry", "DISABLED", "Span", "Counter", "Gauge",
    "current", "set_current", "use", "span", "counter", "gauge",
    "event", "enabled",
    "Exporter", "JsonlExporter", "MemoryExporter", "PerfettoExporter",
    "load_trace",
]
