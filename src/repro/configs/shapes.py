"""Assigned input-shape cells and per-arch applicability.

LM transformer shapes are seq_len x global_batch. ``decode_*``/``long_*``
lower ``serve_step`` (one new token against a seq_len KV/state cache),
NOT ``train_step``. ``long_500k`` needs sub-quadratic attention: it runs
for SSM/hybrid archs only (skips recorded in DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}

# Archs whose unbounded-context layers are O(1)-state (SSM/hybrid):
# the only ones for which long_500k is a realisable configuration.
SUBQUADRATIC = {"rwkv6-3b", "jamba-v0.1-52b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True


def cells(archs: list[str]) -> list[tuple[str, str]]:
    """All runnable (arch, shape) cells, in stable order."""
    return [(a, s) for a in archs for s in SHAPES
            if applicable(a, s)]
