"""The DesignSpace protocol: registry, schedule-space bit-compat, and
parameter grids.

The schedule-space locks are THE refactor acceptance contract: a
search driven through an explicit :class:`ScheduleSpace` must be
byte-identical — (features, labels, times), cache/store accounting,
store fingerprints — to the historical graph-first calls on every
analytic backend, and the space's RNG consumption must match the
pre-protocol helpers exactly (same seeds -> same trajectories).
"""
import random

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro.core.costmodel import Machine, op_durations
from repro.engine.store import store_fingerprint
from repro.rules import distill
from repro.space import (SPACES, DesignSpace, ParamFeature, ParamSpace,
                         ScheduleSpace, as_space, demo_param_space,
                         make_space, random_schedule)


# -- registry / normalization -------------------------------------------------

def test_registry_has_the_shipped_spaces():
    assert {"spmv", "spmv_fine", "halo3d", "flash_attention",
            "spmv_mulsum", "pack", "demo"} <= set(SPACES)
    sp = make_space("spmv", n_streams=3)
    assert isinstance(sp, ScheduleSpace) and sp.n_streams == 3
    assert isinstance(make_space("demo"), ParamSpace)
    with pytest.raises(ValueError, match="unknown design space"):
        make_space("no-such-space")


def test_as_space_normalizes_graphs_and_passes_spaces_through():
    g = C.spmv_dag()
    sp = as_space(g)
    assert isinstance(sp, ScheduleSpace)
    assert sp.graph is g and sp.n_streams == 2     # historical default
    assert as_space(g, 3).n_streams == 3
    demo = demo_param_space()
    assert as_space(demo) is demo
    with pytest.raises(TypeError, match="n_streams"):
        as_space(demo, 2)
    with pytest.raises(TypeError):
        as_space(42)


# -- schedule-space bit-compat ------------------------------------------------

def test_schedule_space_fingerprint_is_the_graph_fingerprint():
    """Old store files must stay warm: the space's fingerprint equals
    the pre-protocol graph fingerprint byte for byte."""
    g = C.spmv_dag()
    m = Machine()
    durs = op_durations(g, m)
    sp = ScheduleSpace(g, 2)
    assert sp.fingerprint(m, durs, "analytic") \
        == store_fingerprint(g, m, durs, "analytic")


def test_schedule_space_rng_matches_historical_helpers():
    """random_candidate consumes the RNG exactly like random_schedule
    (same seed -> same schedule), so seeded searches reproduce."""
    g = C.spmv_dag()
    sp = ScheduleSpace(g, 2)
    for seed in range(5):
        a = sp.random_candidate(random.Random(seed))
        b = random_schedule(g, 2, random.Random(seed))
        assert a.items == b.items


@pytest.mark.parametrize("backend,kwargs", [
    ("sim", {}),
    ("vectorized", {}),
    ("pool", {"n_workers": 2, "min_shard": 1}),
])
def test_space_first_search_is_byte_identical_to_graph_first(
        backend, kwargs):
    """run_search(space, ...) == run_search(graph, ...) on every
    analytic backend: same (features, labels, times), same accounting."""
    g = C.spmv_dag()

    def run(target):
        strat = S.MCTSSearch(target, 2 if target is g else None, seed=4)
        return S.run_search(target, strat, budget=60, batch_size=4,
                            backend=backend,
                            backend_kwargs=dict(kwargs))

    a = run(g)
    b = run(ScheduleSpace(g, 2))
    assert a.times == b.times
    assert [s.items for s in a.schedules] \
        == [s.items for s in b.schedules]
    assert (a.cache_hits, a.cache_misses, a.store_hits) \
        == (b.cache_hits, b.cache_misses, b.store_hits)
    fa, la, ta = a.dataset()
    fb, lb, tb = b.dataset()
    assert ta.tobytes() == tb.tobytes()
    assert fa.X.tobytes() == fb.X.tobytes()
    assert fa.names() == fb.names()
    assert np.array_equal(la.labels, lb.labels)


def test_strategies_accept_spaces_and_graphs_interchangeably():
    g = C.spmv_dag()
    sp = ScheduleSpace(g, 2)
    for mk in (S.RandomSearch, S.ExhaustiveSearch):
        a = mk(g, 2) if mk is S.ExhaustiveSearch else mk(g, 2, seed=1)
        b = mk(sp) if mk is S.ExhaustiveSearch else mk(sp, seed=1)
        pa, pb = a.propose(12), b.propose(12)
        assert [s.items for s in pa] == [s.items for s in pb]


# -- ParamSpace unit behavior -------------------------------------------------

@pytest.fixture()
def grid():
    return demo_param_space()


def test_param_space_candidates_and_encoding(grid):
    cand = grid.candidate(tile=32, unroll=2, prefetch=1)
    assert cand == (32, 2, 1)
    assert grid.as_dict(cand) == {"tile": 32, "unroll": 2,
                                  "prefetch": 1}
    keys, enc = grid.encode_batch([cand, (8, 1, 0)])
    assert enc.dtype == np.int32 and enc.shape == (2, 3)
    assert enc.tolist() == [[2, 1, 1], [0, 0, 0]]
    assert keys[0] == enc[0].tobytes()
    assert len(set(keys)) == 2
    assert grid.tie_key(cand) == (2, 1, 1)
    assert grid.describe(cand) == "tile=32, unroll=2, prefetch=1"
    with pytest.raises(ValueError, match="not a value"):
        grid.encode_batch([(31, 2, 1)])
    with pytest.raises(ValueError, match="dimensions"):
        grid.encode_batch([(32, 2)])
    with pytest.raises(ValueError, match="candidate needs"):
        grid.candidate(tile=32)


def test_param_space_moves_build_candidates_in_dim_order(grid):
    assert grid.moves([]) == [8, 16, 32, 64, 128]
    assert grid.moves([8]) == [1, 2, 4]
    assert grid.moves([8, 1]) == [0, 1]
    assert grid.moves([8, 1, 0]) == []
    assert grid.finalize([8, 1, 0]) == (8, 1, 0)
    with pytest.raises(ValueError, match="incomplete"):
        grid.finalize([8, 1])
    cands = list(grid.enumerate_candidates())
    assert len(cands) == grid.n_candidates() == 5 * 3 * 2
    assert len(set(cands)) == len(cands)
    # random_candidate lands inside the grid; mutate stays inside too.
    rng = random.Random(0)
    c = grid.random_candidate(rng)
    assert c in set(cands)
    assert grid.mutate(c, rng) in set(cands)


def test_param_space_threshold_features(grid):
    feats = grid.all_features()
    by_dim = {}
    for f in feats:
        by_dim.setdefault(f.u, []).append(f)
    # n_values - 1 thresholds per ordered dimension (the smallest value
    # gives a constant column and is never emitted).
    assert [f.v for f in by_dim["tile"]] == [16, 32, 64, 128]
    assert [f.v for f in by_dim["unroll"]] == [2, 4]
    assert [f.v for f in by_dim["prefetch"]] == [1]
    assert all(f.kind == "param_ge" for f in feats)
    X = grid.apply_features([(8, 1, 0), (128, 4, 1)], feats)
    assert X[0].tolist() == [0] * len(feats)
    assert X[1].tolist() == [1] * len(feats)
    assert ParamFeature("param_ge", "tile", 64).describe(1) \
        == "tile >= 64"
    assert ParamFeature("param_ge", "tile", 64).describe(0) \
        == "tile < 64"
    # Features from a foreign basis evaluate to 0, not an error.
    alien = [ParamFeature("param_ge", "warp", 2)]
    assert grid.apply_features([(8, 1, 0)], alien).tolist() == [[0]]


def test_param_space_featurize_prunes_and_guards_degenerate(grid):
    fm = grid.featurize([(8, 1, 0), (8, 1, 1), (8, 2, 0)])
    assert {f.u for f in fm.features} == {"unroll", "prefetch"}
    with pytest.raises(C.DegenerateFeatureSpaceError):
        grid.featurize([(8, 1, 0), (8, 1, 0)])


def test_param_space_validation():
    with pytest.raises(ValueError, match="at least one"):
        ParamSpace("empty", [])
    with pytest.raises(ValueError, match="no values"):
        ParamSpace("p", [("a", ())])
    with pytest.raises(ValueError, match="duplicate values"):
        ParamSpace("p", [("a", (1, 1))])
    with pytest.raises(ValueError, match="duplicate dimension"):
        ParamSpace("p", [("a", (1,)), ("a", (2,))])


def test_param_space_fingerprints_separate_everything(grid):
    m = Machine()
    other_dims = ParamSpace(grid.name,
                            [("tile", (8, 16, 32)), ("unroll", (1, 2))],
                            signature=grid.signature)
    other_sig = demo_param_space()
    other_sig.signature = "different-instance"
    fps = {
        grid.fingerprint(m, {}, "analytic"),
        grid.fingerprint(m, {}, "kernel-wallclock:platform=cpu"),
        grid.fingerprint(Machine(flops_per_s=1e12), {}, "analytic"),
        other_dims.fingerprint(m, {}, "analytic"),
        other_sig.fingerprint(m, {}, "analytic"),
        demo_param_space("renamed").fingerprint(m, {}, "analytic"),
    }
    assert len(fps) == 6
    # Deterministic across instances.
    assert demo_param_space().fingerprint(m, {}, "analytic") \
        == grid.fingerprint(m, {}, "analytic")


def test_param_space_without_analytic_cost_points_at_wallclock():
    sp = ParamSpace("knobs", [("a", (1, 2))])
    with pytest.raises(NotImplementedError, match="wallclock"):
        E.make_evaluator(sp, "sim").evaluate([(1,)])


def test_analytic_backends_reject_graphless_spaces(grid):
    for backend, kwargs in (("vectorized", {}),
                            ("pool", {"n_workers": 2})):
        with pytest.raises(TypeError, match="no graph"):
            E.make_evaluator(grid, backend, **kwargs)


# -- searching a parameter grid ----------------------------------------------

def test_mcts_exhausts_demo_grid_and_finds_the_optimum(grid):
    strat = S.MCTSSearch(grid, seed=0)
    res = S.run_search(grid, strat, budget=400, batch_size=1)
    assert strat.exhausted()
    assert len(res.schedules) == grid.n_candidates()
    best, best_t = res.best()
    assert best == (32, 2, 1)                  # the bowl's optimum
    assert best_t == min(res.times)
    assert res.graph is None and res.space is grid


def test_exhaustive_and_random_over_param_space(grid):
    res = S.run_search(grid, S.ExhaustiveSearch(grid), budget=None)
    assert len(res.schedules) == grid.n_candidates()
    assert res.best()[0] == (32, 2, 1)
    rnd = S.run_search(grid, S.RandomSearch(grid, seed=2), budget=50)
    assert set(rnd.schedules) <= set(grid.enumerate_candidates())


def test_surrogate_guided_over_param_space(grid):
    strat = S.SurrogateGuided(grid, seed=0)
    res = S.run_search(grid, strat, budget=60, batch_size=4)
    assert res.n_proposed == 60
    assert len(res.schedules) <= grid.n_candidates()


def test_distill_param_space_rules(grid):
    """The rules pipeline speaks threshold features: an exhaustive
    demo-grid sweep distills to block-size-style interval rules."""
    res = S.run_search(grid, S.ExhaustiveSearch(grid), budget=None)
    report = distill(res)
    assert report.graph is None
    assert report.n_schedules == grid.n_candidates()
    assert report.rulesets
    text = report.render()
    assert "tile >= " in text or "tile < " in text
    assert report.training_error <= 0.25


def test_param_space_store_warm_start(tmp_path, grid, monkeypatch):
    """demo-grid searches warm-start across evaluators through the
    param-space fingerprint (same contract as schedule spaces)."""
    path = str(tmp_path / "eval.store")

    def run():
        return S.run_search(grid, S.MCTSSearch(grid, seed=1),
                            budget=80, batch_size=4, backend="sim",
                            store_path=path)

    cold = run()
    assert cold.cache_misses > 0 and cold.store_hits == 0

    def no_measuring(self, schedules, encoded=None):
        raise AssertionError("warm run measured — store missed")
    monkeypatch.setattr(E.BatchEvaluator, "_measure_batch",
                        no_measuring)
    warm = run()
    assert warm.cache_misses == 0
    assert warm.store_hits == cold.cache_misses
    assert warm.times == cold.times
    assert warm.schedules == cold.schedules
