"""Parameter-grid design spaces: tunable knobs behind the protocol.

A :class:`ParamSpace` is an ordered list of named dimensions, each
with a finite value set — block sizes, tile widths, unroll factors.
Candidates are value tuples (one value per dimension, in dimension
order); the canonical encoding is the int32 vector of value *indices*,
so cache keys and store addresses are stable as long as the dimension
definition is (the definition itself is hashed into the store
fingerprint — change the grid and old entries stop matching, exactly
as they must).

Sequential construction assigns dimensions in order (``moves`` of a
length-``k`` prefix are dimension ``k``'s values), which gives MCTS,
rollouts, and elite mutation over parameter grids for free via the
:class:`~repro.space.base.DesignSpace` defaults.

Featurization emits *threshold* features — ``block_q >= 64`` — for
numerically ordered dimensions (a binary tree over thresholds can
express any interval rule, which is what block-size design rules are)
and one-hot equality features for unordered ones. The rules pipeline
then renders reports like ``block_k >= 128`` next to the paper's
``Pack before yL`` — same tree, same Algorithm 1, new vocabulary.

:class:`KernelRunner` is the wallclock hook: how the param-space
``wallclock`` evaluator (:class:`repro.engine.params.
KernelWallclockEvaluator`) builds a runnable from a candidate and what
reference output gates its correctness. :func:`demo_param_space` is a
dependency-free analytic grid for tests and smoke runs.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from repro.core.features import (DegenerateFeatureSpaceError,
                                 FeatureMatrix)
from repro.space.base import DesignSpace


@dataclasses.dataclass(frozen=True)
class ParamFeature:
    """A binary feature over one parameter dimension.

    Same field layout as :class:`repro.core.features.Feature` (kind /
    u / v), so rulesets, trees, and reports consume it unchanged;
    ``v`` holds the raw threshold (``param_ge``) or value
    (``param_eq``), not a string, so evaluation never round-trips
    through repr.
    """

    kind: str   # 'param_ge' | 'param_eq'
    u: str      # dimension name
    v: Any      # threshold / value

    def describe(self, value: int) -> str:
        """Human-readable rule text for this feature taking ``value``."""
        if self.kind == "param_ge":
            return (f"{self.u} >= {self.v}" if value
                    else f"{self.u} < {self.v}")
        return (f"{self.u} = {self.v}" if value
                else f"{self.u} != {self.v}")


@dataclasses.dataclass
class KernelRunner:
    """How a :class:`ParamSpace` candidate becomes a measurable program.

    ``build(params)`` maps a candidate's ``{name: value}`` dict to a
    zero-argument callable returning the kernel's outputs on a fixed
    problem instance (inputs are closed over — the instance is part of
    the space, hashed via the space ``signature``). ``reference()``
    returns the ground-truth outputs every candidate must reproduce
    (the wallclock value-correctness gate).
    """

    build: Callable[[dict], Callable[[], Any]]
    reference: Callable[[], Any]


class _ParamBasis:
    """Incremental corpus for :meth:`ParamSpace.featurize` (the
    ``feature_basis`` protocol: ``add`` absorbs, ``matrix`` emits)."""

    def __init__(self, space: "ParamSpace"):
        self.space = space
        self._cands: list[tuple] = []

    def __len__(self) -> int:
        return len(self._cands)

    def add(self, candidates: Sequence) -> "_ParamBasis":
        self._cands.extend(tuple(c) for c in candidates)
        return self

    def matrix(self) -> FeatureMatrix:
        feats = self.space.all_features()
        X = self.space.apply_features(self._cands, feats)
        if X.shape[0]:
            keep = np.flatnonzero(X.min(axis=0) != X.max(axis=0))
        else:
            keep = np.array([], dtype=np.int64)
        return FeatureMatrix([feats[j] for j in keep],
                             np.ascontiguousarray(X[:, keep]))


class _ParamUniverse:
    """Feature universe of a parameter grid (the ``feature_universe``
    protocol): the candidate features are closed-form over the grid
    (``all_features``), so absorbing candidates is a no-op and merging
    two hosts' universes over the same grid is trivially exact."""

    def __init__(self, space: "ParamSpace"):
        self.space = space

    def __len__(self) -> int:
        return len(self.space.all_features())

    def add(self, candidates: Sequence) -> "_ParamUniverse":
        return self

    def merge(self, other: "_ParamUniverse") -> "_ParamUniverse":
        return self

    def candidate_features(self) -> list[ParamFeature]:
        return self.space.all_features()


class ParamSpace(DesignSpace):
    """A finite grid of named parameter dimensions.

    ``dims`` is an ordered ``[(name, values), ...]``; candidates are
    value tuples in that order. ``runner`` attaches wallclock
    measurement (see :class:`KernelRunner`), ``analytic_cost_fn`` an
    analytic objective (``fn(params_dict) -> float``) for the ``sim``
    backend, and ``signature`` names the fixed problem instance
    (shapes, dtypes, flags) so store fingerprints of the same grid on
    different instances never collide.
    """

    def __init__(self, name: str,
                 dims: Sequence[tuple[str, Sequence]], *,
                 runner: KernelRunner | None = None,
                 signature: str = "",
                 analytic_cost_fn: Callable[[dict], float] | None = None):
        if not dims:
            raise ValueError("a ParamSpace needs at least one dimension")
        self.name = name
        self.dims: list[tuple[str, tuple]] = []
        seen: set[str] = set()
        for dim_name, values in dims:
            dim_name = str(dim_name)
            values = tuple(values)
            if not values:
                raise ValueError(f"dimension {dim_name!r} has no values")
            if len(set(values)) != len(values):
                raise ValueError(
                    f"dimension {dim_name!r} has duplicate values")
            if dim_name in seen:
                raise ValueError(f"duplicate dimension {dim_name!r}")
            seen.add(dim_name)
            self.dims.append((dim_name, values))
        self._index = [{v: i for i, v in enumerate(vs)}
                       for _, vs in self.dims]
        self._dim_of = {n: i for i, (n, _) in enumerate(self.dims)}
        self.runner = runner
        self.signature = signature
        self.analytic_cost_fn = analytic_cost_fn

    # -- candidate helpers -------------------------------------------------
    def candidate(self, **params) -> tuple:
        """Build a candidate tuple from keyword values."""
        unknown = set(params) - set(self._dim_of)
        if unknown or len(params) != len(self.dims):
            raise ValueError(
                f"candidate needs exactly {sorted(self._dim_of)}, "
                f"got {sorted(params)}")
        return tuple(params[n] for n, _ in self.dims)

    def as_dict(self, candidate: Sequence) -> dict:
        """``{name: value}`` view of a candidate tuple."""
        return {n: v for (n, _), v in zip(self.dims, candidate)}

    def _indices(self, candidate: Sequence) -> list[int]:
        cand = tuple(candidate)
        if len(cand) != len(self.dims):
            raise ValueError(
                f"candidate {cand!r} has {len(cand)} values for "
                f"{len(self.dims)} dimensions")
        out = []
        for (name, _), idx, v in zip(self.dims, self._index, cand):
            i = idx.get(v)
            if i is None:
                raise ValueError(
                    f"{v!r} is not a value of dimension {name!r}")
            out.append(i)
        return out

    # -- identity ----------------------------------------------------------
    def encode_batch(self, candidates: Sequence
                     ) -> tuple[list[bytes], np.ndarray]:
        enc = np.asarray([self._indices(c) for c in candidates],
                         dtype=np.int32).reshape(len(candidates),
                                                 len(self.dims))
        return [row.tobytes() for row in enc], enc

    def decode_batch(self, enc: np.ndarray) -> list[tuple]:
        """Candidate tuples back from ``encode_batch`` index rows."""
        enc = np.asarray(enc, dtype=np.int32).reshape(-1, len(self.dims))
        out: list[tuple] = []
        for row in enc:
            cand = []
            for (name, vs), i in zip(self.dims, row):
                if not 0 <= i < len(vs):
                    raise ValueError(
                        f"index {int(i)} out of range for dimension "
                        f"{name!r}")
                cand.append(vs[int(i)])
            out.append(tuple(cand))
        return out

    def candidate_key(self, candidate: Sequence) -> tuple:
        return tuple(candidate)

    def tie_key(self, candidate: Sequence) -> tuple:
        return tuple(self._indices(candidate))

    def describe(self, candidate: Sequence) -> str:
        return ", ".join(f"{n}={v}" for (n, _), v
                         in zip(self.dims, candidate))

    # -- moves: assign dimensions in order ---------------------------------
    def moves(self, prefix: list) -> list:
        if len(prefix) >= len(self.dims):
            return []
        return list(self.dims[len(prefix)][1])

    def move_key(self, move):
        return move

    def finalize(self, prefix: list) -> tuple:
        if len(prefix) != len(self.dims):
            raise ValueError(
                f"incomplete candidate: {len(prefix)} of "
                f"{len(self.dims)} dimensions assigned")
        return tuple(prefix)

    def candidate_moves(self, candidate: Sequence) -> Sequence:
        return tuple(candidate)

    def enumerate_candidates(self) -> Iterator[tuple]:
        return itertools.product(*(vs for _, vs in self.dims))

    def n_candidates(self) -> int:
        out = 1
        for _, vs in self.dims:
            out *= len(vs)
        return out

    # -- featurization -----------------------------------------------------
    def all_features(self) -> list[ParamFeature]:
        """Unpruned feature list: thresholds for ordered dimensions,
        one-hot equality for unordered ones."""
        feats: list[ParamFeature] = []
        for name, values in self.dims:
            try:
                ordered = sorted(values)
            except TypeError:
                ordered = None
            if ordered is not None:
                feats.extend(ParamFeature("param_ge", name, v)
                             for v in ordered[1:])
            else:
                feats.extend(ParamFeature("param_eq", name, v)
                             for v in values)
        return feats

    def feature_basis(self) -> _ParamBasis:
        return _ParamBasis(self)

    def feature_universe(self) -> "_ParamUniverse":
        return _ParamUniverse(self)

    def featurize(self, candidates: Sequence) -> FeatureMatrix:
        fm = self.feature_basis().add(candidates).matrix()
        if not fm.features:
            raise DegenerateFeatureSpaceError(
                f"corpus of {len(candidates)} candidate(s) in "
                f"{self.name!r} has no discriminating features after "
                "constant-column pruning (all candidates are "
                "identical, or the corpus is empty); at least 2 "
                "distinct candidates are required")
        return fm

    def apply_features(self, candidates: Sequence,
                       features: list) -> np.ndarray:
        X = np.zeros((len(candidates), len(features)), dtype=np.int8)
        if not len(candidates) or not features:
            return X
        for j, f in enumerate(features):
            d = self._dim_of.get(f.u)
            if d is None:
                continue          # feature from another basis: all 0
            col = [c[d] for c in (tuple(c) for c in candidates)]
            if f.kind == "param_ge":
                X[:, j] = [1 if v >= f.v else 0 for v in col]
            else:
                X[:, j] = [1 if v == f.v else 0 for v in col]
        return X

    # -- evaluation support ------------------------------------------------
    def fingerprint(self, machine, durations: dict,
                    objective: str) -> bytes:
        from repro.engine.store import FINGERPRINT_SIZE
        h = hashlib.blake2b(digest_size=FINGERPRINT_SIZE)
        h.update(b"objective=" + objective.encode() + b"\n")
        h.update(b"param-space=" + self.name.encode() + b"\n")
        h.update(b"signature=" + self.signature.encode() + b"\n")
        h.update(repr(machine).encode() + b"\n")
        for name, values in self.dims:
            h.update(repr((name, values)).encode() + b"\n")
        return h.digest()

    def analytic_cost(self, candidate: Sequence, machine,
                      durations: dict) -> float:
        if self.analytic_cost_fn is None:
            return super().analytic_cost(candidate, machine, durations)
        return float(self.analytic_cost_fn(self.as_dict(candidate)))


def demo_param_space(name: str = "demo") -> ParamSpace:
    """A tiny analytic parameter grid (no JAX needed).

    A smooth cost bowl over (tile, unroll, prefetch) with the optimum
    at ``tile=32, unroll=2, prefetch=1`` — enough structure for
    strategies, labeling, and rules to find and express, cheap enough
    for unit tests and smoke runs on any container.
    """
    import math

    def cost(p: dict) -> float:
        tile = (math.log2(p["tile"]) - 5.0) ** 2        # min at 32
        unroll = (math.log2(p["unroll"]) - 1.0) ** 2    # min at 2
        pf = 0.25 * (1 - p["prefetch"])                 # prefer on
        return 1.0 + 0.5 * tile + 0.25 * unroll + pf

    return ParamSpace(
        name,
        [("tile", (8, 16, 32, 64, 128)),
         ("unroll", (1, 2, 4)),
         ("prefetch", (0, 1))],
        signature="analytic-demo-bowl-v1",
        analytic_cost_fn=cost)
