"""Shared test configuration: deterministic seeding.

Every test runs with the global ``random`` and legacy numpy RNGs
re-seeded, so test order / ``-k`` selections / partial runs cannot
change outcomes (library code that takes explicit seeds is unaffected —
this only pins accidental global-state consumers). Markers are
registered in pytest.ini.
"""
import os
import random
import sys

import numpy as np
import pytest

# Make the repo root importable (``benchmarks`` is a plain directory,
# used by the smoke test) alongside ``src`` from PYTHONPATH.
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)

GLOBAL_SEED = 0


@pytest.fixture(autouse=True)
def deterministic_seed():
    random.seed(GLOBAL_SEED)
    np.random.seed(GLOBAL_SEED)
    yield
