"""DAG program model (paper §III-A).

A CUDA+MPI (here: TPU compute + collective) program is a directed acyclic
graph whose vertices are operations and whose edges are dependencies.
Vertex types follow Table II of the paper:

  * ``CPU``       — synchronous host operation (e.g. posting an Isend,
                    an MPI_Wait, an optimizer bookkeeping step).
  * ``GPU``       — asynchronous device operation not yet bound to a stream.
  * ``BoundGPU``  — a GPU vertex assigned to execution stream ``s``
                    (represented here by :class:`BoundOp` with ``stream``).

Artificial ``start``/``end`` CPU vertices bracket the program.

An *implementation* of the program is a topological traversal of the DAG
plus a stream assignment for every GPU vertex (a :class:`Schedule`).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Iterable, Mapping


class OpKind(enum.Enum):
    CPU = "CPU"
    GPU = "GPU"
    # Sync ops are generated during schedule expansion (Table III), never
    # authored by users, but they are first-class items in feature vectors.
    SYNC = "SYNC"


class CommRole(enum.Enum):
    """Communication role of a CPU op (drives the cost model)."""

    NONE = "none"
    POST_SEND = "post_send"
    POST_RECV = "post_recv"
    WAIT_SEND = "wait_send"
    WAIT_RECV = "wait_recv"


@dataclasses.dataclass(frozen=True)
class Op:
    """A program operation (DAG vertex).

    Cost metadata is used by :mod:`repro.core.costmodel`; it is ignored by
    the search/labeling/rules pipeline, which only sees names and orderings.
    """

    name: str
    kind: OpKind
    flops: float = 0.0
    bytes_hbm: float = 0.0
    comm_bytes: float = 0.0
    comm_role: CommRole = CommRole.NONE
    # Optional fixed duration override (seconds); None -> derived from
    # flops/bytes by the machine model.
    duration: float | None = None

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        return self.name


@dataclasses.dataclass(frozen=True)
class BoundOp:
    """A schedule item: an op, bound to a stream if it is a GPU op."""

    name: str
    stream: int | None = None

    def __str__(self) -> str:  # pragma: no cover
        if self.stream is None:
            return self.name
        return f"{self.name}@s{self.stream}"


class Graph:
    """A DAG of :class:`Op` with explicit ``start``/``end`` vertices."""

    START = "start"
    END = "end"

    def __init__(self) -> None:
        self.ops: dict[str, Op] = {}
        self.preds: dict[str, set[str]] = {}
        self.succs: dict[str, set[str]] = {}
        # Bumped on every mutation; derived-table caches (e.g. the sync
        # expansion tables in repro.core.sync) key on it so a graph
        # mutated after first use is never served stale data.
        self.version = 0
        self.add_op(Op(self.START, OpKind.CPU, duration=0.0))
        self.add_op(Op(self.END, OpKind.CPU, duration=0.0))

    # -- construction -----------------------------------------------------
    def add_op(self, op: Op) -> Op:
        if op.name in self.ops:
            raise ValueError(f"duplicate op name {op.name!r}")
        self.version += 1
        self.ops[op.name] = op
        self.preds[op.name] = set()
        self.succs[op.name] = set()
        return op

    def add_edge(self, u: str, v: str) -> None:
        if u not in self.ops or v not in self.ops:
            raise KeyError(f"unknown op in edge {u!r}->{v!r}")
        self.version += 1
        self.preds[v].add(u)
        self.succs[u].add(v)

    def finalize(self) -> "Graph":
        """Wire ``start``/``end`` so every vertex is on a start->end path."""
        interior = [n for n in self.ops if n not in (self.START, self.END)]
        for n in interior:
            if not self.preds[n]:
                self.add_edge(self.START, n)
            if not (self.succs[n] - {self.END}):
                self.succs[n].discard(self.END)
                self.preds[self.END].discard(n)
                self.add_edge(n, self.END)
        self._check_acyclic()
        return self

    # -- queries ----------------------------------------------------------
    def _check_acyclic(self) -> None:
        order = self.topological_order()
        if len(order) != len(self.ops):
            raise ValueError("graph has a cycle")

    def topological_order(self) -> list[str]:
        indeg = {n: len(p) for n, p in self.preds.items()}
        frontier = sorted(n for n, d in indeg.items() if d == 0)
        out: list[str] = []
        while frontier:
            n = frontier.pop(0)
            out.append(n)
            for s in sorted(self.succs[n]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    frontier.append(s)
        return out

    def gpu_ops(self) -> list[str]:
        return [n for n, o in self.ops.items() if o.kind is OpKind.GPU]

    def eligible(self, scheduled: Iterable[str]) -> list[str]:
        """Vertices whose predecessors are all in ``scheduled``."""
        done = set(scheduled)
        out = []
        for n in self.ops:
            if n in done:
                continue
            if self.preds[n] <= done:
                out.append(n)
        return sorted(out)

    def n_vertices(self) -> int:
        return len(self.ops)


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A complete implementation: traversal order + stream assignment."""

    items: tuple[BoundOp, ...]

    def order(self) -> tuple[str, ...]:
        return tuple(i.name for i in self.items)

    def streams(self) -> dict[str, int]:
        return {i.name: i.stream for i in self.items if i.stream is not None}

    def key(self) -> tuple:
        """Hashable identity (canonical under stream relabeling is enforced
        at construction time by the enumerator / MCTS expansion)."""
        return tuple((i.name, i.stream) for i in self.items)


def validate_schedule(graph: Graph, schedule: Schedule) -> None:
    """Raise if ``schedule`` is not a topological traversal of ``graph``."""
    seen: set[str] = set()
    for item in schedule.items:
        op = graph.ops.get(item.name)
        if op is None:
            raise ValueError(f"unknown op {item.name!r}")
        if not (graph.preds[item.name] <= seen):
            missing = graph.preds[item.name] - seen
            raise ValueError(f"{item.name!r} scheduled before preds {missing}")
        if op.kind is OpKind.GPU and item.stream is None:
            raise ValueError(f"GPU op {item.name!r} has no stream")
        if op.kind is not OpKind.GPU and item.stream is not None:
            raise ValueError(f"non-GPU op {item.name!r} bound to stream")
        seen.add(item.name)
    if seen != set(graph.ops):
        raise ValueError(f"schedule missing ops {set(graph.ops) - seen}")


def canonicalize_streams(items: Iterable[BoundOp]) -> tuple[BoundOp, ...]:
    """Relabel streams in first-use order (bijection canonical form).

    Two schedules that differ only by a bijection of stream names are the
    same implementation (paper §III-C2); the canonical form names streams
    0,1,2,... in order of first use.
    """
    mapping: dict[int, int] = {}
    out = []
    for it in items:
        if it.stream is None:
            out.append(it)
            continue
        if it.stream not in mapping:
            mapping[it.stream] = len(mapping)
        out.append(BoundOp(it.name, mapping[it.stream]))
    return tuple(out)


# ---------------------------------------------------------------------------
# The paper's demonstration workload: distributed SpMV (Fig. 3).
# ---------------------------------------------------------------------------

def spmv_dag(
    *,
    rows_per_rank: int = 150_000 // 4,
    nnz_per_rank: int = 1_500_000 // 4,
    local_frac: float = 0.5,
    value_bytes: int = 8,
    index_bytes: int = 4,
) -> Graph:
    """Build the SpMV op-DAG of Fig. 3c.

    Vertices (GPU ops are unbound; streams are an implementation choice):

      Pack (GPU)      gather x_L entries into per-neighbor send buffers
      PostSend (CPU)  MPI_Isend the packed buffers
      PostRecv (CPU)  MPI_Irecv into x_R
      WaitSend (CPU)  MPI_Wait on sends
      WaitRecv (CPU)  MPI_Wait on recvs
      yL (GPU)        y_L = A_L x_L   (local multiply)
      yR (GPU)        y_R = A_R x_R   (remote multiply, needs x_R)

    Edges: Pack->PostSend->WaitSend, PostRecv->WaitRecv->yR; yL independent.
    """
    nnz_local = nnz_per_rank * local_frac
    nnz_remote = nnz_per_rank * (1.0 - local_frac)
    # Remote x entries exchanged with neighbors: with a band of width n/4 and
    # contiguous row blocks, a rank needs ~half a block from each neighbor.
    halo_entries = rows_per_rank
    halo_bytes = halo_entries * value_bytes

    def spmv_bytes(nnz: float) -> float:
        # val + col index per nnz, x gather, y write (row ptr amortized).
        return nnz * (value_bytes + index_bytes + value_bytes) + \
            rows_per_rank * value_bytes

    g = Graph()
    g.add_op(Op("Pack", OpKind.GPU, flops=0.0,
                bytes_hbm=2 * halo_bytes + halo_entries * index_bytes))
    g.add_op(Op("PostSend", OpKind.CPU, comm_bytes=halo_bytes,
                comm_role=CommRole.POST_SEND))
    g.add_op(Op("PostRecv", OpKind.CPU, comm_bytes=halo_bytes,
                comm_role=CommRole.POST_RECV))
    g.add_op(Op("WaitSend", OpKind.CPU, comm_role=CommRole.WAIT_SEND))
    g.add_op(Op("WaitRecv", OpKind.CPU, comm_role=CommRole.WAIT_RECV))
    g.add_op(Op("yL", OpKind.GPU, flops=2 * nnz_local,
                bytes_hbm=spmv_bytes(nnz_local)))
    g.add_op(Op("yR", OpKind.GPU, flops=2 * nnz_remote,
                bytes_hbm=spmv_bytes(nnz_remote)))
    g.add_edge("Pack", "PostSend")
    g.add_edge("PostSend", "WaitSend")
    g.add_edge("PostRecv", "WaitRecv")
    g.add_edge("WaitRecv", "yR")
    # Deadlock-avoidance under SPMD symmetry: all ranks run the same
    # schedule, so WaitRecv before PostSend would have every rank blocking
    # on a message no rank has sent. Such traversals are not valid
    # implementations and are excluded from the design space.
    g.add_edge("PostSend", "WaitRecv")
    return g.finalize()


def spmv_dag_fine(
    *,
    rows_per_rank: int = 150_000 // 4,
    nnz_per_rank: int = 1_500_000 // 4,
    value_bytes: int = 8,
    index_bytes: int = 4,
) -> Graph:
    """Fine-grained SpMV DAG: per-neighbor Pack/Send/Recv vertices.

    The paper (§III-A) discusses this granularity trade-off — separate
    vertices per neighbor remove false dependencies ("not being able to
    send to rank 1 before the pack for rank 2 is completed") at the cost
    of a larger search space — but evaluates only the coarse DAG. This
    builder enables the ablation (EXPERIMENTS §Paper, granularity row).

    Two neighbors (left/right of the circulant band). Deadlock-avoidance
    under SPMD symmetry: our recv from the left neighbor is their
    right-send, i.e. our own PostSend_r's symmetric twin — so WaitRecv_l
    requires PostSend_r to have been posted (and vice versa).
    """
    halo_bytes = rows_per_rank * value_bytes / 2
    nnz_half = nnz_per_rank / 4  # remote split across two neighbors

    def spmv_bytes(nnz: float) -> float:
        return nnz * (2 * value_bytes + index_bytes) + \
            rows_per_rank * value_bytes

    g = Graph()
    for side in ("l", "r"):
        g.add_op(Op(f"Pack_{side}", OpKind.GPU,
                    bytes_hbm=2 * halo_bytes))
        g.add_op(Op(f"PostSend_{side}", OpKind.CPU,
                    comm_bytes=halo_bytes,
                    comm_role=CommRole.POST_SEND))
        g.add_op(Op(f"PostRecv_{side}", OpKind.CPU,
                    comm_bytes=halo_bytes,
                    comm_role=CommRole.POST_RECV))
        g.add_op(Op(f"WaitSend_{side}", OpKind.CPU,
                    comm_role=CommRole.WAIT_SEND))
        g.add_op(Op(f"WaitRecv_{side}", OpKind.CPU,
                    comm_role=CommRole.WAIT_RECV))
        g.add_edge(f"Pack_{side}", f"PostSend_{side}")
        g.add_edge(f"PostSend_{side}", f"WaitSend_{side}")
        g.add_edge(f"PostRecv_{side}", f"WaitRecv_{side}")
    g.add_op(Op("yL", OpKind.GPU, flops=2 * nnz_per_rank / 2,
                bytes_hbm=spmv_bytes(nnz_per_rank / 2)))
    g.add_op(Op("yR", OpKind.GPU, flops=2 * 2 * nnz_half,
                bytes_hbm=spmv_bytes(2 * nnz_half)))
    g.add_edge("WaitRecv_l", "yR")
    g.add_edge("WaitRecv_r", "yR")
    g.add_edge("PostSend_r", "WaitRecv_l")   # symmetric-twin rendezvous
    g.add_edge("PostSend_l", "WaitRecv_r")
    return g.finalize()


def halo3d_dag(
    *,
    local_extent: int = 128,
    halo_width: int = 2,
    value_bytes: int = 8,
    flops_per_cell: float = 8.0,
) -> Graph:
    """3-D halo-exchange stencil DAG — the paper's named future-work
    direction (§VI: "currently being extended to 3D halo-exchange
    communication modeling fine-grained communication operations in
    each dimension").

    Per face f in {xn, xp, yn, yp, zn, zp}: Pack_f (GPU) -> PostSend_f
    -> WaitSend_f and PostRecv_f -> WaitRecv_f -> Bnd_f (the face's
    boundary stencil update). Inner (GPU) is the halo-independent bulk
    update, free to overlap all communication. Symmetric-twin
    rendezvous edges (PostSend_xp -> WaitRecv_xn etc.) exclude
    SPMD-deadlocking traversals.
    """
    n = local_extent
    face_cells = n * n * halo_width
    face_bytes = face_cells * value_bytes

    g = Graph()
    g.add_op(Op("Inner", OpKind.GPU,
                flops=flops_per_cell * (n - 2 * halo_width) ** 3,
                bytes_hbm=2 * value_bytes * n ** 3))
    faces = ("xn", "xp", "yn", "yp", "zn", "zp")
    for f in faces:
        g.add_op(Op(f"Pack_{f}", OpKind.GPU,
                    bytes_hbm=2 * face_bytes))
        g.add_op(Op(f"PostSend_{f}", OpKind.CPU,
                    comm_bytes=face_bytes,
                    comm_role=CommRole.POST_SEND))
        g.add_op(Op(f"PostRecv_{f}", OpKind.CPU,
                    comm_bytes=face_bytes,
                    comm_role=CommRole.POST_RECV))
        g.add_op(Op(f"WaitSend_{f}", OpKind.CPU,
                    comm_role=CommRole.WAIT_SEND))
        g.add_op(Op(f"WaitRecv_{f}", OpKind.CPU,
                    comm_role=CommRole.WAIT_RECV))
        g.add_op(Op(f"Bnd_{f}", OpKind.GPU,
                    flops=flops_per_cell * face_cells,
                    bytes_hbm=3 * face_bytes))
        g.add_edge(f"Pack_{f}", f"PostSend_{f}")
        g.add_edge(f"PostSend_{f}", f"WaitSend_{f}")
        g.add_edge(f"PostRecv_{f}", f"WaitRecv_{f}")
        g.add_edge(f"WaitRecv_{f}", f"Bnd_{f}")
    twin = {"xn": "xp", "xp": "xn", "yn": "yp", "yp": "yn",
            "zn": "zp", "zp": "zn"}
    for f in faces:
        g.add_edge(f"PostSend_{twin[f]}", f"WaitRecv_{f}")
    return g.finalize()
