"""Param-space wall-clock backend: measure the repo's own kernels.

The counterpart of :mod:`repro.engine.wallclock` for
:class:`~repro.space.params.ParamSpace` candidates: instead of
rendering a schedule into a token-chain runner, each candidate's
parameter assignment is handed to the space's
:class:`~repro.space.params.KernelRunner` (``build(params)`` → a
zero-argument jitted callable on a fixed problem instance). Everything
search-visible — memo cache, three-way hit/miss meters, persistent
:class:`~repro.engine.store.EvalStore` warm starts, noise seeding,
salvage — is inherited from :class:`~repro.engine.base.EvaluatorBase`
unchanged, so a kernel autotune run is driven, deduped, budgeted, and
warm-started exactly like a schedule search.

Measurement protocol per canonical-unique candidate:

  1. **compile phase** — build every candidate's runner and run it
     once (``block_until_ready``), asserting value correctness against
     ``runner.reference()`` via the shared wallclock gate. With
     ``compile_mode="batch"`` (the default) this phase covers the
     *whole batch before any timing starts*, so XLA compile time
     amortizes the way the vectorized backend amortizes Python
     dispatch — timings never absorb a neighbor's compile;
     ``compile_mode="per_candidate"`` interleaves (the naive loop,
     kept for the BENCH comparison).
  2. **timing phase** — ``warmup - 1`` further calls, then ``repeats``
     timed calls (``block_until_ready`` inside the stopwatch), record
     the median.

The store fingerprint keys on the measuring platform
(``jax.default_backend()``) in addition to the timing protocol: a CPU
interpret-mode sweep and a TPU sweep of the same grid are different
experiments and must never warm-start each other.
"""
from __future__ import annotations

import statistics
import time
from typing import Sequence

import numpy as np

from repro import obs
from repro.core.costmodel import Machine
from repro.engine.base import EvaluatorBase
from repro.engine.wallclock import _as_output_map, assert_outputs_close
from repro.space.params import ParamSpace


class KernelWallclockEvaluator(EvaluatorBase):
    """Wall-clock evaluation of a :class:`ParamSpace` with a runner."""

    backend = "wallclock"

    def __init__(self, space: ParamSpace,
                 machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0, *,
                 repeats: int = 5, warmup: int = 1,
                 check_values: bool = True, rtol: float = 1e-4,
                 atol: float = 1e-6, compile_mode: str = "batch",
                 **base_kwargs):
        super().__init__(space, machine, noise_sigma, noise_seed,
                         **base_kwargs)
        runner = getattr(self.space, "runner", None)
        if runner is None:
            raise ValueError(
                f"design space {self.space.name!r} has no KernelRunner "
                "attached; the param-space wallclock backend needs "
                "runner= on the ParamSpace (build + reference)")
        if compile_mode not in ("batch", "per_candidate"):
            raise ValueError(
                f"compile_mode must be 'batch' or 'per_candidate', "
                f"got {compile_mode!r}")
        self.runner = runner
        self.repeats = max(1, repeats)
        self.warmup = max(1, warmup)
        self.check_values = check_values
        self.rtol = rtol
        self.atol = atol
        self.compile_mode = compile_mode
        self.n_checked = 0
        self._reference: dict | None = None

    def _objective_key(self) -> str:
        """Kernel wall clock is platform-specific on top of being
        protocol-specific: CPU interpret-mode and TPU sweeps of the
        same grid must never share store entries. (``compile_mode`` is
        deliberately excluded — it moves compile cost around but the
        timed quantity is the same.)"""
        import jax
        return (f"kernel-wallclock:platform={jax.default_backend()}:"
                f"repeats={self.repeats}:warmup={self.warmup}")

    # -- reference outputs (computed lazily, once) -------------------------
    def _reference_outputs(self) -> dict:
        if self._reference is None:
            self._reference = _as_output_map(self.runner.reference())
        return self._reference

    def _check(self, out, candidate) -> None:
        assert_outputs_close(
            out, self._reference_outputs(), rtol=self.rtol,
            atol=self.atol,
            context=(f" for candidate "
                     f"({self.space.describe(candidate)}) — kernel "
                     "output failed the value-correctness gate"))
        self.n_checked += 1

    def _measure_batch(self, candidates: Sequence,
                       encoded: np.ndarray | None = None) -> list[float]:
        import jax

        out: list[float] = []
        # The compile-vs-gate-vs-timing split per miss batch: gate wall
        # is accumulated inside whichever phase runs the value check so
        # the compile/timing spans report pure XLA-compile and pure
        # stopwatch time. Telemetry is observational only — the
        # stopwatch readings that become results never include span
        # bookkeeping (spans wrap whole loops, not timed calls).
        gate_s = 0.0

        def _gated_check(result, cand):
            nonlocal gate_s
            g0 = time.perf_counter()
            self._check(result, cand)
            gate_s += time.perf_counter() - g0

        try:
            runs = []
            with obs.span("kernel.compile", n=len(candidates),
                          mode=self.compile_mode) as compile_span:
                for cand in candidates:
                    run = self.runner.build(self.space.as_dict(cand))
                    runs.append(run)
                    if self.compile_mode == "batch":
                        # Compile + gate the whole batch ahead of timing.
                        result = jax.block_until_ready(run())
                        if self.check_values:
                            _gated_check(result, cand)
                compile_span.set(gate_s=gate_s)
            compile_gate_s = gate_s
            with obs.span("kernel.timing", n=len(candidates),
                          repeats=self.repeats) as timing_span:
                for cand, run in zip(candidates, runs):
                    if self.compile_mode == "per_candidate":
                        result = jax.block_until_ready(run())
                        if self.check_values:
                            _gated_check(result, cand)
                    for _ in range(self.warmup - 1):
                        jax.block_until_ready(run())
                    times = []
                    for _ in range(self.repeats):
                        t0 = time.perf_counter()
                        jax.block_until_ready(run())
                        times.append(time.perf_counter() - t0)
                    out.append(statistics.median(times))
                timing_span.set(gate_s=gate_s - compile_gate_s)
            if self.check_values:
                obs.counter("kernel.gate_checks").add(len(candidates))
        finally:
            # Same salvage contract as the executor backend: if a
            # candidate fails the value gate mid-batch, the timings
            # already paid for are banked (memo cache + store) and
            # metered as misses on their next lookup.
            if encoded is not None and len(out) < len(candidates):
                self._salvage_partial(encoded[:len(out)], out)
        return out
