"""RWKV-6 (Finch) time-mixing: attention-free, data-dependent decay.

Matrix-valued per-head state S (N x N) with the RWKV-6 recurrence:

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

where the decay w_t = exp(-exp(w0 + LoRA(x_t))) is *data-dependent*
(the Finch contribution). Token-shift mixing on the projections, SiLU
gate, per-head group normalization on the readout.

Two evaluation modes:

  * ``rwkv_scan``   — sequential lax.scan over time (reference; exact).
  * ``rwkv_chunked``— chunked block-parallel form (beyond-paper perf
    lever for the long_500k cell): within a chunk, contributions are
    computed with cumulative decay products; states propagate across
    chunk boundaries. O(T/C) serial steps instead of O(T).

Decode carries (shift, S) — O(1) state per token, which is why this
arch (and Jamba's mamba layers) run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.params import Spec

LORA_RANK = 32


def rwkv_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        "mu": Spec((5, d), (None, "d_model"), init="zeros"),  # r,k,v,g,w
        "wr": Spec((d, d), ("d_model", "heads_x_dim")),
        "wk": Spec((d, d), ("d_model", "heads_x_dim")),
        "wv": Spec((d, d), ("d_model", "heads_x_dim")),
        "wg": Spec((d, d), ("d_model", "heads_x_dim")),
        "wo": Spec((d, d), ("heads_x_dim", "d_model")),
        "w0": Spec((d,), ("heads_x_dim",), init="zeros"),
        "w_lora_a": Spec((d, LORA_RANK), ("d_model", None)),
        "w_lora_b": Spec((LORA_RANK, d), (None, "heads_x_dim"),
                         init="zeros"),
        "u": Spec((h, n), ("heads", "head_dim"), init="zeros"),
        "ln_scale": Spec((h, n), ("heads", "head_dim"), init="ones"),
    }


def _projections(p: dict, x: jax.Array, x_shift: jax.Array,
                 cfg: ModelConfig):
    """Token-shift mix + r/k/v/g/w projections."""
    dt = x.dtype
    mu = p["mu"].astype(dt)                      # (5, d)
    mix = x[None] + (x_shift - x)[None] * mu[:, None, None, :]
    xr, xk, xv, xg, xw = mix
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    b, s, _ = x.shape
    cst = lambda a: constrain(a, ("batch", "seq", "heads_x_dim"))
    r = cst(xr @ p["wr"].astype(dt)).reshape(b, s, h, n)
    k = cst(xk @ p["wk"].astype(dt)).reshape(b, s, h, n)
    v = cst(xv @ p["wv"].astype(dt)).reshape(b, s, h, n)
    g = jax.nn.silu(cst(xg @ p["wg"].astype(dt)))
    # Data-dependent decay (the RWKV-6 contribution).
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(dt)) @ \
        p["w_lora_b"].astype(dt)
    w = jnp.exp(-jnp.exp(
        (p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
        .clip(-8.0, 4.0))).reshape(b, s, h, n)
    return r, k, v, g, w


def _readout(p: dict, y: jax.Array, g: jax.Array, cfg: ModelConfig):
    """Per-head groupnorm, gate, output projection."""
    b, s, h, n = y.shape
    yf = y.astype(jnp.float32)
    mean = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + 1e-5) * \
        p["ln_scale"].astype(jnp.float32)
    out = (yn.reshape(b, s, h * n).astype(g.dtype) * g)
    return out @ p["wo"].astype(g.dtype)


def rwkv_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                 state: tuple[jax.Array, jax.Array] | None = None,
                 chunk: int | None = None):
    """x: (B, S, d). state: (shift (B, d), S (B, H, N, N)) or None.

    Returns (y, new_state).
    """
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    if state is None:
        shift0 = jnp.zeros((b, d), x.dtype)
        s0 = jnp.zeros((b, h, n, n), jnp.float32)
    else:
        shift0, s0 = state
    x_shift = jnp.concatenate([shift0[:, None], x[:, :-1]], axis=1)
    r, k, v, g, w = _projections(p, x, x_shift, cfg)
    u = p["u"].astype(jnp.float32)

    rT = r.astype(jnp.float32).transpose(1, 0, 2, 3)  # (S,B,H,N)
    kT = k.astype(jnp.float32).transpose(1, 0, 2, 3)
    vT = v.astype(jnp.float32).transpose(1, 0, 2, 3)
    wT = w.astype(jnp.float32).transpose(1, 0, 2, 3)

    if chunk is None:
        def step(S, inp):
            rt, kt, vt, wt = inp
            kv = kt[..., :, None] * vt[..., None, :]      # (B,H,N,N)
            yt = jnp.einsum("bhn,bhnm->bhm", rt,
                            S + u[..., :, None] * kv)
            S_new = wt[..., :, None] * S + kv
            return S_new, yt

        s_final, y = jax.lax.scan(step, s0, (rT, kT, vT, wT))
    else:
        s_final, y = _chunked(rT, kT, vT, wT, u, s0, chunk)

    y = y.transpose(1, 0, 2, 3)                          # (B,S,H,N)
    out = _readout(p, y, g, cfg)
    return out, (x[:, -1], s_final)


def _chunked(rT, kT, vT, wT, u, s0, chunk: int):
    """Block-parallel RWKV evaluation (exact, O(T/C) sequential steps).

    Within a chunk: y_t = r_t (prod_{i<t} w_i) S_in + intra-chunk causal
    pairs with decay products between k_i and r_t; standard chunked
    linear-attention algebra, all in f32.
    """
    s, b, h, n = rT.shape
    assert s % chunk == 0, "sequence must divide by chunk"
    nc = s // chunk
    rs = rT.reshape(nc, chunk, b, h, n)
    ks = kT.reshape(nc, chunk, b, h, n)
    vs = vT.reshape(nc, chunk, b, h, n)
    ws = wT.reshape(nc, chunk, b, h, n)

    def block(S_in, blk):
        rc, kc, vc, wc = blk                   # (C,B,H,N)
        logw = jnp.log(jnp.maximum(wc, 1e-38))
        # Stability clamp for the factored decay products: a per-step
        # decay below exp(-30/C) compounds to < 1e-13 across the chunk —
        # numerically zero in f32 — so clamping costs no accuracy while
        # bounding exp(-cum) <= e^30 (GLA-style secondary chunking
        # avoided; tests check chunked == sequential).
        logw = jnp.maximum(logw, -30.0 / chunk)
        cum = jnp.cumsum(logw, axis=0)         # prod_{i<=t} w_i
        cum_excl = cum - logw                  # prod_{i<t} w_i
        # Inter-chunk: r_t decayed against incoming state.
        r_dec = rc * jnp.exp(cum_excl)
        y_inter = jnp.einsum("cbhn,bhnm->cbhm", r_dec, S_in)
        # Intra-chunk causal pairs: decay between i (k) and t (r) is
        # prod_{j in (i, t)} w_j = exp(cum_excl[t] - cum[i]).
        att = jnp.einsum("cbhn,dbhn->cdbh", r_dec,
                         kc * jnp.exp(-cum))
        mask = jnp.tril(jnp.ones((chunk, chunk)), -1)[..., None, None]
        att = att * mask
        # Current-token bonus term (diag(u)).
        bonus = jnp.einsum("cbhn,cbhn->cbh", rc * u, kc)
        y_intra = jnp.einsum("cdbh,dbhn->cbhn", att, vc) + \
            bonus[..., None] * vc
        # State update across the chunk.
        k_dec = kc * jnp.exp(cum[-1] - cum)
        S_out = jnp.exp(cum[-1])[..., :, None] * S_in + jnp.einsum(
            "cbhn,cbhm->bhnm", k_dec, vc)
        return S_out, y_inter + y_intra

    s_final, ys = jax.lax.scan(jax.checkpoint(block), s0,
                               (rs, ks, vs, ws))
    return s_final, ys.reshape(s, b, h, n)


def rwkv_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                state: tuple[jax.Array, jax.Array]):
    """Single-token decode; x: (B, 1, d)."""
    return rwkv_forward(p, x, cfg, state=state)
