"""Batched serving: prefill a batch of prompts, decode greedily.

Demonstrates the KV/state-cache serving path for any architecture
family (attention, SSM, hybrid, enc-dec, VLM caches all supported).

Usage: PYTHONPATH=src python examples/serve_lm.py
           [--arch rwkv6-3b] [--batch 4] [--prompt-len 24] [--new 16]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_reduced
from repro.models.model import LM
from repro.serve.engine import Engine

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)
    frontend = None
    if cfg.frontend is not None:
        frontend = jax.random.normal(
            key, (args.batch, cfg.frontend.n_positions,
                  cfg.frontend.d_frontend), jnp.float32)

    n_front = cfg.frontend.n_positions if cfg.family == "vlm" else 0
    engine = Engine(model, params,
                    t_max=args.prompt_len + n_front + args.new + 1)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new, frontend=frontend)
    wall = time.perf_counter() - t0
    print(f"arch={args.arch} family={cfg.family} "
          f"batch={args.batch} new={args.new}")
    for b in range(args.batch):
        print(f"  seq{b}: prompt..{prompts[b, -4:].tolist()} -> "
              f"{out[b].tolist()}")
    total = args.batch * args.new
    print(f"{total} tokens in {wall:.2f}s "
          f"({total / wall:.1f} tok/s incl. compile)")

    # Consistency check: generated tokens equal the argmax continuation
    # of a full forward pass over (prompt + generated).
    full = jnp.concatenate([prompts, out[:, :-1]], axis=1)
    batch = {"tokens": full}
    if frontend is not None:
        batch["frontend"] = frontend
    logits, _ = model.forward(params, batch)
    ref = jnp.argmax(logits[:, args.prompt_len - 1:], axis=-1)
    ok = bool(jnp.all(ref == out))
    print("decode == forward argmax:", ok)
    assert ok


if __name__ == "__main__":
    main()
