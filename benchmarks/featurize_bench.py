"""Featurization benchmarks: vectorized vs the removed Python-loop path.

``featurize_loop_reference`` preserves, verbatim, the double loop that
``repro.core.features.featurize`` used before vectorization. It exists
so the bit-identity contract stays executable (tests import it) and so
the speedup row below keeps measuring against the real predecessor
rather than a strawman.
"""
from __future__ import annotations

import gc
import itertools
import random
import time

import numpy as np

import repro.core as C
import repro.search as S
from repro.core.dag import halo3d_dag
from repro.core.features import Feature, FeatureMatrix
from repro.core.sync import expanded_names


def featurize_loop_reference(graph, schedules) -> FeatureMatrix:
    """The pre-vectorization ``featurize``: pure-Python double loop."""
    expanded = [expanded_names(graph, s) for s in schedules]
    streams = [s.streams() for s in schedules]
    universe = sorted(set(itertools.chain.from_iterable(expanded)))
    gpu = sorted(graph.gpu_ops())

    feats: list[Feature] = []
    for u, v in itertools.combinations(universe, 2):
        feats.append(Feature("order", u, v))
    for u, v in itertools.combinations(gpu, 2):
        feats.append(Feature("stream", u, v))

    X = np.zeros((len(schedules), len(feats)), dtype=np.int8)
    for i, (names, st) in enumerate(zip(expanded, streams)):
        pos = {n: k for k, n in enumerate(names)}
        for j, f in enumerate(feats):
            if f.kind == "order":
                pu, pv = pos.get(f.u), pos.get(f.v)
                X[i, j] = 1 if (pu is not None and pv is not None
                                and pu < pv) else 0
            else:
                X[i, j] = 1 if st.get(f.u) == st.get(f.v) else 0

    keep = [j for j in range(len(feats))
            if X[:, j].min() != X[:, j].max()]
    return FeatureMatrix([feats[j] for j in keep], X[:, keep])


def featurize_benches() -> list[str]:
    """Bit-identity on the smoke corpus + speedup at 2000 schedules."""
    rows = []

    # Contract check on the smoke corpus (the exhaustive coarse-SpMV
    # space): identical feature lists AND identical matrices.
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    t0 = time.perf_counter()
    fm_vec = C.featurize(g, scheds)
    t_vec = time.perf_counter() - t0
    fm_loop = featurize_loop_reference(g, scheds)
    assert fm_loop.features == fm_vec.features
    assert np.array_equal(fm_loop.X, fm_vec.X)
    rows.append(f"featurize_smoke_corpus,{t_vec * 1e6:.1f},"
                f"bit_identical_n{fm_vec.X.shape[0]}x{fm_vec.X.shape[1]}")

    # Speedup at 2000 schedules on the widest space (halo3d: ~4.7k
    # candidate pair features), vectorized vs the loop predecessor.
    # Loop and vectorized runs are interleaved and the speedup is the
    # median of per-round ratios, so CPU-speed drift on a noisy
    # container hits both sides of each ratio equally.
    gh = halo3d_dag()
    rng = random.Random(0)
    big = [S.random_schedule(gh, 2, rng) for _ in range(2000)]

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        return time.perf_counter() - t0, out

    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        ratios, t_loops, t_vecs = [], [], []
        fm_l = fm_v = None
        for _ in range(3):
            t_loop, fm_l = timed(
                lambda: featurize_loop_reference(gh, big))
            t_vec, fm_v = timed(lambda: C.featurize(gh, big))
            t_loops.append(t_loop)
            t_vecs.append(t_vec)
            ratios.append(t_loop / t_vec)
    finally:
        if gc_was_enabled:
            gc.enable()
    assert fm_l.features == fm_v.features
    assert np.array_equal(fm_l.X, fm_v.X)
    t_loop, t_vec = min(t_loops), min(t_vecs)
    speedup = float(np.median(ratios))
    rows.append(f"featurize_loop_2000,{t_loop / 2000 * 1e6:.2f},"
                f"{t_loop * 1e3:.0f}_ms_total")
    rows.append(f"featurize_vectorized_2000,{t_vec / 2000 * 1e6:.2f},"
                f"{t_vec * 1e3:.0f}_ms_total")
    rows.append(f"featurize_vectorized_speedup,{t_vec / 2000 * 1e6:.2f},"
                f"{speedup:.1f}x")
    return rows
