"""Two-stage surrogate search, the strategy portfolio, and the
run_search budget/clamping semantics introduced alongside them."""
import random

import numpy as np
import pytest

import repro.core as C
import repro.search as S
from repro.core.dag import spmv_dag_fine


# -- run_search clamps over-returning strategies ------------------------------

class OverReturner:
    """Deliberately ignores ``ask`` and returns 10x as many proposals."""

    def __init__(self, graph, n_streams=2, seed=0):
        self.inner = S.RandomSearch(graph, n_streams, seed=seed)
        self.observed = 0

    def propose(self, budget):
        return self.inner.propose(10 * budget)

    def observe(self, schedule, time):
        self.observed += 1


def test_run_search_clamps_over_returning_strategy():
    g = C.spmv_dag()
    strat = OverReturner(g)
    res = S.run_search(g, strat, budget=30, batch_size=8)
    # Without the clamp the first propose(8) alone would push
    # n_proposed to 80 and evaluate the excess.
    assert res.n_proposed == 30
    assert strat.observed == 30
    assert res.cache_hits + res.cache_misses == 30


def test_run_search_clamp_exact_final_batch():
    g = C.spmv_dag()
    res = S.run_search(g, OverReturner(g), budget=7, batch_size=64)
    assert res.n_proposed == 7


# -- sim_budget: stop on simulations, not proposals ---------------------------

def test_run_search_sim_budget_counts_cache_misses():
    g = C.spmv_dag()
    res = S.run_search(g, S.RandomSearch(g, 2, seed=0), budget=None,
                       sim_budget=25, batch_size=1)
    assert res.cache_misses == 25
    # random search re-proposes duplicates: those were free (memo hits)
    assert res.n_proposed >= 25


def test_run_search_sim_budget_terminates_on_exhausted_space():
    """sim_budget larger than the space + a never-exhausting strategy
    (portfolio pads batches with duplicates) must stop via the stall
    guard instead of spinning forever."""
    g = C.spmv_dag()  # 280 distinct implementations with 2 streams
    res = S.run_search(g, S.PortfolioSearch(g, 2, seed=0), budget=None,
                       sim_budget=330, batch_size=1, stall_limit=400)
    assert res.cache_misses == 280  # every implementation simulated
    assert len(res.schedules) == 280


def test_run_search_unbounded_budget_terminates_via_stall_guard():
    """budget=None alone (no sim_budget) with a never-exhausting
    strategy must also terminate once the space is exhausted."""
    g = C.spmv_dag()
    res = S.run_search(g, S.PortfolioSearch(g, 2, seed=0), budget=None,
                       batch_size=1, stall_limit=400)
    assert res.cache_misses == 280
    assert len(res.schedules) == 280


def test_run_search_sim_budget_with_shared_evaluator():
    g = C.spmv_dag()
    ev = S.BatchEvaluator(g)
    S.run_search(g, S.RandomSearch(g, 2, seed=0), budget=None,
                 sim_budget=10, batch_size=1, evaluator=ev)
    # the second run's budget counts only its own fresh simulations
    res2 = S.run_search(g, S.RandomSearch(g, 2, seed=1), budget=None,
                        sim_budget=10, batch_size=1, evaluator=ev)
    assert res2.cache_misses == 10


# -- the ridge surrogate ------------------------------------------------------

def test_surrogate_rank_correlation_held_out():
    """Screening quality floor: Spearman > 0.8 on held-out simulated
    times for a model trained on 300 random SpMV schedules."""
    g = C.spmv_dag()
    rng = random.Random(0)
    train = [S.random_schedule(g, 2, rng) for _ in range(300)]
    held_out = [S.random_schedule(g, 2, rng) for _ in range(200)]
    ev = S.BatchEvaluator(g)
    sur = S.RidgeSurrogate(g)
    for s, t in zip(train, ev.evaluate(train)):
        sur.observe(s, t)
    rho = S.spearman(sur.predict(held_out),
                     np.array(ev.evaluate(held_out)))
    assert rho > 0.8, rho


def test_surrogate_predicts_mean_when_degenerate():
    g = C.spmv_dag()
    sur = S.RidgeSurrogate(g, refit_every=1)
    s = S.random_schedule(g, 2, random.Random(0))
    assert sur.predict([s]) == pytest.approx([0.0])  # no data: mean 0
    sur.observe(s, 3.0)
    sur.observe(s, 5.0)  # identical schedules: no features survive
    np.testing.assert_allclose(sur.predict([s]), [4.0])


def test_spearman_basics():
    assert S.spearman([1, 2, 3], [10, 20, 30]) == pytest.approx(1.0)
    assert S.spearman([1, 2, 3], [3, 2, 1]) == pytest.approx(-1.0)
    assert S.spearman([1, 1, 1], [1, 2, 3]) == 0.0  # degenerate
    # ties get average ranks (scipy convention)
    a, b = [1.0, 2.0, 2.0, 3.0], [1.0, 2.5, 2.5, 4.0]
    assert S.spearman(a, b) == pytest.approx(1.0)


# -- the two-stage strategy ---------------------------------------------------

def test_surrogate_guided_valid_canonical_and_screens():
    g = spmv_dag_fine()
    strat = S.SurrogateGuided(g, 2, seed=0, warmup=20)
    res = S.run_search(g, strat, budget=120, batch_size=4)
    assert res.n_proposed == 120
    for s in res.schedules:
        C.validate_schedule(g, s)
        assert C.canonicalize_streams(s.items) == s.items
    q = strat.screening_quality()
    assert q["n_screened"] > 0
    assert q["n_compared"] > 0
    # every screened->simulated pair was logged with its prediction
    assert len(strat.screen_log) == q["n_compared"]


def test_portfolio_beats_plain_mcts_at_equal_sim_budget():
    """The acceptance bar: on spmv_dag_fine with an equal
    discrete-event-simulation budget the portfolio's best makespan is
    <= plain MCTS's best, with >= 5 surrogate-screened candidates per
    simulation spent."""
    g = spmv_dag_fine()
    sims = 300
    res_m = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=None,
                         sim_budget=sims, batch_size=1)
    # seed_proposals=0 so the greedy phase's unmetered prefix
    # simulations can't subsidize the portfolio
    port = S.PortfolioSearch(g, 2, seed=0, seed_proposals=0)
    res_p = S.run_search(g, port, budget=None, sim_budget=sims,
                         batch_size=1)
    assert port.greedy.n_prefix_sims == 0
    assert res_p.cache_misses == res_m.cache_misses == sims
    assert res_p.best()[1] <= res_m.best()[1]
    q = port.screening_quality()
    assert q["n_screened"] / sims >= 5.0


def test_portfolio_observations_reach_all_phases():
    g = C.spmv_dag()
    port = S.PortfolioSearch(g, 2, seed=0, seed_proposals=4,
                             mcts_proposals=8, warmup=12)
    res = S.run_search(g, port, budget=40)
    assert res.n_proposed == 40
    # every observation fed the MCTS tree; the surrogate trains on each
    # distinct schedule once (duplicates carry no new information)
    assert port.mcts.root.n_rollouts == 40
    assert port.surrogate.surrogate.n_observations == len(res.schedules)


def test_portfolio_survives_exhausted_space():
    """On a tiny space MCTS exhausts mid-portfolio; the portfolio must
    hand over to the surrogate phase instead of ending the search."""
    g = C.Graph()
    g.add_op(C.Op("k1", C.OpKind.GPU, duration=2e-6))
    g.add_op(C.Op("k2", C.OpKind.GPU, duration=3e-6))
    g.add_edge("k1", "k2")
    g.finalize()
    n_space = len(list(C.enumerate_schedules(g, 2)))
    port = S.PortfolioSearch(g, 2, seed=0, seed_proposals=2,
                             mcts_proposals=10**6, warmup=4)
    res = S.run_search(g, port, budget=50)
    assert res.n_proposed == 50  # surrogate random-fills past exhaustion
    assert len(res.schedules) == n_space


# -- vectorized featurizer vs the removed loop path ---------------------------

def test_featurize_bit_identical_to_loop_reference():
    from benchmarks.featurize_bench import featurize_loop_reference

    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    fm_loop = featurize_loop_reference(g, scheds)
    fm_vec = C.featurize(g, scheds)
    assert fm_loop.features == fm_vec.features
    assert fm_vec.X.dtype == np.int8
    np.testing.assert_array_equal(fm_loop.X, fm_vec.X)


def test_featurize_bit_identical_on_fine_corpus():
    from benchmarks.featurize_bench import featurize_loop_reference

    g = spmv_dag_fine()
    rng = random.Random(3)
    scheds = [S.random_schedule(g, 3, rng) for _ in range(150)]
    fm_loop = featurize_loop_reference(g, scheds)
    fm_vec = C.featurize(g, scheds)
    assert fm_loop.features == fm_vec.features
    np.testing.assert_array_equal(fm_loop.X, fm_vec.X)


def test_featurize_like_reference_basis_round_trip():
    """A reference basis applied to its own training set reproduces
    FeatureMatrix.X; applied to unseen schedules it matches the loop
    semantics (absent items -> 0)."""
    g = spmv_dag_fine()
    rng = random.Random(4)
    train = [S.random_schedule(g, 2, rng) for _ in range(60)]
    unseen = [S.random_schedule(g, 2, rng) for _ in range(40)]
    fm = C.featurize(g, train)
    np.testing.assert_array_equal(
        C.featurize_like(g, train, fm), fm.X)

    X_unseen = C.featurize_like(g, unseen, fm)
    assert X_unseen.shape == (len(unseen), len(fm.features))
    for i, s in enumerate(unseen[:10]):
        names = C.expanded_names(g, s)
        pos = {n: k for k, n in enumerate(names)}
        streams = s.streams()
        for j, f in enumerate(fm.features):
            if f.kind == "order":
                pu, pv = pos.get(f.u), pos.get(f.v)
                want = int(pu is not None and pv is not None and pu < pv)
            else:
                want = int(streams.get(f.u) == streams.get(f.v))
            assert X_unseen[i, j] == want
