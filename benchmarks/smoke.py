"""30-second end-to-end smoke pass: search -> labels -> tree -> rules.

Runs the full paper pipeline through the unified search subsystem and
the ``repro.rules.distill`` rules pipeline on the SpMV DAG with a
small MCTS budget. Used two ways:

  * ``PYTHONPATH=src python benchmarks/smoke.py`` prints the summary;
  * ``pytest -m smoke`` runs it as a marked test
    (tests/test_smoke.py), so CI can gate on the hot path cheaply.

:func:`run_backend_smoke` additionally drives a small search through
*every* registered evaluation backend (pool with 2 workers, wallclock
on the tiny CPU demo impls), so the smoke gate keeps all engine
backends honest, not just the default serial one.
:func:`run_autotune_smoke` does the same for the kernel `ParamSpace`
path — a 2-point block-size sweep through the param-space wallclock
evaluator — and :func:`run_store_smoke` / the ``store_path`` form of
the autotune smoke are the CI warm-start gates for the schedule-space
and kernel-space store fingerprints respectively.
:func:`run_rpc_smoke` spins up a two-host localhost evaluation fleet
(``repro.engine.server`` subprocesses sharing one ``EvalStore``) and
gates on a cold ``--backend rpc`` search matching serial plus a warm
one replaying with zero ``engine.measure`` spans.
"""
from __future__ import annotations

import time

import repro.core as C
import repro.rules as R
import repro.search as S


def run_smoke(budget: int = 200, seed: int = 0,
              backend: str | None = None,
              backend_kwargs: dict | None = None) -> dict:
    """One end-to-end search->distill pass; returns a summary dict."""
    t0 = time.perf_counter()
    g = C.spmv_dag()
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=seed), budget=budget,
                       backend=backend, backend_kwargs=backend_kwargs)
    report = R.distill(res)
    times = res.times_array()
    best, best_t = res.best()
    rendered = report.render()
    return {
        "n_evaluations": res.n_proposed,
        "n_schedules": len(res.schedules),
        "cache_hits": res.cache_hits,
        "best_us": best_t * 1e6,
        "spread": float(times.max() / times.min()),
        "n_classes": report.labeling.n_classes,
        "n_features": len(report.feature_matrix.features),
        "n_rulesets": len(report.rulesets),
        "training_error": report.training_error,
        "report_lines": rendered.count("\n"),
        "best_order": " ".join(str(i) for i in best.items
                               if i.name not in ("start", "end")),
        "wall_s": time.perf_counter() - t0,
    }


def run_backend_smoke(budget: int = 48, seed: int = 0) -> dict:
    """A small search through every evaluation backend.

    Analytic backends (sim / vectorized / pool-with-2-workers) must
    return byte-identical (times, cache counters); wallclock runs the
    jitted executor on tiny demo impls with its value-correctness gate
    on. Returns {backend: summary} with the identity verdict under
    ``"analytic_identical"``.
    """
    import repro.engine as E

    g = C.spmv_dag()
    out: dict = {}
    results = {}
    for backend, kwargs in (("sim", {}), ("vectorized", {}),
                            ("pool", {"n_workers": 2, "min_shard": 1})):
        t0 = time.perf_counter()
        res = S.run_search(g, S.MCTSSearch(g, 2, seed=seed),
                           budget=budget, batch_size=8,
                           backend=backend, backend_kwargs=kwargs)
        results[backend] = res
        out[backend] = {
            "n_schedules": len(res.schedules),
            "cache_misses": res.cache_misses,
            "best_us": res.best()[1] * 1e6,
            "wall_s": time.perf_counter() - t0,
        }
    out["analytic_identical"] = all(
        results[b].times == results["sim"].times
        and results[b].cache_misses == results["sim"].cache_misses
        for b in ("vectorized", "pool"))

    small = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    impls, env = E.demo_spmv_impls(small, n=8)
    t0 = time.perf_counter()
    res = S.run_search(small, S.MCTSSearch(small, 2, seed=seed),
                       budget=min(budget, 10),
                       backend="wallclock",
                       backend_kwargs=dict(impls=impls, env=env,
                                           repeats=3))
    out["wallclock"] = {
        "n_schedules": len(res.schedules),
        "cache_misses": res.cache_misses,
        "best_us": res.best()[1] * 1e6,
        "wall_s": time.perf_counter() - t0,
    }
    return out


def run_store_smoke(store_path: str, budget: int = 120,
                    seed: int = 0, trace_path: str | None = None) -> dict:
    """The store validating itself: search twice against ``store_path``.

    The first pass warms the store if it is cold (on a restored CI
    cache it is already warm and measures nothing); the second pass
    runs a *fresh* evaluator against the same file and must replay
    entirely from disk — ``store_hits > 0``, zero measurements,
    byte-identical times. CI calls this after restoring the store from
    the workflow cache, so a stale or corrupt cache fails loudly here
    rather than silently re-simulating.

    The warm pass runs under its own :mod:`repro.obs` telemetry
    registry: the returned ``second`` dict carries ``measure_spans``
    (the number of ``engine.measure`` spans — 0 on a true warm replay,
    the telemetry-side half of the warm-start gate) and ``rounds``
    (``driver.round`` span count). ``trace_path`` additionally writes
    the warm pass as a Perfetto trace (the CI trace artifact).
    """
    from repro import obs

    g = C.spmv_dag()

    def search():
        return S.run_search(g, S.MCTSSearch(g, 2, seed=seed),
                            budget=budget, batch_size=8,
                            backend="vectorized",
                            store_path=store_path)

    first = search()
    exporters = [obs.PerfettoExporter(trace_path)] if trace_path else []
    tel = obs.Telemetry(exporters=exporters)
    with obs.use(tel):
        second = search()
    tel.close()
    spans = tel.spans_by_name()
    assert second.store_hits > 0, \
        "warm search reported no store hits — the store did not persist"
    assert second.cache_misses == 0, \
        f"warm search still measured {second.cache_misses} schedules"
    assert second.times == first.times, \
        "warm replay diverged from the previous run"
    assert first.telemetry is None and second.telemetry is not None, \
        "SearchResult.telemetry must track whether a registry was live"
    return {
        "first": {"misses": first.cache_misses,
                  "store_hits": first.store_hits},
        "second": {"misses": second.cache_misses,
                   "store_hits": second.store_hits,
                   "measure_spans":
                       spans.get("engine.measure", {}).get("count", 0),
                   "rounds": spans.get("driver.round", {}).get("count",
                                                               0)},
        "warm_cache_restored": first.cache_misses == 0,
    }


def run_rpc_smoke(store_path: str, sim_budget: int = 60,
                  seed: int = 0) -> dict:
    """The evaluation service validating itself, end to end.

    Spins up two localhost ``repro.engine.server`` subprocesses that
    share one :class:`~repro.engine.store.EvalStore` file, then runs
    the same halo3d MCTS search three ways:

    1. a serial ``sim`` reference;
    2. a cold ``rpc`` pass against the two-host fleet (the client also
       attaches ``store_path``, so misses dispatched over the wire are
       written through — every party appends whole records to the one
       file, duplicates resolve first-record-wins);
    3. a warm ``rpc`` pass with a fresh evaluator, run under its own
       :mod:`repro.obs` registry — it must replay entirely from the
       shared store: ``store_hits > 0``, zero measurements, and zero
       ``engine.measure`` spans (the telemetry-side gate, mirroring
       :func:`run_store_smoke`).

    All three passes must produce byte-identical times. On a restored
    CI cache even the cold pass replays from disk — reported as
    ``warm_cache_restored``, same semantics as :func:`run_store_smoke`.
    """
    from repro import obs
    from repro.core.dag import halo3d_dag
    from repro.engine.server import spawn_server_process

    g = halo3d_dag()

    def search(backend, **kw):
        return S.run_search(g, S.MCTSSearch(g, 2, seed=seed),
                            budget=None, sim_budget=sim_budget,
                            batch_size=8, backend=backend,
                            store_path=store_path, **kw)

    reference = S.run_search(g, S.MCTSSearch(g, 2, seed=seed),
                             budget=None, sim_budget=sim_budget,
                             batch_size=8, backend="sim")
    servers = [spawn_server_process("halo3d", backend="sim",
                                    store_path=store_path)
               for _ in range(2)]
    try:
        hosts = [s.addr for s in servers]
        cold = search("rpc", backend_kwargs={"hosts": hosts,
                                             "min_shard": 1})
        tel = obs.Telemetry()
        with obs.use(tel):
            warm = search("rpc", backend_kwargs={"hosts": hosts,
                                                 "min_shard": 1})
        tel.close()
    finally:
        for s in servers:
            s.terminate()
    spans = tel.spans_by_name()
    assert cold.times == reference.times, \
        "rpc search diverged from the serial reference"
    assert warm.times == cold.times, \
        "warm rpc replay diverged from the cold run"
    assert warm.store_hits > 0, \
        "warm rpc search reported no store hits"
    assert warm.cache_misses == 0, \
        f"warm rpc search still measured {warm.cache_misses} schedules"
    return {
        "hosts": len(servers),
        "cold": {"misses": cold.cache_misses,
                 "store_hits": cold.store_hits},
        "warm": {"misses": warm.cache_misses,
                 "store_hits": warm.store_hits,
                 "measure_spans":
                     spans.get("engine.measure", {}).get("count", 0),
                 "rounds": spans.get("driver.round", {}).get("count", 0)},
        "rpc_identical_to_sim": cold.times == reference.times,
        "warm_cache_restored": cold.cache_misses == 0,
    }


def run_autotune_smoke(store_path: str | None = None) -> dict:
    """Tiny kernel-space autotune pass: a 2-point ``spmv_mulsum``
    block-size sweep through the param-space ``wallclock`` backend on
    CPU (interpret-mode kernel, value-correctness gate on).

    With ``store_path``, runs the sweep twice and asserts the second
    pass — always a fresh evaluator — replays entirely from disk
    (``store_hits == n_candidates``, zero measurements, identical
    times), mirroring :func:`run_store_smoke` for the kernel
    `ParamSpace` fingerprints so the CI warm-start gate covers them
    too.
    """
    from repro.kernels.autotune import spmv_mulsum_space

    def sweep():
        sp = spmv_mulsum_space(n=128, k=4, block_values=(32, 64),
                               interpret=True)
        t0 = time.perf_counter()
        res = S.run_search(sp, S.ExhaustiveSearch(sp), budget=None,
                           backend="wallclock",
                           backend_kwargs={"repeats": 1},
                           store_path=store_path)
        return sp, res, time.perf_counter() - t0

    sp, first, wall = sweep()
    assert len(first.schedules) == sp.n_candidates() == 2
    best, best_t = first.best()
    out = {
        "n_candidates": len(first.schedules),
        "best": sp.describe(best),
        "best_us": best_t * 1e6,
        "first": {"misses": first.cache_misses,
                  "store_hits": first.store_hits},
        "wall_s": wall,
    }
    if store_path is not None:
        _, second, _ = sweep()
        assert second.cache_misses == 0, \
            f"warm kernel sweep still measured {second.cache_misses}"
        assert second.store_hits == len(first.schedules), \
            "warm kernel sweep was not served entirely by the store"
        assert second.times == first.times, \
            "warm kernel replay diverged from the previous sweep"
        out["second"] = {"misses": second.cache_misses,
                         "store_hits": second.store_hits}
        out["warm_cache_restored"] = first.cache_misses == 0
    return out


def _ooc_child(mode: str, n_rows: int, d: int, block: int,
               headroom_mb: int) -> None:
    """Subprocess body of :func:`run_ooc_smoke`: fit one tree under a
    hard ``RLIMIT_AS`` address-space ceiling.

    The ceiling is self-calibrated — current VmSize (read from
    ``/proc/self/statm`` *after* the imports) plus ``headroom_mb`` —
    so it bounds what the training pass itself may allocate,
    independent of the interpreter's baseline footprint.
    """
    import resource

    import numpy as np

    from repro.rules.trees import fit_from_histograms

    def blocks():
        rng = np.random.default_rng(0)
        for lo in range(0, n_rows, block):
            m = min(block, n_rows - lo)
            yield (rng.random((m, d)) < 0.5).astype(np.int8)

    y = np.empty(n_rows, dtype=np.int64)
    lo = 0
    for X in blocks():
        y[lo:lo + len(X)] = (X[:, 0] * 4 + X[:, 1] * 2 + X[:, 2]) % 3
        lo += len(X)

    with open("/proc/self/statm") as fh:
        vm = int(fh.read().split()[0]) * resource.getpagesize()
    limit = vm + headroom_mb * (1 << 20)
    resource.setrlimit(resource.RLIMIT_AS, (limit, limit))
    try:
        if mode == "ooc":
            tree = fit_from_histograms(blocks, y, max_leaf_nodes=8,
                                       max_depth=7)
        else:
            X = np.concatenate(list(blocks()))
            tree = R.DecisionTree(8, 7).fit(X, y)
    except MemoryError:
        print("RESULT memoryerror")
        return
    print(f"RESULT ok leaves={tree.n_leaves()}")


def run_ooc_smoke(n_rows: int = 60_000, d: int = 192,
                  block: int = 4096, headroom_mb: int = 160) -> dict:
    """Out-of-core distillation under a hard memory ceiling.

    Two subprocesses fit the same ``max_leaf_nodes=8`` tree on the
    same synthetic ``n_rows x d`` 0/1 corpus with ``RLIMIT_AS`` capped
    at (post-import VmSize + ``headroom_mb``). The histogram path must
    finish inside the cap; the dense path — the float64 matrix alone
    is ~``n_rows * d * 8`` bytes, before the presort — must hit
    ``MemoryError``. At the defaults the dense fit needs >250 MB
    against a 160 MB allowance while the out-of-core pass peaks near
    27 MB regardless of row count, so the gate fails loudly if either
    path's memory behavior regresses.
    """
    import os
    import subprocess
    import sys

    here = os.path.abspath(__file__)
    src = os.path.join(os.path.dirname(os.path.dirname(here)), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    # Single-threaded BLAS/OpenMP: thread stacks reserve address space
    # that would eat unpredictable chunks of the RLIMIT_AS allowance.
    env["OMP_NUM_THREADS"] = "1"
    env["OPENBLAS_NUM_THREADS"] = "1"
    out: dict = {}
    for mode in ("ooc", "dense"):
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, here, "--ooc-child", mode, str(n_rows),
             str(d), str(block), str(headroom_mb)],
            capture_output=True, text=True, env=env, timeout=600)
        out[mode] = {
            "ok": proc.returncode == 0 and "RESULT ok" in proc.stdout,
            "memory_error": "RESULT memoryerror" in proc.stdout
            or "MemoryError" in proc.stderr,
            "wall_s": time.perf_counter() - t0,
        }
    out["ooc_ok"] = out["ooc"]["ok"]
    out["dense_ok"] = out["dense"]["ok"]
    assert out["ooc_ok"], \
        "out-of-core fit exceeded the memory ceiling it is built to hold"
    assert not out["dense_ok"] and out["dense"]["memory_error"], \
        "dense fit passed under a ceiling sized to be impossible — " \
        "the gate is no longer binding"
    return out


def main(argv: list[str] | None = None) -> None:
    import sys

    argv = sys.argv[1:] if argv is None else argv
    if argv[:1] == ["--ooc-child"]:
        _ooc_child(argv[1], int(argv[2]), int(argv[3]), int(argv[4]),
                   int(argv[5]))
        return
    out = run_smoke()
    for k, v in out.items():
        print(f"smoke_{k}: {v}")
    for backend, v in run_backend_smoke().items():
        print(f"smoke_backend_{backend}: {v}")
    for k, v in run_autotune_smoke().items():
        print(f"smoke_autotune_{k}: {v}")
    for k, v in run_ooc_smoke().items():
        print(f"smoke_ooc_{k}: {v}")


if __name__ == "__main__":
    main()
