"""Numpy batch simulator: the discrete-event model as array ops.

:func:`repro.core.costmodel.simulate` replays one expanded schedule at a
time through a Python loop. This backend replays a whole *batch* of
schedules at once: every schedule of a graph is a permutation of the
same N ops, so the batch packs into an ``(B, N)`` op-id matrix plus an
``(B, N)`` stream matrix, and one pass over the N positions updates all
B simulations with vectorized numpy ops —

  * per-stream FIFO times and pending stream-wait floors are ``(B, S)``
    arrays updated by fancy-indexed prefix-max;
  * CUDA-event times are a ``(B, N+1)`` array (slot N is a zero-valued
    sentinel that pads variable-length wait sets — harmless under
    ``max`` since all event times are >= 0);
  * rendezvous (PostSend/PostRecv/WaitSend/WaitRecv) is a gather from
    ``(B, C)`` per-channel post-time arrays plus precomputed wire-time
    constants.

Sync-op *insertion* (Table III) is also derived in array form: CES
presence is static per op (any GPU predecessor), CSWE/CER presence is a
vectorized stream comparison over padded predecessor/successor id
tables. No :class:`~repro.core.sync.ExpandedItem` objects are built.

Every floating-point operation mirrors the serial simulator's exact
sequence of IEEE adds/maxes per element, so results are **bit-identical**
to :func:`~repro.core.costmodel.simulate` — locked by an exhaustive
cross-check on the paper SpMV space and randomized property tests on
the fine-grained and halo3d spaces (tests/test_engine_vectorized.py).

One static precondition replaces the serial simulator's runtime
rendezvous asserts: for every WaitRecv channel the matching posts (and
the twin-channel PostSend, when the twin exists in the graph) must be
DAG ancestors of the wait, so they are posted in *every* valid
traversal. All repo graphs guarantee this via their deadlock-avoidance
edges; :class:`VectorizedEvaluator` raises at construction otherwise.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.costmodel import Machine
from repro.core.dag import CommRole, Graph, OpKind, Schedule
from repro.engine.base import EvaluatorBase

_ROLE_NONE, _ROLE_PS, _ROLE_PR, _ROLE_WS, _ROLE_WR = range(5)
_ROLE_PREFIX = {_ROLE_PS: "PostSend", _ROLE_PR: "PostRecv",
                _ROLE_WS: "WaitSend", _ROLE_WR: "WaitRecv"}
# Twin channels of the symmetric-rank rendezvous model (mirrors
# costmodel.simulate's _twin table).
_TWIN = {"_l": "_r", "_r": "_l",
         "_xn": "_xp", "_xp": "_xn", "_yn": "_yp", "_yp": "_yn",
         "_zn": "_zp", "_zp": "_zn"}


def _ancestors(graph: Graph, name: str) -> set[str]:
    out: set[str] = set()
    frontier = list(graph.preds[name])
    while frontier:
        u = frontier.pop()
        if u not in out:
            out.add(u)
            frontier.extend(graph.preds[u])
    return out


def _pad(rows: list[list[int]], sentinel: int) -> np.ndarray:
    width = max(1, max((len(r) for r in rows), default=0))
    out = np.full((len(rows), width), sentinel, dtype=np.int32)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


class GraphTables:
    """Schedule-independent encoding of (graph, machine) for the batch
    simulator; built once per evaluator, reused by every batch."""

    def __init__(self, graph: Graph, machine: Machine,
                 durations: dict[str, float]):
        names = list(graph.ops)
        self.op_id = {n: i for i, n in enumerate(names)}
        n = self.n_ops = len(names)
        ops = [graph.ops[name] for name in names]

        self.is_gpu = np.array([op.kind is OpKind.GPU for op in ops])
        self.dur = np.array([durations[name] for name in names])
        # What each op adds to the host clock when it executes: async
        # launch overhead for GPU ops, the op duration for CPU ops.
        self.cpu_add = np.where(self.is_gpu, machine.launch_overhead_s,
                                self.dur)

        gpu_pred_rows = [[self.op_id[u] for u in sorted(graph.preds[name])
                          if graph.ops[u].kind is OpKind.GPU]
                         for name in names]
        gpu_succ_rows = [[self.op_id[v] for v in sorted(graph.succs[name])
                          if graph.ops[v].kind is OpKind.GPU]
                         for name in names]
        self.gpu_preds = _pad(gpu_pred_rows, sentinel=n)
        self.gpu_succs = _pad(gpu_succ_rows, sentinel=n)
        self.has_gpu_pred = np.array([bool(r) for r in gpu_pred_rows])
        # CER is unconditionally required when any successor is a CPU op;
        # GPU successors contribute a per-schedule stream comparison.
        self.cer_static = np.array(
            [any(graph.ops[v].kind is not OpKind.GPU
                 for v in graph.succs[name]) for name in names])

        role_of = {CommRole.POST_SEND: _ROLE_PS,
                   CommRole.POST_RECV: _ROLE_PR,
                   CommRole.WAIT_SEND: _ROLE_WS,
                   CommRole.WAIT_RECV: _ROLE_WR}
        self.role = np.array([role_of.get(op.comm_role, _ROLE_NONE)
                              for op in ops], dtype=np.int8)
        self.is_post = (self.role == _ROLE_PS) | (self.role == _ROLE_PR)
        self.is_wait = (self.role == _ROLE_WS) | (self.role == _ROLE_WR)

        # Channels: the op-name suffix after the role prefix (exactly
        # what simulate() strips at runtime), one slot per suffix.
        suffixes: dict[str, int] = {}
        chan = np.zeros(n, dtype=np.int32)
        for i, (name, op) in enumerate(zip(names, ops)):
            r = int(self.role[i])
            if r == _ROLE_NONE:
                continue
            sfx = name.removeprefix(_ROLE_PREFIX[r])
            chan[i] = suffixes.setdefault(sfx, len(suffixes))
        self.chan = chan
        c = max(1, len(suffixes))
        send_bytes = np.zeros(c)
        recv_bytes = np.zeros(c)
        self.twin = np.arange(c, dtype=np.int32)
        for i, (name, op) in enumerate(zip(names, ops)):
            r = int(self.role[i])
            if r == _ROLE_PS:
                send_bytes[chan[i]] = op.comm_bytes
            elif r == _ROLE_PR:
                recv_bytes[chan[i]] = op.comm_bytes
        # Wire times are schedule-independent; precompute them with the
        # same transfer_duration() call the serial simulator makes.
        self.send_xfer = np.array(
            [machine.transfer_duration(b) for b in send_bytes])
        self.recv_xfer = np.array(
            [machine.transfer_duration(b) for b in recv_bytes])

        # Static rendezvous resolution + the ancestor precondition that
        # replaces simulate()'s runtime asserts (see module docstring).
        # Post/wait ops collapse to slot arithmetic on one (B, 2C) post
        # array — send channel c at slot c, recv channel c at slot C+c:
        #   post op  -> write cpu_t to post_slot[op]
        #   wait op  -> cpu_t = max(cpu_t,
        #                   max(post[wait_a[op]], post[wait_b[op]])
        #                   + wait_xfer[op])
        # (for WaitSend both slots are the send slot; max(x, x) == x).
        self.post_slot = np.zeros(n, dtype=np.int32)
        self.wait_a = np.zeros(n, dtype=np.int32)
        self.wait_b = np.zeros(n, dtype=np.int32)
        self.wait_xfer = np.zeros(n)
        for i, name in enumerate(names):
            r = int(self.role[i])
            ci = chan[i]
            if r == _ROLE_PS:
                self.post_slot[i] = ci
            elif r == _ROLE_PR:
                self.post_slot[i] = c + ci
            elif r in (_ROLE_WS, _ROLE_WR):
                sfx = name.removeprefix(_ROLE_PREFIX[r])
                anc = _ancestors(graph, name)
                if r == _ROLE_WS:
                    if f"PostSend{sfx}" not in anc:
                        raise ValueError(
                            f"vectorized backend: PostSend{sfx} must be "
                            f"a DAG ancestor of {name}")
                    self.wait_a[i] = self.wait_b[i] = ci
                    self.wait_xfer[i] = self.send_xfer[ci]
                    continue
                twin_sfx = _TWIN.get(sfx, sfx)
                if f"PostSend{twin_sfx}" not in graph.ops:
                    twin_sfx = sfx
                if (f"PostSend{twin_sfx}" not in anc
                        or f"PostRecv{sfx}" not in anc):
                    raise ValueError(
                        f"vectorized backend: PostSend{twin_sfx} and "
                        f"PostRecv{sfx} must be DAG ancestors of {name} "
                        "(add rendezvous edges, or use backend='sim')")
                self.twin[ci] = suffixes[twin_sfx]
                self.wait_a[i] = self.twin[ci]
                self.wait_b[i] = c + ci
                self.wait_xfer[i] = self.recv_xfer[ci]

        self.sync_op_s = machine.sync_op_s


class _Section:
    """Per-position slices of the rows where a (B, N) mask is True.

    One global ``nonzero`` + ``searchsorted`` replaces the per-column
    ``np.nonzero(mask[:, i])`` the inner loop would otherwise pay N
    times; :meth:`split` groups any aligned per-(row, position) value
    array the same way, so the loop body only does gathers on
    pre-sliced views.
    """

    def __init__(self, mask: np.ndarray):
        n = mask.shape[1]
        self._cols, self._rows = np.nonzero(mask.T)
        self._bounds = np.searchsorted(self._cols, np.arange(n + 1))
        self.rows = self._slices(self._rows)

    def _slices(self, values: np.ndarray) -> list[np.ndarray]:
        b = self._bounds
        return [values[b[i]:b[i + 1]] for i in range(len(b) - 1)]

    def split(self, arr: np.ndarray) -> list[np.ndarray]:
        """Group ``arr[(b, i), ...]`` values by position ``i``."""
        return self._slices(np.moveaxis(arr, 0, 1)[self._cols, self._rows])


def simulate_encoded(tables: GraphTables, encoded: np.ndarray
                     ) -> np.ndarray:
    """Makespans for a ``(B, 2, N)`` encoded batch (op ids row 0,
    streams row 1; see :meth:`EvaluatorBase._encode_batch`),
    bit-identical to per-schedule
    :func:`repro.core.costmodel.simulate`."""
    T = tables
    B, N = encoded.shape[0], encoded.shape[2]
    if B == 0:
        return np.zeros(0)
    order = encoded[:, 0, :]                     # (B, N) op ids
    streams = encoded[:, 1, :]                   # (B, N) stream or -1
    rows = np.arange(B, dtype=np.intp)[:, None]
    en = N + 1
    ev_base = (rows * en)[:, :, None]            # (B, 1, 1) event rows

    # stream_of[b, op] = the stream op runs on in schedule b (-9 for the
    # sentinel op slot; unused CPU slots keep -1).
    stream_of = np.full((B, en), -9, dtype=np.int32)
    np.put_along_axis(stream_of, order, streams, axis=1)
    so_flat = stream_of.ravel()

    gp = T.gpu_preds[order]                      # (B, N, P)
    gs = T.gpu_succs[order]                      # (B, N, Q)
    own = streams[:, :, None]
    is_gpu_at = T.is_gpu[order]                  # (B, N)
    # Table III in array form: which positions carry a CES / CSWE / CER.
    cswe_mask = (gp < N) & (so_flat[ev_base + gp] != own)   # per-wait
    has_cswe = is_gpu_at & cswe_mask.any(axis=2)
    has_ces = ~is_gpu_at & T.has_gpu_pred[order]
    has_cer = is_gpu_at & (
        T.cer_static[order]
        | ((gs < N) & (so_flat[ev_base + gs] != own)).any(axis=2))

    n_streams = max(1, int(streams.max()) + 1)
    n_chan = T.send_xfer.shape[0]

    # Bulk flat-index arrays (state buffers are 1-D: slot b*width+col;
    # fancy indexing on 1-D arrays beats 2-D index pairs), grouped into
    # per-position views up front so the loop body is pure arithmetic.
    sidx = rows * n_streams + np.maximum(streams, 0)     # GPU stream slot
    ev_gather = ev_base + np.where(cswe_mask, gp, N)
    ces_gather = ev_base + gp                    # sentinel -> 0.0
    pidx = rows * (2 * n_chan) + T.post_slot[order]
    aidx = rows * (2 * n_chan) + T.wait_a[order]
    bidx = rows * (2 * n_chan) + T.wait_b[order]

    ces = _Section(has_ces)
    ces_ev = ces.split(ces_gather)
    cswe = _Section(has_cswe)
    cswe_ev = cswe.split(ev_gather)
    cswe_sidx = cswe.split(sidx)
    gpu = _Section(is_gpu_at)
    gpu_sidx = gpu.split(sidx)
    gpu_dur = gpu.split(T.dur[order])
    post = _Section(T.is_post[order])
    post_pidx = post.split(pidx)
    wait = _Section(T.is_wait[order])
    wait_aidx = wait.split(aidx)
    wait_bidx = wait.split(bidx)
    wait_xf = wait.split(T.wait_xfer[order])
    cer = _Section(has_cer)
    cer_widx = cer.split(rows * en + order)
    cer_sidx = cer.split(sidx)

    cpu_add_t = np.ascontiguousarray(T.cpu_add[order].T)  # (N, B)
    cpu_t = np.zeros(B)
    stream_t = np.zeros(B * n_streams)
    stream_wait = np.zeros(B * n_streams)
    event_t = np.zeros(B * en)                   # op-id slots; slot N is
    post_t = np.zeros(B * 2 * n_chan)            # the 0.0 pad sentinel
    sync = T.sync_op_s

    for i in range(N):
        # CES-b4-op: host blocks on every GPU predecessor's event.
        m = ces.rows[i]
        if m.size:
            cpu_t[m] += sync
            ev = event_t[ces_ev[i]]              # (k, P); pads read 0.0
            cpu_t[m] = np.maximum(cpu_t[m], ev.max(axis=1))

        # CSWE-b4-op: op's stream waits on cross-stream pred events.
        m = cswe.rows[i]
        if m.size:
            cpu_t[m] += sync
            floor = event_t[cswe_ev[i]].max(axis=1)
            idx = cswe_sidx[i]
            stream_wait[idx] = np.maximum(stream_wait[idx], floor)

        # The op itself: one fused host-clock add (launch overhead for
        # GPU, duration for CPU), then kind/role-specific effects.
        cpu_t += cpu_add_t[i]
        m = gpu.rows[i]
        if m.size:
            idx = gpu_sidx[i]
            start = np.maximum(np.maximum(cpu_t[m], stream_t[idx]),
                               stream_wait[idx])
            stream_wait[idx] = 0.0
            stream_t[idx] = start + gpu_dur[i]

        m = post.rows[i]
        if m.size:
            post_t[post_pidx[i]] = cpu_t[m]
        m = wait.rows[i]
        if m.size:
            arrived = np.maximum(post_t[wait_aidx[i]],
                                 post_t[wait_bidx[i]]) + wait_xf[i]
            cpu_t[m] = np.maximum(cpu_t[m], arrived)

        # CER-after-op: snapshot the producer stream's completion time.
        m = cer.rows[i]
        if m.size:
            event_t[cer_widx[i]] = stream_t[cer_sidx[i]]
            cpu_t[m] += sync

    return np.maximum(cpu_t, stream_t.reshape(B, n_streams).max(axis=1))


def simulate_batch(tables: GraphTables,
                   schedules: Sequence[Schedule]) -> np.ndarray:
    """Makespans for a batch of complete valid schedules, bit-identical
    to per-schedule :func:`repro.core.costmodel.simulate`."""
    T = tables
    n = T.n_ops
    encoded = np.empty((len(schedules), 2, n), dtype=np.int32)
    op_id = T.op_id
    for b, sched in enumerate(schedules):
        items = sched.items
        if len(items) != n:
            raise ValueError(
                f"schedule has {len(items)} items, graph has {n} ops")
        encoded[b, 0, :] = [op_id[i.name] for i in items]
        encoded[b, 1, :] = [-1 if i.stream is None else i.stream
                            for i in items]
    return simulate_encoded(tables, encoded)


class VectorizedEvaluator(EvaluatorBase):
    """Evaluation backend running :func:`simulate_batch` on all cache
    misses of a batch at once."""

    backend = "vectorized"

    def __init__(self, graph: Graph, machine: Machine | None = None,
                 noise_sigma: float = 0.0, noise_seed: int = 0,
                 **base_kwargs):
        super().__init__(graph, machine, noise_sigma, noise_seed,
                         **base_kwargs)
        if self.graph is None:
            raise TypeError(
                "the vectorized backend simulates schedules of a "
                f"Graph; design space {self.space.name!r} has no graph "
                "(use backend='sim' for spaces with an analytic cost, "
                "or 'wallclock' for kernel runners)")
        self._tables = GraphTables(self.graph, self.machine,
                                   self._durations)

    def _measure_batch(self, schedules: Sequence[Schedule],
                       encoded: np.ndarray | None = None) -> list[float]:
        if encoded is None:
            return simulate_batch(self._tables, schedules).tolist()
        return simulate_encoded(self._tables, encoded).tolist()
