"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

_MODULES: dict[str, str] = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "smollm-360m": "repro.configs.smollm_360m",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "moonshot-v1-16b-a3b": "repro.configs.moonshot_v1_16b_a3b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "internvl2-2b": "repro.configs.internvl2_2b",
}

ARCHS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    return importlib.import_module(_MODULES[name]).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return importlib.import_module(_MODULES[name]).REDUCED
