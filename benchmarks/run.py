"""Benchmark harness — one function per paper table/figure, plus kernel,
substrate, featurization, evaluation-engine, tree-kernel/surrogate, and
at-scale search benches.

Prints ``name,us_per_call,derived`` CSV; ``--json PATH`` additionally
writes the same rows as machine-readable JSON
(``[{"name":..., "us_per_call":..., "derived":...}, ...]``) so the
perf trajectory can accumulate across PRs, e.g.::

    PYTHONPATH=src python benchmarks/run.py --json BENCH_4.json

``--baseline BENCH_N.json`` compares the rows just measured against a
committed baseline file and prints per-row deltas; rows slower than
``--regression-threshold`` (fractional, default 0.5 — benchmark noise
on shared CI runners is real) exit nonzero, so the BENCH_2..N
trajectory is checkable instead of advisory::

    PYTHONPATH=src python benchmarks/run.py --baseline BENCH_7.json

``--trace PATH`` / ``--telemetry`` attach the :mod:`repro.obs`
registry for the whole bench run (Perfetto trace / summary table) —
the way to see where a sweep's wall time actually goes.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# Allow ``python benchmarks/run.py`` (script dir on sys.path, repo root
# not): the ``benchmarks`` package lives one level up.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.at_scale import at_scale_benches
from benchmarks.autotune_bench import autotune_benches
from benchmarks.driver_bench import driver_benches
from benchmarks.engine_bench import engine_benches
from benchmarks.featurize_bench import featurize_benches
from benchmarks.kernels_bench import (kernel_benches, model_benches,
                                      search_eval_benches)
from benchmarks.paper import (fig1_spread, fig4_labels, fig5_tree,
                              granularity_ablation, noise_robustness,
                              stepdag_overlap, table5_accuracy,
                              tables678_rules)
from benchmarks.trees_bench import trees_benches

BENCH_FNS = (fig1_spread, fig4_labels, fig5_tree, table5_accuracy,
             tables678_rules, stepdag_overlap, granularity_ablation,
             noise_robustness, featurize_benches, trees_benches,
             engine_benches, autotune_benches, driver_benches,
             at_scale_benches, search_eval_benches, kernel_benches,
             model_benches)


def parse_row(row: str) -> dict:
    """``name,us_per_call,derived`` CSV line -> JSON-ready dict.

    ``derived`` may itself contain commas (class-size lists etc.), so
    only the first two fields are split off.
    """
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def compare_to_baseline(records: list[dict], baseline: list[dict],
                        threshold: float = 0.5
                        ) -> tuple[list[str], list[str]]:
    """Per-row deltas of ``records`` vs a committed baseline.

    Returns ``(report_lines, regressions)``: one human line per row
    present in both (delta = (new - old) / old on ``us_per_call``;
    positive = slower), with rows beyond ``threshold`` flagged and
    collected into ``regressions``. Rows only on one side are listed
    but never fail the comparison — the bench set grows every PR.
    """
    base = {r["name"]: r["us_per_call"] for r in baseline}
    new = {r["name"]: r["us_per_call"] for r in records}
    lines: list[str] = []
    regressions: list[str] = []
    for name, us in new.items():
        old = base.get(name)
        if old is None:
            lines.append(f"  new       {name}: {us:.2f} us (no baseline)")
            continue
        delta = (us - old) / old if old else 0.0
        verdict = "ok"
        if delta > threshold:
            verdict = "REGRESSED"
            regressions.append(name)
        lines.append(f"  {verdict:<9} {name}: {old:.2f} -> {us:.2f} us "
                     f"({delta:+.1%})")
    for name in base:
        if name not in new:
            lines.append(f"  gone      {name}: only in baseline")
    return lines, regressions


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write rows as a JSON list to PATH")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help="compare rows against a committed BENCH_N.json "
                         "and exit nonzero on regressions beyond "
                         "--regression-threshold")
    ap.add_argument("--regression-threshold", type=float, default=0.5,
                    metavar="FRAC",
                    help="fractional us_per_call slowdown vs the "
                         "baseline that counts as a regression "
                         "(default 0.5 = 50%%)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Perfetto/Chrome trace of the whole "
                         "bench run to PATH (repro.obs)")
    ap.add_argument("--telemetry", action="store_true",
                    help="print the telemetry summary table after the "
                         "run")
    args = ap.parse_args()

    tel = None
    if args.trace or args.telemetry:
        from repro import obs
        exporters = [obs.PerfettoExporter(args.trace)] if args.trace \
            else []
        tel = obs.Telemetry(exporters=exporters)
        obs.set_current(tel)

    rows: list[str] = []
    print("name,us_per_call,derived")
    for fn in BENCH_FNS:
        for row in fn():
            print(row, flush=True)
            rows.append(row)
    records = [parse_row(row) for row in rows]

    if tel is not None:
        if args.telemetry:
            print(tel.summary(), flush=True)
        tel.close()
        if args.trace:
            print(f"# trace written to {args.trace}", flush=True)
        from repro import obs
        obs.set_current(None)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} rows to {args.json}", flush=True)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        lines, regressions = compare_to_baseline(
            records, baseline, args.regression_threshold)
        print(f"# vs baseline {args.baseline} "
              f"(threshold {args.regression_threshold:+.0%}):")
        for line in lines:
            print(line)
        if regressions:
            print(f"# {len(regressions)} row(s) regressed: "
                  f"{', '.join(regressions)}")
            sys.exit(1)
        print("# no regressions", flush=True)


if __name__ == "__main__":
    main()
