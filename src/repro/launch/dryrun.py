import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at first
# initialization, and the production meshes need 512 host devices.

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ARCHS                      # noqa: E402
from repro.configs.shapes import SHAPES, applicable  # noqa: E402
from repro.launch import costs as costs_mod          # noqa: E402
from repro.launch import hlo as hlo_mod              # noqa: E402
from repro.launch.inputs import build_cell           # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, on the 16x16 single-pod
mesh AND the 2x16x16 multi-pod mesh: lower + compile the step function
from ShapeDtypeStruct stand-ins (no allocation), print
memory_analysis / cost_analysis, run the loop-corrected HLO analyzer,
and persist a JSON record for EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "experiments" / "dryrun"


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             out_dir: pathlib.Path = OUT_DIR, force: bool = False,
             tag: str = "", **build_kw) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = out_dir / mesh_name / f"{arch}__{shape}{tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    out_path.parent.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cell = build_cell(arch, shape, mesh, **build_kw)
    kind = cell.meta["kind"]
    donate = (0, 1) if kind == "train" else \
        ((1,) if kind == "decode" else ())
    with mesh:
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        analysis = hlo_mod.analyze(compiled.as_text())

    mem_stats = {k: int(getattr(mem, k)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes")}
    rf = costs_mod.roofline(
        cell.cfg, shape, kind, chips,
        hlo_flops_per_chip=analysis.dot_flops,
        collective_bytes_per_chip=analysis.total_collective_bytes,
        memory_stats=mem_stats,
        collective_bytes_f32=analysis.collective_bytes_f32)
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "chips": chips, "kind": kind,
        "meta": {k: v for k, v in cell.meta.items() if k != "rules"},
        "rules": cell.meta["rules"],
        "memory_analysis": mem_stats,
        "per_device_bytes": mem_stats["argument_size_in_bytes"] +
        mem_stats["temp_size_in_bytes"] +
        mem_stats["output_size_in_bytes"] -
        mem_stats["alias_size_in_bytes"],
        # XLA-CPU f32 shadow copies of bf16 buffers (absent on TPU).
        # The estimate floors at args+outputs (real data that must be
        # resident) since convert instances over-count shared buffers:
        "cpu_upcast_bytes": analysis.cpu_upcast_bytes,
        "per_device_bytes_tpu_estimate": max(
            mem_stats["argument_size_in_bytes"] +
            mem_stats["output_size_in_bytes"] -
            mem_stats["alias_size_in_bytes"],
            mem_stats["argument_size_in_bytes"] +
            mem_stats["temp_size_in_bytes"] +
            mem_stats["output_size_in_bytes"] -
            mem_stats["alias_size_in_bytes"] -
            int(analysis.cpu_upcast_bytes)),
        "cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
        "hlo": {
            "dot_flops_per_chip": analysis.dot_flops,
            "collective_bytes_f32": analysis.collective_bytes_f32,
            "collective_bytes": analysis.collective_bytes,
            "collective_count": analysis.collective_count,
            "loop_trips": analysis.loop_trips[:64],
        },
        "roofline": rf.to_dict(),
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
    }
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                if applicable(a, s):
                    todo.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]

    failures = []
    for multi_pod in meshes:
        for a, s in todo:
            tag = "pod2x16x16" if multi_pod else "pod16x16"
            try:
                rec = run_cell(a, s, multi_pod=multi_pod,
                               force=args.force)
                rl = rec["roofline"]
                tpu_gb = rec.get("per_device_bytes_tpu_estimate",
                                 rec["per_device_bytes"]) / 1e9
                print(f"[OK] {tag} {a} x {s}: "
                      f"{rec['per_device_bytes'] / 1e9:.2f} GB/dev "
                      f"(tpu-est {tpu_gb:.2f}), "
                      f"dom={rl['dominant']}, "
                      f"frac={rl['roofline_fraction']:.3f}, "
                      f"compile={rec['timings']['compile_s']:.0f}s",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                failures.append((tag, a, s, repr(e)))
                print(f"[FAIL] {tag} {a} x {s}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed: "
                         f"{[(t, a, s) for t, a, s, _ in failures]}")


if __name__ == "__main__":
    main()
