"""Model configuration for the architecture zoo.

One :class:`ModelConfig` instance per assigned architecture lives in
``repro/configs/<arch>.py``; reduced variants drive CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

MlpKind = Literal["swiglu", "geglu", "relu2", "gelu"]
BlockKind = Literal["attn", "mamba", "rwkv"]


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int | None = None     # defaults to d_ff
    capacity_factor: float = 1.25
    # "einsum": GShard-style dispatch matmuls (paper-era baseline).
    # "gather": take/segment_sum dispatch (beyond-paper optimization).
    dispatch: Literal["einsum", "gather"] = "einsum"
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    """Modality frontend stub ([audio]/[vlm] archs).

    The brief: frontends are STUBS — input_specs() provides precomputed
    frame/patch embeddings of shape (batch, n_positions, d_frontend); a
    learned projection maps d_frontend -> d_model.
    """

    kind: Literal["audio", "vision"]
    n_positions: int        # frames (audio) or patches (vision)
    d_frontend: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None          # defaults to d_model // n_heads
    mlp: MlpKind = "swiglu"
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-5
    moe: MoeConfig | None = None
    moe_every: int = 1                 # apply MoE MLP every k-th layer
    # Hybrid models: repeating per-period block pattern; n_layers must be
    # a multiple of len(pattern). E.g. Jamba 1:7 attn:mamba.
    pattern: tuple[BlockKind, ...] | None = None
    # SSM / linear-recurrence dims.
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    rwkv_head_dim: int = 64
    # Encoder-decoder (whisper): encoder layer count; decoder uses
    # n_layers. Cross-attention in every decoder layer.
    n_encoder_layers: int = 0
    frontend: FrontendConfig | None = None
    # Attention variants.
    attn_window: int | None = None     # sliding window (None = full)
    attn_logit_softcap: float | None = None
    # Tensor-parallel head padding (Megatron-style): q-heads are padded
    # up to a multiple of this so the heads dim shards evenly; dummy
    # heads are masked out of the output (exact semantics). The launch
    # layer sets this to the model-axis extent; 1 = no padding.
    head_pad_to: int = 1
    # Numerics / training.
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    z_loss: float = 1e-4
    tie_embeddings: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None \
            else self.d_model // self.n_heads

    def head_layout(self) -> tuple[int, int, int]:
        """(stored_kv_heads K, q_per_stored g_p, padded_q_heads Hq_p).

        The TPU-native GQA layout for ``head_pad_to`` = tp-way tensor
        parallelism (vLLM-style): KV heads are *duplicated* r = tp/hkv
        times so the stored-KV dim shards evenly, and q heads are
        arranged in K groups of g_p = ceil(g / r) slots (padded with
        masked dummy heads when g doesn't split evenly). Falls back to
        the unpadded layout (attention replicated on the model axis)
        when no layout with <= 2x q-head waste exists — only hits the
        smallest archs (smollm's 5 kv heads, whisper's 6).
        """
        hq, hkv, tp = self.n_heads, self.n_kv_heads, self.head_pad_to
        g = hq // hkv
        if tp <= 1 or hkv % tp == 0:
            return hkv, g, hq
        if tp % hkv != 0:
            return hkv, g, hq          # no clean duplication: fallback
        r = tp // hkv
        g_p = -(-g // r)               # ceil
        hq_p = hkv * r * g_p
        if hq_p > 2 * hq:
            return hkv, g, hq          # too wasteful: fallback
        return hkv * r, g_p, hq_p

    @property
    def n_heads_padded(self) -> int:
        return self.head_layout()[2]

    @property
    def q_per_kv(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def block_kinds(self) -> tuple[BlockKind, ...]:
        """Per-layer block kinds, length n_layers."""
        if self.pattern is None:
            kind: BlockKind = "rwkv" if self.family == "ssm" else "attn"
            return tuple([kind] * self.n_layers)
        assert self.n_layers % len(self.pattern) == 0
        reps = self.n_layers // len(self.pattern)
        return tuple(self.pattern) * reps

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return (idx % self.moe_every) == (self.moe_every - 1)

    def param_count(self) -> float:
        """Analytic parameter count (total, incl. all experts)."""
        c = self
        d, dh = c.d_model, c.head_dim
        total = 2 * c.vocab * d if not c.tie_embeddings else c.vocab * d
        kinds = c.block_kinds()
        for i, kind in enumerate(kinds):
            total += d  # pre-norm scale
            if kind == "attn":
                total += d * (c.n_heads * dh) + 2 * d * (c.n_kv_heads * dh)
                total += (c.n_heads * dh) * d
                if c.qkv_bias:
                    total += (c.n_heads + 2 * c.n_kv_heads) * dh
            elif kind == "mamba":
                di = c.mamba_expand * d
                total += d * 2 * di            # in_proj
                total += di * c.mamba_d_conv   # conv
                total += di * (2 * c.mamba_d_state + 1) + di  # x_proj,dt
                total += di * d                # out_proj
                total += di * c.mamba_d_state + di  # A, D
            elif kind == "rwkv":
                # r,k,v,g,o projections + decay/mix params.
                total += 5 * d * d + 4 * d
            total += d  # mlp pre-norm
            if c.is_moe_layer(i):
                de = c.moe.d_expert or c.d_ff
                n_mats = 3 if c.mlp in ("swiglu", "geglu") else 2
                total += (c.moe.n_experts + c.moe.n_shared) * \
                    n_mats * d * de
                total += d * c.moe.n_experts   # router
            else:
                n_mats = 3 if c.mlp in ("swiglu", "geglu") else 2
                total += n_mats * d * c.d_ff
        total += d  # final norm
        # Encoder stack (whisper): attention + dense mlp per layer, plus
        # decoder cross-attention (counted here, used in blocks).
        if c.family == "encdec":
            enc = c.n_encoder_layers * (
                2 * d + d * (c.n_heads * dh) + 2 * d * (c.n_kv_heads * dh)
                + (c.n_heads * dh) * d + 2 * d * c.d_ff)
            cross = c.n_layers * (
                d + d * (c.n_heads * dh) + 2 * d * (c.n_kv_heads * dh)
                + (c.n_heads * dh) * d)
            total += enc + cross
        if c.frontend is not None:
            total += c.frontend.d_frontend * d
        return float(total)

    def active_param_count(self) -> float:
        """Params touched per token (MoE: only routed top-k active)."""
        if self.moe is None:
            return self.param_count()
        c = self
        de = c.moe.d_expert or c.d_ff
        n_mats = 3 if c.mlp in ("swiglu", "geglu") else 2
        n_moe_layers = sum(c.is_moe_layer(i) for i in range(c.n_layers))
        inactive = n_moe_layers * \
            (c.moe.n_experts - c.moe.top_k) * n_mats * c.d_model * de
        return self.param_count() - inactive
