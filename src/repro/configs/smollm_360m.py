"""smollm-360m [dense]: llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, mlp="swiglu",
)

REDUCED = ModelConfig(
    name="smollm-360m-reduced", family="dense",
    n_layers=2, d_model=96, n_heads=3, n_kv_heads=1,
    d_ff=256, vocab=512, mlp="swiglu",
)
