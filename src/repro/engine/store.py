"""Persistent content-addressed evaluation store: measurements that
outlive the process.

The paper's whole cost is measurement — MCTS explores an enormous
schedule space and every node expansion pays a simulation or a
wall-clock run, so the memo cache *is* the budget (§III,
``sim_budget``). :class:`~repro.engine.base.EvaluatorBase` already
keys everything on canonical ``(B, 2, N)`` row bytes; this module adds
the one missing layer — an on-disk store keyed by
``(fingerprint, canonical row bytes) -> base time`` — so every search
(CI runs, benchmark sweeps, many users tuning the same graph) starts
warm instead of re-simulating from zero.

Contracts:

* **Content-addressed.** The fingerprint
  (:func:`store_fingerprint`) hashes the graph's ops and edges, the
  machine/durations table, and the backend's objective identity, so
  results from different graphs, machines, or objectives can never
  collide — one store file safely serves many searches.
* **Noiseless base times only.** Measurement noise stays parent-side,
  seeded per ``(canonical key, draw index)``
  (see :mod:`repro.engine.base`), so the store holds the underlying
  base time and noisy searches are bit-reproducible warm or cold.
* **Crash-safe, append-only.** Records are length-prefixed and
  CRC-checksummed; writers only ever append whole records with a
  single ``O_APPEND`` write, so concurrent writers interleave at
  record granularity and a crash can corrupt at most the file tail.
  :meth:`EvalStore.open`-time parsing truncates a corrupt tail and
  keeps every intact record.

File format (little-endian)::

    magic:  b"REPRO-EVALSTORE-v1\\n"
    record: u32 payload_len | payload | u32 crc32(payload)
    payload: fingerprint (16 bytes) | canonical row bytes | f64 time

Duplicate keys may appear in the file (concurrent writers racing the
same miss); the first record wins on load — all writers of a given
``(fingerprint, key)`` measured the same deterministic quantity.
"""
from __future__ import annotations

import hashlib
import os
import struct
import zlib
from typing import Iterable

from repro.core.costmodel import Machine
from repro.core.dag import Graph

MAGIC = b"REPRO-EVALSTORE-v1\n"
FINGERPRINT_SIZE = 16
_LEN = struct.Struct("<I")
_TIME = struct.Struct("<d")
# payload = fingerprint + key (>= 1 encoded position = 8 bytes) + time
_MIN_PAYLOAD = FINGERPRINT_SIZE + _TIME.size


def store_fingerprint(graph: Graph, machine: Machine,
                      durations: dict[str, float],
                      objective: str) -> bytes:
    """16-byte content address of *what a base time means*.

    Hashes everything that determines the mapping
    ``canonical row bytes -> base time``: the graph's ops (all cost
    metadata — the canonical encoding only carries op *indices*, so op
    identity must come from here), its edge set, the machine constants,
    the resolved per-op duration table, and the backend's objective
    identity (``"analytic"`` for the bit-identical sim/vectorized/pool
    family — their results are interchangeable by construction, so they
    deliberately *share* a fingerprint and warm-start each other —
    vs ``"wallclock:..."`` for measured time). blake2b is stable
    across processes and ``PYTHONHASHSEED`` values.
    """
    h = hashlib.blake2b(digest_size=FINGERPRINT_SIZE)
    h.update(b"objective=" + objective.encode() + b"\n")
    h.update(repr(machine).encode() + b"\n")
    for name in sorted(graph.ops):
        op = graph.ops[name]
        h.update(repr((op.name, op.kind.value, op.flops, op.bytes_hbm,
                       op.comm_bytes, op.comm_role.value, op.duration,
                       durations.get(name))).encode())
    for u in sorted(graph.succs):
        for v in sorted(graph.succs[u]):
            h.update(f"edge {u}->{v}\n".encode())
    return h.digest()


class EvalStore:
    """Append-only on-disk memo of ``(fingerprint, key) -> base time``.

    Opening loads every intact record into memory (lookups are dict
    hits; the search hot path never touches the disk for reads) and
    truncates any corrupt tail left by a crashed writer. ``put_many``
    appends each batch with one ``write`` syscall on an ``O_APPEND``
    descriptor, so concurrent writers on a local filesystem interleave
    whole batches. Idempotent: keys already present are not re-written.
    """

    def __init__(self, path: "str | os.PathLike"):
        self.path = os.fspath(path)
        self._mem: dict[bytes, dict[bytes, float]] = {}
        self.n_records = 0
        self.n_truncated_bytes = 0
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._fd: int | None = os.open(
            self.path, os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            self._load()
        except Exception:
            os.close(self._fd)
            self._fd = None
            raise

    # -- load / recovery ---------------------------------------------------
    def _load(self) -> None:
        size = os.fstat(self._fd).st_size
        data = os.pread(self._fd, size, 0) if size else b""
        if not data:
            os.write(self._fd, MAGIC)
            return
        if not data.startswith(MAGIC):
            raise ValueError(
                f"{self.path!r} is not an evaluation store "
                f"(bad magic {data[:8]!r})")
        off = len(MAGIC)
        end_ok = off
        n = len(data)
        while off + _LEN.size <= n:
            (plen,) = _LEN.unpack_from(data, off)
            rec_end = off + _LEN.size + plen + _LEN.size
            if plen < _MIN_PAYLOAD or rec_end > n:
                break                      # truncated / nonsense tail
            payload = data[off + _LEN.size:off + _LEN.size + plen]
            (crc,) = _LEN.unpack_from(data, rec_end - _LEN.size)
            if zlib.crc32(payload) != crc:
                break                      # corrupt tail
            fp = payload[:FINGERPRINT_SIZE]
            key = payload[FINGERPRINT_SIZE:plen - _TIME.size]
            (t,) = _TIME.unpack_from(payload, plen - _TIME.size)
            self._mem.setdefault(fp, {}).setdefault(key, t)
            self.n_records += 1
            off = end_ok = rec_end
        if end_ok < n:
            self.n_truncated_bytes = n - end_ok
            os.ftruncate(self._fd, end_ok)

    # -- lookups -----------------------------------------------------------
    def get(self, fingerprint: bytes, key: bytes) -> float | None:
        """The stored base time, or ``None`` if never measured."""
        bucket = self._mem.get(fingerprint)
        return None if bucket is None else bucket.get(key)

    def __len__(self) -> int:
        return sum(len(b) for b in self._mem.values())

    def __contains__(self, fp_key: tuple[bytes, bytes]) -> bool:
        fp, key = fp_key
        return key in self._mem.get(fp, ())

    def fingerprints(self) -> list[bytes]:
        return list(self._mem)

    def stats(self) -> dict:
        return {
            "path": self.path,
            "entries": len(self),
            "fingerprints": len(self._mem),
            "records_loaded": self.n_records,
            "truncated_bytes": self.n_truncated_bytes,
        }

    # -- writes ------------------------------------------------------------
    def put_many(self, fingerprint: bytes,
                 items: Iterable[tuple[bytes, float]]) -> int:
        """Append ``(key, base time)`` pairs; returns how many were new.

        Keys already present are skipped (content-addressed: the value
        is a pure function of the address). The whole batch goes out as
        one append so concurrent writers cannot interleave inside it.
        """
        if self._fd is None:
            raise ValueError(f"store {self.path!r} is closed")
        if len(fingerprint) != FINGERPRINT_SIZE:
            raise ValueError(
                f"fingerprint must be {FINGERPRINT_SIZE} bytes")
        bucket = self._mem.setdefault(fingerprint, {})
        buf = bytearray()
        n_new = 0
        for key, t in items:
            if key in bucket:
                continue
            t = float(t)
            bucket[key] = t
            payload = fingerprint + bytes(key) + _TIME.pack(t)
            buf += _LEN.pack(len(payload))
            buf += payload
            buf += _LEN.pack(zlib.crc32(payload))
            n_new += 1
        if buf:
            os.write(self._fd, bytes(buf))
            self.n_records += n_new
        return n_new

    def put(self, fingerprint: bytes, key: bytes, t: float) -> int:
        return self.put_many(fingerprint, [(key, t)])

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Close the file descriptor; idempotent. Reads keep working
        (the in-memory index survives); writes raise."""
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self) -> "EvalStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; context-manager close preferred
        try:
            self.close()
        except Exception:
            pass
