"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.pack import ops as pack_ops
from repro.kernels.spmv import ops as spmv_ops
from repro.spmv.matrix import band_matrix


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("n,k,dtype", [
    (64, 1, jnp.float32),
    (300, 7, jnp.float32),       # non-aligned rows and K
    (512, 8, jnp.float32),
    (1024, 16, jnp.bfloat16),
    (2048, 5, jnp.bfloat16),
])
def test_ell_matvec_sweep(n, k, dtype):
    rng = np.random.default_rng(n + k)
    vals = rng.standard_normal((n, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(n, k)).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    ref = np.asarray(spmv_ops.ell_matvec_ref(
        jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x)))
    out = spmv_ops.ell_matvec(jnp.asarray(vals, dtype),
                              jnp.asarray(cols),
                              jnp.asarray(x, dtype))
    scale = np.abs(ref).max() + 1e-6
    assert np.abs(np.asarray(out) - ref).max() / scale < _tol(dtype)


@pytest.mark.parametrize("n,k,hb,block_r", [
    (256, 4, 32, 64),
    (512, 8, 64, 128),
    (384, 3, 48, 128),    # n not a multiple of block_r
])
def test_ell_onehot_sweep(n, k, hb, block_r):
    rng = np.random.default_rng(n)
    offs = rng.integers(-hb, hb + 1, size=(n, k))
    cols = ((np.arange(n)[:, None] + offs) % n).astype(np.int32)
    vals = rng.standard_normal((n, k)).astype(np.float32)
    x = rng.standard_normal(n).astype(np.float32)
    ref = np.asarray(spmv_ops.ell_matvec_ref(
        jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x)))
    out = spmv_ops.ell_matvec_onehot(
        jnp.asarray(vals), jnp.asarray(cols), jnp.asarray(x),
        half_bandwidth=hb, block_r=block_r)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-5,
                               atol=1e-4)


def test_kernels_agree_on_paper_matrix():
    """Reduced version of the paper's band matrix through both paths."""
    A = band_matrix(n=2048, nnz=16384, half_bandwidth=512, seed=7)
    x = np.random.default_rng(1).standard_normal(2048).astype(np.float32)
    ref = A.matvec(x)
    y1 = spmv_ops.ell_matvec(jnp.asarray(A.vals), jnp.asarray(A.cols),
                             jnp.asarray(x))
    y2 = spmv_ops.ell_matvec_onehot(
        jnp.asarray(A.vals), jnp.asarray(A.cols), jnp.asarray(x),
        half_bandwidth=512, block_r=128)
    np.testing.assert_allclose(np.asarray(y1), ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 9),
       st.sampled_from([33, 100, 256]))
def test_ell_matvec_property(seed, k, n):
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal((n, k)).astype(np.float32)
    cols = rng.integers(0, n, size=(n, k)).astype(np.int32)
    x = rng.standard_normal(n).astype(np.float32)
    ref = (vals.astype(np.float64) * x.astype(np.float64)[cols]).sum(1)
    out = spmv_ops.ell_matvec(jnp.asarray(vals), jnp.asarray(cols),
                              jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("n,m", [(128, 64), (1000, 333), (4096, 1024)])
def test_pack_sweep(n, m):
    rng = np.random.default_rng(m)
    x = rng.standard_normal(n).astype(np.float32)
    idx = rng.integers(0, n, size=m).astype(np.int32)
    out = pack_ops.pack(jnp.asarray(x), jnp.asarray(idx))
    np.testing.assert_array_equal(np.asarray(out), x[idx])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_pack_property(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 512))
    m = int(rng.integers(1, 300))
    x = rng.standard_normal(n).astype(np.float32)
    idx = rng.integers(0, n, size=m).astype(np.int32)
    out = pack_ops.pack(jnp.asarray(x), jnp.asarray(idx))
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(pack_ops.pack_ref(
            jnp.asarray(x), jnp.asarray(idx))))


# -- flash attention ------------------------------------------------------------

from repro.kernels.flash_attention import ops as fa_ops  # noqa: E402


@pytest.mark.parametrize("b,h,s,d,dtype", [
    (2, 3, 256, 64, jnp.float32),
    (1, 2, 300, 64, jnp.float32),      # non-block-aligned seq
    (2, 2, 256, 128, jnp.bfloat16),
    (1, 2, 64, 48, jnp.float32),       # lane-padded head dim
])
def test_flash_attention_causal_sweep(b, h, s, d, dtype):
    rng = np.random.default_rng(s + d)
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    out = fa_ops.mha(q, k, v, causal=True)
    ref = fa_ops.attention_ref(q, k, v, causal=True)
    err = float(jnp.abs(out.astype(jnp.float32) -
                        ref.astype(jnp.float32)).max())
    assert err < (3e-2 if dtype == jnp.bfloat16 else 1e-5), err


def test_flash_attention_cross_noncausal():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 64)), jnp.float32)
    out = fa_ops.mha(q, k, v, causal=False)
    ref = fa_ops.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


def test_flash_attention_decode_alignment():
    """Right-aligned causal: queries are the last Sq of the kv seq."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 384, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 384, 64)), jnp.float32)
    out = fa_ops.mha(q, k, v, causal=True)
    ref = fa_ops.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)
