"""Autotuning the repo's own Pallas kernels through the DesignSpace
stack: the param-space wallclock backend, its value-correctness gate,
persistent warm starts, and block-size design rules.

Everything runs on CPU (interpret-mode kernels, tiny instances) so the
whole file stays in tier-1 budgets; the same code paths drive a real
TPU sweep by constructing the spaces with bigger shapes and
``interpret=None``.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.engine as E
import repro.search as S
from repro.engine.params import KernelWallclockEvaluator
from repro.kernels.autotune import (flash_attention_space, pack_space,
                                    spmv_mulsum_space)
from repro.rules import distill
from repro.rules.labels import Labeling
from repro.space import KernelRunner, ParamSpace


def median_split(times: np.ndarray) -> Labeling:
    """Deterministic 2-class labeler (fast half / slow half).

    Tiny wall-clock corpora (a 9-point block grid) rarely show the
    multi-plateau structure the paper's convolution labeler keys on;
    a median split always yields two classes, so the distilled rules
    exercise the threshold-feature path deterministically.
    """
    order = np.argsort(times, kind="stable")
    s = times[order]
    cut = s.size // 2
    labels = np.empty(s.size, dtype=np.int64)
    labels[order] = (np.arange(s.size) >= cut).astype(np.int64)
    return Labeling(order=order, sorted_times=s,
                    convolution=np.zeros_like(s),
                    boundaries=np.array([cut - 1]),
                    labels=labels, n_classes=2)


def _spmv_grid(block_values=(32, 64)):
    return spmv_mulsum_space(n=128, k=4, block_values=block_values,
                             interpret=True)


# -- evaluator basics ---------------------------------------------------------

def test_kernel_wallclock_dispatch_and_requirements():
    sp = _spmv_grid()
    ev = E.make_evaluator(sp, "wallclock", repeats=1)
    assert isinstance(ev, KernelWallclockEvaluator)
    no_runner = ParamSpace("bare", [("a", (1, 2))])
    with pytest.raises(ValueError, match="KernelRunner"):
        E.make_evaluator(no_runner, "wallclock")
    with pytest.raises(ValueError, match="compile_mode"):
        E.make_evaluator(sp, "wallclock", compile_mode="eager")


@pytest.mark.parametrize("compile_mode", ["batch", "per_candidate"])
def test_kernel_sweep_measures_and_memoizes(compile_mode):
    sp = _spmv_grid()
    ev = E.make_evaluator(sp, "wallclock", repeats=2,
                          compile_mode=compile_mode)
    cands = list(sp.enumerate_candidates())
    times = ev.evaluate(cands)
    assert len(times) == 2 and all(t > 0.0 for t in times)
    assert ev.n_checked == 2                 # every candidate gated
    again = ev.evaluate(cands)
    assert again == times                    # memoized, not re-run
    assert ev.n_checked == 2
    assert ev.stats()["memory_hits"] == 2


def test_wallclock_gate_rejects_wrong_output_candidate():
    """The value-correctness gate: a kernel candidate producing wrong
    output is rejected before (batch mode: any) timing, and the paid
    measurements of earlier good candidates are salvaged."""
    honest = _spmv_grid(block_values=(16, 32, 64))
    bad_block = 64

    def build(params):
        run = honest.runner.build(params)
        if params["block_n"] != bad_block:
            return run
        return lambda: run() + 1.0           # wrong values, right shape
    broken = ParamSpace(honest.name, honest.dims,
                        runner=KernelRunner(
                            build=build,
                            reference=honest.runner.reference),
                        signature=honest.signature + ":broken")

    ev = E.make_evaluator(broken, "wallclock", repeats=1)
    with pytest.raises(AssertionError,
                       match="value-correctness gate"):
        ev.evaluate([(16,), (32,), (bad_block,)])
    # batch compile_mode gates before timing: nothing was banked for
    # the bad candidate, and in batch mode the good ones were not yet
    # timed either — re-evaluating them measures fresh.
    good = ev.evaluate([(16,), (32,)])
    assert all(t > 0.0 for t in good)

    # per_candidate mode interleaves, so the good candidates *before*
    # the bad one were already timed — salvage banks them (metered as
    # misses on next lookup, per the salvage contract): re-evaluating
    # them re-runs nothing, so the gate count stays at the first
    # pass's two successful checks.
    ev2 = E.make_evaluator(broken, "wallclock", repeats=1,
                           compile_mode="per_candidate")
    with pytest.raises(AssertionError,
                       match="value-correctness gate"):
        ev2.evaluate([(16,), (32,), (bad_block,)])
    assert ev2.n_checked == 2
    banked = ev2.evaluate([(16,), (32,)])
    assert ev2.n_checked == 2                # served from salvage
    assert all(t > 0.0 for t in banked)


def test_gate_error_names_the_candidate():
    honest = _spmv_grid(block_values=(32,))
    broken = ParamSpace(honest.name, honest.dims,
                        runner=KernelRunner(
                            build=lambda p: lambda: jnp.zeros(128),
                            reference=honest.runner.reference),
                        signature=honest.signature + ":zeros")
    ev = E.make_evaluator(broken, "wallclock", repeats=1)
    with pytest.raises(AssertionError, match="block_n=32"):
        ev.evaluate([(32,)])


def test_check_values_off_skips_the_gate():
    honest = _spmv_grid(block_values=(32,))
    broken = ParamSpace(honest.name, honest.dims,
                        runner=KernelRunner(
                            build=lambda p: lambda: jnp.zeros(128),
                            reference=honest.runner.reference),
                        signature=honest.signature + ":unchecked")
    ev = E.make_evaluator(broken, "wallclock", repeats=1,
                          check_values=False)
    assert ev.evaluate([(32,)])[0] > 0.0
    assert ev.n_checked == 0


def test_platform_is_part_of_the_objective_key():
    sp = _spmv_grid()
    ev = E.make_evaluator(sp, "wallclock", repeats=3, warmup=2)
    key = ev._objective_key()
    assert key.startswith("kernel-wallclock:platform=")
    assert key.endswith(":repeats=3:warmup=2")
    # compile_mode moves compile cost around but measures the same
    # quantity — deliberately NOT in the key.
    ev2 = E.make_evaluator(sp, "wallclock", repeats=3, warmup=2,
                           compile_mode="per_candidate")
    assert ev2._objective_key() == key
    assert ev2.store_fingerprint == ev.store_fingerprint


# -- warm starts across runs --------------------------------------------------

def test_warm_kernel_search_replays_with_zero_measurements(
        tmp_path, monkeypatch):
    """tests/test_engine_store.py's acceptance lock, for kernel grids:
    the second ``run_search`` against a fresh evaluator performs zero
    measurements — 100% store hits — and replays the cold trajectory
    byte-identically (wallclock times are memoized real measurements,
    so the values match exactly)."""
    path = str(tmp_path / "kernels.store")

    def run():
        sp = _spmv_grid()                      # fresh space each run
        return S.run_search(sp, S.MCTSSearch(sp, seed=2), budget=6,
                            batch_size=2, backend="wallclock",
                            backend_kwargs={"repeats": 1},
                            store_path=path)

    cold = run()
    assert cold.cache_misses == 2 and cold.store_hits == 0
    assert len(cold.schedules) == 2

    def no_measuring(self, candidates, encoded=None):
        raise AssertionError("warm run called _measure_batch")
    monkeypatch.setattr(KernelWallclockEvaluator, "_measure_batch",
                        no_measuring)
    warm = run()
    assert warm.cache_misses == 0
    assert warm.store_hits == cold.cache_misses   # 100% store hits
    assert warm.cache_hits == cold.cache_hits
    assert warm.times == cold.times
    assert warm.schedules == cold.schedules
    fa, la, ta = cold.dataset()
    fb, lb, tb = warm.dataset()
    assert ta.tobytes() == tb.tobytes()
    assert fa.X.tobytes() == fb.X.tobytes()
    assert np.array_equal(la.labels, lb.labels)


def test_different_grids_never_share_store_entries(tmp_path):
    path = str(tmp_path / "kernels.store")
    sp = _spmv_grid()
    with E.make_evaluator(sp, "wallclock", repeats=1,
                          store_path=path) as ev:
        ev.evaluate(list(sp.enumerate_candidates()))
        assert ev.cache_misses == 2
    # Same kernel, different problem instance: different signature,
    # different fingerprint, zero warm hits.
    other = spmv_mulsum_space(n=256, k=4, block_values=(32, 64),
                              interpret=True)
    with E.make_evaluator(other, "wallclock", repeats=1,
                          store_path=path) as ev2:
        ev2.evaluate(list(other.enumerate_candidates()))
        assert (ev2.store_hits, ev2.cache_misses) == (0, 2)


# -- the acceptance criterion: kernel design rules ---------------------------

def test_flash_attention_autotune_distills_block_size_rules(tmp_path):
    """ISSUE acceptance: a flash_attention param-space wallclock search
    on CPU distills to a RuleReport of block-size design rules, and
    the warm re-run reports 100% store hits."""
    path = str(tmp_path / "fa.store")

    def run():
        sp = flash_attention_space(batch=1, heads=1, seq=64,
                                   head_dim=16,
                                   block_values=(16, 32, 64),
                                   interpret=True)
        res = S.run_search(sp, S.ExhaustiveSearch(sp), budget=None,
                           backend="wallclock",
                           backend_kwargs={"repeats": 1},
                           store_path=path)
        return sp, res

    sp, cold = run()
    assert len(cold.schedules) == sp.n_candidates() == 9
    assert cold.cache_misses == 9 and cold.store_hits == 0

    report = distill(cold, labeler=median_split)
    assert report.n_schedules == 9
    assert report.labeling.n_classes == 2
    assert report.rulesets and all(rs.rules for rs in report.rulesets)
    rule_dims = {r.feature.u for rs in report.rulesets
                 for r in rs.rules}
    assert rule_dims <= {"block_q", "block_k"} and rule_dims
    text = report.render()
    assert "block_q" in text or "block_k" in text

    _, warm = run()
    assert (warm.store_hits, warm.cache_misses) == (9, 0)  # 100% warm
    assert warm.times == cold.times


def test_pack_space_smallest_grid_round_trip():
    sp = pack_space(n=256, m=64, block_c_values=(32, 64),
                    chunk_values=(64, 128), interpret=True)
    assert sp.n_candidates() == 4
    res = S.run_search(sp, S.ExhaustiveSearch(sp), budget=None,
                       backend="wallclock",
                       backend_kwargs={"repeats": 1})
    assert len(res.times) == 4 and min(res.times) > 0.0
    best, _ = res.best()
    assert best in set(sp.enumerate_candidates())


def test_flash_attention_space_filters_non_divisor_blocks():
    sp = flash_attention_space(seq=64, block_values=(16, 48, 64),
                               interpret=True)
    assert dict(sp.dims)["block_q"] == (16, 64)
    with pytest.raises(ValueError, match="divides"):
        flash_attention_space(seq=64, block_values=(48,))
