"""Shared neural layers: norms, MLP variants, rotary embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.params import Spec


# -- norms -------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * rms).astype(dt) * scale.astype(dt)


def norm_spec(d: int) -> Spec:
    return Spec((d,), ("d_model",), init="ones")


# -- rotary position embeddings ----------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLP variants --------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": Spec((d, f), ("d_model", "d_ff")),
            "wg": Spec((d, f), ("d_model", "d_ff")),
            "wo": Spec((f, d), ("d_ff", "d_model")),
        }
    return {
        "wi": Spec((d, f), ("d_model", "d_ff")),
        "wo": Spec((f, d), ("d_ff", "d_model")),
    }


def mlp(p: dict, x: jax.Array, kind: str) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    h = constrain(h, ("batch", "seq", "d_ff"))
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * h
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"].astype(x.dtype)) * h
    elif kind == "relu2":               # squared ReLU (Primer / nemotron)
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return constrain(h @ p["wo"].astype(x.dtype),
                     ("batch", "seq", "d_model"))


# -- embeddings ----------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    out = {"tokens": Spec((v, d), ("vocab", "d_model"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["lm_head"] = Spec((d, v), ("d_model", "vocab"))
    if cfg.frontend is not None:
        out["frontend_proj"] = Spec(
            (cfg.frontend.d_frontend, d), ("d_frontend", "d_model"))
    return out


def embed_tokens(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return constrain(p["tokens"].astype(dtype)[tokens],
                     ("batch", "seq", "d_model"))


def logits_out(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        out = x @ p["tokens"].astype(x.dtype).T
    else:
        out = x @ p["lm_head"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "vocab"))
