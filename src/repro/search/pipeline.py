"""The single search entry point: strategy x evaluator -> dataset.

``run_search`` is the one code path behind the paper reproduction
(benchmarks/paper.py), the SpMV baseline, and the LM-step scenario
(examples/schedule_search.py): it drives any :class:`SearchStrategy`
against any evaluation-engine backend (:mod:`repro.engine` —
serial/vectorized/pool/wallclock, selected with ``backend=``) and
collects the deduplicated (schedule, time) observations.
``SearchResult.dataset()`` then emits the (features, labels, times)
triple consumed by the rules distillation subsystem
(:mod:`repro.rules`) — or pass the whole result to
:func:`repro.rules.distill` for the one-call search -> rules report.

The loop itself lives in :class:`repro.driver.SearchDriver` (the
acquisition-aware round driver); ``run_search`` constructs a driver
with no acquisition override and no sinks, which is bit-compatible
with the original inline loop (locked by tests/test_driver.py).
Construct a :class:`~repro.driver.SearchDriver` directly to screen
pools with a named acquisition (``ucb``, ``expected_improvement``) or
to stream evaluated batches to sinks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.core.features import FeatureMatrix
from repro.engine.base import EvaluatorBase, canonical_key
from repro.rules.labels import Labeling, label_times
from repro.search.strategy import SearchStrategy
from repro.space.base import DesignSpace, as_space


def _tie_key(schedule: Schedule) -> tuple:
    """Total order on canonical encodings (``None`` streams sort first).

    The canonical (first-use-relabeled) item sequence with CPU ops'
    ``None`` stream mapped to -1, so tuples compare without type
    errors. Backend-independent: canonicalization is a pure function
    of the schedule.
    """
    return tuple((name, -1 if s is None else s)
                 for name, s in canonical_key(schedule))


@dataclasses.dataclass
class SearchResult:
    """Deduplicated observations from one search run.

    ``graph`` is the searched DAG for schedule spaces and ``None`` for
    non-graph design spaces; ``space`` always carries the
    :class:`~repro.space.base.DesignSpace` searched (filled in lazily
    from ``graph`` for results constructed the historical way).
    """

    graph: Graph | None
    schedules: list[Schedule]
    times: list[float]
    n_proposed: int
    cache_hits: int
    cache_misses: int
    # First-time evaluations served by the persistent cross-run store
    # (repro.engine.store) instead of a paid measurement; 0 storeless.
    store_hits: int = 0
    space: DesignSpace | None = None
    # Round-by-round telemetry summary (repro.obs): one dict per driver
    # round — {round, n, n_fresh, best, evaluate_s, memory_hits,
    # store_hits, misses} — filled only when a telemetry registry is
    # enabled during the run, None otherwise. Purely observational:
    # never part of the byte-identity contract, and the per-round
    # latency/hit-ratio signal the cost-aware acquisition work
    # consumes.
    telemetry: "list[dict] | None" = None

    def design_space(self) -> DesignSpace:
        """The searched space (wrapping ``graph`` when not recorded)."""
        if self.space is None:
            self.space = as_space(self.graph)
        return self.space

    def best(self) -> tuple[Schedule, float]:
        """The fastest observed (schedule, time).

        Exact makespan ties are broken by the schedule's canonical
        encoding (lexicographically smallest wins, CPU ops sorting
        before stream 0), NOT by observation order — so the winner is
        a deterministic function of the observed *set*, identical
        across evaluation backends, batch sizes, and proposal
        orderings that cover the same schedules.
        """
        if not self.schedules:
            raise ValueError(
                "empty search result (budget 0 or strategy proposed "
                "nothing) has no best schedule")
        times = np.asarray(self.times, dtype=np.float64)
        ties = np.flatnonzero(times == times.min())
        tie_key = self.design_space().tie_key
        i = int(ties[0]) if ties.size == 1 else \
            min((int(j) for j in ties),
                key=lambda j: tie_key(self.schedules[j]))
        return self.schedules[i], self.times[i]

    def times_array(self) -> np.ndarray:
        return np.asarray(self.times, dtype=np.float64)

    def dataset(self) -> tuple[FeatureMatrix, Labeling, np.ndarray]:
        """(features, labels, times) for the rules pipeline."""
        times = self.times_array()
        return (self.design_space().featurize(self.schedules),
                label_times(times), times)


def run_search(graph: "Graph | DesignSpace", strategy: SearchStrategy,
               machine: Machine | None = None,
               budget: int | None = 2000,
               batch_size: int = 1,
               evaluator: EvaluatorBase | None = None,
               backend: str | None = None,
               backend_kwargs: dict | None = None,
               sim_budget: int | None = None,
               stall_limit: int = 1000,
               store=None,
               store_path: "str | None" = None) -> SearchResult:
    """Drive ``strategy`` for up to ``budget`` evaluations.

    ``budget`` counts proposals (evaluations), not distinct schedules;
    ``None`` means run until the strategy exhausts — or, for
    strategies that never return an empty batch, until ``stall_limit``
    consecutive proposals yield no fresh simulation.
    ``batch_size`` is how many schedules are requested per ``propose``
    call; 1 reproduces the paper's strictly sequential loop (each
    observation lands before the next proposal), larger values trade
    strategy-state freshness for evaluator throughput. A strategy that
    returns more than it was asked for is clamped to the remaining
    budget — the excess is neither evaluated nor counted.

    ``sim_budget`` bounds *discrete-event simulations* (evaluator cache
    misses) instead of proposals: the loop stops once the strategy has
    spent that many distinct simulations. Checked between batches, so a
    batch may overshoot by up to ``batch_size - 1``; use
    ``batch_size=1`` for an exact cap. This is the fair-comparison knob
    for strategies (e.g. surrogate screening) that trade many cheap
    proposals for few expensive simulations. A strategy that never
    exhausts (random rollouts, surrogate padding) makes no progress a
    ``sim_budget`` or ``budget=None`` loop can observe once the space
    runs out of new implementations; whenever the loop is not bounded
    by a proposal ``budget``, ``stall_limit`` therefore breaks it
    after that many consecutive proposals without a single fresh
    simulation.

    ``backend`` selects the evaluation engine by registry name
    (:func:`repro.engine.make_evaluator`: ``"sim"`` (default),
    ``"vectorized"``, ``"pool"``, ``"wallclock"``), with
    ``backend_kwargs`` forwarded to its constructor — e.g.
    ``backend="pool", backend_kwargs={"n_workers": 4}``. All analytic
    backends are bit-identical, so the backend is a pure
    throughput/objective choice. A backend created here is closed when
    the search returns; pass a preconfigured ``evaluator`` instead to
    keep its memo cache alive across runs.

    ``store=`` / ``store_path=`` attach the persistent content-
    addressed evaluation store (:class:`repro.engine.EvalStore`) to the
    evaluator this call constructs: base times measured here are
    written through, and a later run — any process, any analytic
    backend — replays them as ``store_hits`` without measuring,
    byte-identical to the cold run (``sim_budget`` counts misses +
    store hits, so warm trajectories match cold ones exactly). Only
    valid with ``backend=``-style construction; attach the store to
    your own ``evaluator=`` instead when you pass one.

    Every proposal is evaluated and fed back via ``observe``; the result
    keeps the first observation per canonical schedule (matching how the
    paper's MCTS records its rollout set). Pass either ``machine`` or a
    preconfigured ``evaluator`` (which owns its machine), not both (and
    not ``backend`` with ``evaluator`` — the evaluator already *is* a
    backend); a shared evaluator keeps its memo cache across runs, and
    the result's cache counters report this run's traffic only.
    """
    # Lazy: repro.driver.driver imports this module for SearchResult.
    from repro.driver.driver import SearchDriver
    return SearchDriver(graph, strategy, machine=machine, budget=budget,
                        batch_size=batch_size, evaluator=evaluator,
                        backend=backend, backend_kwargs=backend_kwargs,
                        sim_budget=sim_budget, stall_limit=stall_limit,
                        store=store, store_path=store_path).run()
