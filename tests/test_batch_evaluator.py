"""BatchEvaluator: bit-identical to per-schedule simulation, with a
transposition/memo cache over canonical schedule hashes."""
import numpy as np
import pytest

import repro.core as C
import repro.search as S
from repro.core.costmodel import Machine, op_durations
from repro.core.dag import BoundOp, Schedule


@pytest.fixture(scope="module")
def spmv_space():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    return g, scheds


def test_batched_bit_identical_to_costmodel(spmv_space):
    """The whole 280-schedule SpMV space: batched evaluation must equal
    per-schedule ``C.makespan`` exactly (== on floats, not isclose)."""
    g, scheds = spmv_space
    ev = S.BatchEvaluator(g)
    batched = ev.evaluate(scheds)
    naive = [C.makespan(g, s) for s in scheds]
    assert batched == naive


def test_batched_bit_identical_with_custom_machine(spmv_space):
    g, scheds = spmv_space
    m = Machine(flops_per_s=100e12, hbm_bytes_per_s=500e9,
                launch_overhead_s=7e-6)
    ev = S.BatchEvaluator(g, machine=m)
    assert ev.evaluate(scheds[:50]) == \
        [C.makespan(g, s, m) for s in scheds[:50]]


def test_op_durations_match_simulate_fallback(spmv_space):
    """The precomputed duration table feeds simulate() the exact values
    its per-op fallback would compute."""
    g, _ = spmv_space
    m = Machine()
    durs = op_durations(g, m)
    for name, op in g.ops.items():
        if op.duration is not None:
            assert durs[name] == op.duration
        elif op.kind is C.OpKind.GPU:
            assert durs[name] == m.gpu_duration(op.flops, op.bytes_hbm)
        else:
            assert durs[name] == m.cpu_op_s


def test_memo_cache_hits_on_reproposal(spmv_space):
    g, scheds = spmv_space
    batch = scheds[:40]
    ev = S.BatchEvaluator(g)
    first = ev.evaluate(batch)
    assert (ev.cache_hits, ev.cache_misses) == (0, 40)
    second = ev.evaluate(batch)
    assert second == first
    assert (ev.cache_hits, ev.cache_misses) == (40, 40)
    assert len(ev) == 40  # no new cache entries


def test_memo_cache_within_one_batch(spmv_space):
    g, scheds = spmv_space
    dup = [scheds[0], scheds[1], scheds[0], scheds[0]]
    ev = S.BatchEvaluator(g)
    out = ev.evaluate(dup)
    assert out[0] == out[2] == out[3]
    assert (ev.cache_hits, ev.cache_misses) == (2, 2)


def test_memo_cache_is_bijection_aware(spmv_space):
    """A stream-relabeled (non-canonical) schedule is the same
    implementation — it must hit the cache entry of its canonical twin
    and get the identical makespan."""
    g, scheds = spmv_space
    two_stream = next(s for s in scheds
                      if len(set(s.streams().values())) == 2)
    relabeled = Schedule(tuple(
        BoundOp(i.name, 1 - i.stream if i.stream is not None else None)
        for i in two_stream.items))
    assert relabeled.key() != two_stream.key()
    ev = S.BatchEvaluator(g)
    t0 = ev.evaluate([two_stream])[0]
    t1 = ev.evaluate([relabeled])[0]
    assert t1 == t0
    assert (ev.cache_hits, ev.cache_misses) == (1, 1)
    assert t0 == C.makespan(g, relabeled)


def test_noise_is_post_cache_and_seeded(spmv_space):
    g, scheds = spmv_space
    s = scheds[0]
    ev_a = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=11)
    ev_b = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=11)
    a = ev_a.evaluate([s, s, s])
    assert a == ev_b.evaluate([s, s, s])  # seeded: reproducible
    assert len(set(a)) > 1  # fresh noise per evaluation, even on hits
    assert ev_a.cache_misses == 1  # underlying makespan cached once
    clean = C.makespan(g, s)
    assert all(abs(t / clean - 1.0) < 0.5 for t in a)


def test_noise_is_order_independent(spmv_space):
    """Noise is seeded per (canonical key, draw index), so a permuted
    batch gets the permuted noisy values — batch order, backend, and
    worker sharding can never change what a schedule measures."""
    import random
    g, scheds = spmv_space
    batch = scheds[:30]
    perm = list(range(len(batch)))
    random.Random(4).shuffle(perm)
    ev_a = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=11)
    ev_b = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=11)
    straight = ev_a.evaluate(batch)
    shuffled = ev_b.evaluate([batch[i] for i in perm])
    assert shuffled == [straight[i] for i in perm]


def test_noise_depends_on_seed(spmv_space):
    g, scheds = spmv_space
    s = scheds[0]
    a = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=1).evaluate([s])
    b = S.BatchEvaluator(g, noise_sigma=0.05, noise_seed=2).evaluate([s])
    assert a != b


def test_stats_reports_cache_traffic(spmv_space):
    g, scheds = spmv_space
    ev = S.BatchEvaluator(g)
    assert ev.stats() == {"backend": "sim", "memory_hits": 0,
                          "store_hits": 0, "misses": 0,
                          "size": 0, "hit_rate": 0.0}
    ev.evaluate(scheds[:20])
    ev.evaluate(scheds[:30])
    st = ev.stats()
    assert st["backend"] == "sim"
    assert st["misses"] == 30
    assert st["memory_hits"] == 20
    assert st["store_hits"] == 0        # no persistent store attached
    assert st["size"] == len(ev) == 30
    assert st["hit_rate"] == pytest.approx(20 / 50)


def test_stats_parity_across_backends(spmv_space):
    """The QoS meter is backend-independent: the same traffic produces
    the identical {memory_hits, store_hits, misses} triple on the
    serial, vectorized, and pool backends."""
    import repro.engine as E
    g, scheds = spmv_space
    traffic = scheds[:25] + scheds[5:15] + scheds[:25]
    triples = {}
    for backend, kwargs in (("sim", {}), ("vectorized", {}),
                            ("pool", {"n_workers": 2, "min_shard": 1})):
        with E.make_evaluator(g, backend, **kwargs) as ev:
            ev.evaluate(traffic)
            st = ev.stats()
            triples[backend] = (st["memory_hits"], st["store_hits"],
                                st["misses"])
    assert triples["sim"] == (35, 0, 25)
    assert triples["vectorized"] == triples["sim"]
    assert triples["pool"] == triples["sim"]


def test_encode_relabel_handles_sparse_stream_ids(spmv_space):
    """The batched first-use relabel sizes by *distinct* ids present,
    not max(id)+1: a schedule using stream 10**6 must encode in peanuts
    of memory and land in the same cache bucket as its dense twin
    (bijection-awareness with non-contiguous ids)."""
    g, scheds = spmv_space
    two_stream = next(s for s in scheds
                      if len(set(s.streams().values())) == 2)
    sparse = Schedule(tuple(
        BoundOp(i.name,
                None if i.stream is None else
                (10 ** 6 if i.stream else 3))
        for i in two_stream.items))
    ev = S.BatchEvaluator(g)
    keys, _ = ev._encode_batch([two_stream, sparse])
    assert keys[0] == keys[1]           # same canonical identity
    # And the per-schedule canonical_key agrees on the equivalence.
    from repro.engine.base import canonical_key
    assert canonical_key(two_stream) == canonical_key(sparse)
    t0 = ev.evaluate([two_stream])[0]
    assert ev.evaluate([sparse])[0] == t0
    assert (ev.cache_hits, ev.cache_misses) == (1, 1)


def test_encode_relabel_matches_canonical_key_mixed_batch(spmv_space):
    """Batched relabel == per-schedule canonical_key over a batch that
    mixes dense, sparse, and permuted stream ids."""
    g, scheds = spmv_space
    import random
    rng = random.Random(0)
    batch = []
    for s in scheds[:20]:
        remap = {0: rng.choice([0, 7, 10 ** 6]),
                 1: rng.choice([1, 3, 99999])}
        while remap[0] == remap[1]:
            remap[1] += 1
        batch.append(Schedule(tuple(
            BoundOp(i.name,
                    None if i.stream is None else remap[i.stream])
            for i in s.items)))
    ev = S.BatchEvaluator(g)
    keys, _ = ev._encode_batch(batch)
    base_keys, _ = ev._encode_batch(scheds[:20])
    assert keys == base_keys


def test_evaluate_one_matches_makespan(spmv_space):
    g, scheds = spmv_space
    ev = S.BatchEvaluator(g)
    assert ev.evaluate_one(scheds[7]) == C.makespan(g, scheds[7])
