"""Gradient-boosted tree surrogate on the pairwise features (§IV-B).

The ROADMAP's "smarter surrogates" item: the ridge surrogate in
:mod:`repro.search.surrogate` is linear in the order/stream features,
which caps its halo3d screening rank-correlation around ~0.66 — the
makespan of a schedule depends on feature *interactions* (an ordering
only matters on the critical path the stream assignment creates). Tree
ensembles are the standard answer for this class of cost model (OptiML;
Penney & Chen's survey), and the vectorized split kernel in
:mod:`repro.rules.trees` makes them cheap: one :class:`~
repro.rules.trees.Presort` of the feature matrix serves every boosting
round.

:class:`GradientBoostedSurrogate` implements the same online protocol
as ``RidgeSurrogate`` (``observe`` / ``predict`` / ``n_observations``)
— both now share :class:`OnlineSurrogateBase`, the corpus/refit
bookkeeping — and registers under the name ``"boost"`` in the
:data:`repro.search.surrogate.SURROGATES` registry, so
``SurrogateGuided(surrogate="boost")`` / ``PortfolioSearch`` screen
with it unchanged.

This module deliberately imports nothing from :mod:`repro.search`
(the dependency points search -> rules, never back).
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import Graph, Schedule
from repro.core.features import Feature
from repro.rules.trees import Presort, RegressionTree, forest_leaf_values
from repro.space.base import DesignSpace, as_space


class OnlineSurrogateBase:
    """Corpus + refit bookkeeping shared by the online surrogates.

    Observations accumulate into the design space's incremental
    feature basis (:class:`~repro.core.features.FeatureBasis` for
    schedule spaces, the threshold basis for parameter grids);
    subclasses implement
    ``_fit`` (rebuild the model from the whole corpus) and are refit
    lazily — on the first ``predict`` after the corpus has grown past a
    geometric-backoff threshold. Each refit rebuilds the feature matrix
    for the whole corpus, so refitting every k observations would make
    cumulative featurization cost quadratic on long runs; waiting for
    ~25% corpus growth past the ``refit_every`` floor keeps it linear
    (amortized) while the model stays fresh.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 refit_every: int = 8):
        self.space = as_space(graph)
        self.graph = getattr(self.space, "graph", None)
        self.refit_every = max(1, refit_every)
        self.basis = self.space.feature_basis()
        self._times: list[float] = []
        self._fitted_n = -1          # observation count at last fit

    @property
    def n_observations(self) -> int:
        return len(self._times)

    def observe(self, schedule: Schedule, time: float) -> None:
        self.basis.add([schedule])
        self._times.append(float(time))

    def _stale(self) -> bool:
        if self._fitted_n < 0:
            return True
        wait = max(self.refit_every, self._fitted_n // 4)
        return len(self._times) - self._fitted_n >= wait

    def _fit(self) -> None:
        raise NotImplementedError


class GradientBoostedSurrogate(OnlineSurrogateBase):
    """Least-squares gradient boosting over order/stream features.

    Stagewise additive model: start from the mean observed time, then
    repeatedly fit a small :class:`~repro.rules.trees.RegressionTree`
    to the residuals and add ``learning_rate`` times its prediction.
    All rounds of one refit share a single :class:`Presort` (the
    feature matrix is fixed within a fit; only residuals change), so a
    full refit is one argsort plus ``n_estimators`` passes of the
    vectorized split kernel. Boosting stops early when a round's tree
    cannot split or the training MSE stops improving.

    With no (or degenerate) data it predicts the observed mean —
    exactly the ridge surrogate's fallback contract.
    """

    def __init__(self, graph: Graph, n_estimators: int = 200,
                 learning_rate: float = 0.05, max_leaf_nodes: int = 8,
                 max_depth: int | None = None, refit_every: int = 8,
                 tol: float = 1e-5):
        super().__init__(graph, refit_every=refit_every)
        if n_estimators < 1:
            raise ValueError("n_estimators must be >= 1")
        if not 0.0 < learning_rate <= 1.0:
            raise ValueError("learning_rate must be in (0, 1]")
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_leaf_nodes = max_leaf_nodes
        self.max_depth = max_depth
        self.tol = tol
        self._trees: list[RegressionTree] = []
        self._features: list[Feature] = []
        self._y_mean = 0.0

    def _fit(self) -> None:
        self._fitted_n = len(self._times)
        y = np.asarray(self._times, dtype=np.float64)
        self._y_mean = float(y.mean()) if y.size else 0.0
        self._trees, self._features = [], []
        if y.size < 2:
            return
        fm = self.basis.matrix()
        if not fm.features:
            return  # all observations identical: mean is the best guess
        X = fm.X.astype(np.float64)
        self._features = fm.features
        ps = Presort(X)
        F = np.full(y.size, self._y_mean)
        mse = float(np.mean((y - F) ** 2))
        for _ in range(self.n_estimators):
            t = RegressionTree(max_leaf_nodes=self.max_leaf_nodes,
                               max_depth=self.max_depth).fit(
                                   X, y - F, presort=ps)
            if t.n_leaves() < 2:
                break           # residuals carry no splittable signal
            F = F + self.learning_rate * t.predict(X)
            new_mse = float(np.mean((y - F) ** 2))
            self._trees.append(t)
            if mse - new_mse <= self.tol * mse:   # relative improvement
                break
            mse = new_mse

    def _leaf_matrix(self, schedules: list[Schedule]) -> np.ndarray:
        """(n_trees, n_schedules) per-tree leaf values, one descent."""
        X = self.space.apply_features(schedules, self._features) \
            .astype(np.float64)
        return forest_leaf_values(self._trees, X)

    def predict(self, schedules: list[Schedule]) -> np.ndarray:
        """Predicted times, one per schedule (refits if stale)."""
        if self._stale():
            self._fit()
        out = np.full(len(schedules), self._y_mean, dtype=np.float64)
        if not self._trees or not schedules:
            return out
        # One batched leaf-gather for the whole ensemble; the
        # accumulation stays sequential in boosting-round order, so
        # predictions are bit-identical to summing t.predict(X) per
        # round (each H row IS that round's t.predict(X)).
        for row in self._leaf_matrix(schedules):
            out += self.learning_rate * row
        return out

    def predict_with_std(self, schedules: list[Schedule]
                         ) -> tuple[np.ndarray, np.ndarray]:
        """(predicted time, predictive deviation) per schedule.

        The deviation is ensemble disagreement: treat each boosting
        round's scaled contribution ``c_t(x) = lr * h_t(x)`` as one of
        ``T`` votes on the total correction and report the deviation
        the sum would have if the votes were independent —
        ``sd(x) = sqrt(T * Var_t(c_t(x)))`` (the bagging-style proxy;
        cf. virtual ensembles for gradient boosting). Where every
        round lands ``x`` in leaves with similar values the model has
        settled (sd -> 0); rounds pulling in different directions —
        feature-space regions the corpus barely covers — inflate sd.
        Exactly zero deviation with fewer than two trees (or no data),
        so downstream acquisitions degrade to mean-ranking on a cold
        model. The mean equals :meth:`predict` bit-for-bit.
        """
        if self._stale():
            self._fit()
        n = len(schedules)
        mu = np.full(n, self._y_mean, dtype=np.float64)
        sd = np.zeros(n, dtype=np.float64)
        if not self._trees or not schedules:
            return mu, sd
        C = self.learning_rate * self._leaf_matrix(schedules)
        for row in C:             # same accumulation order as predict
            mu += row
        if C.shape[0] >= 2:
            sd = np.sqrt(C.shape[0]
                         * np.maximum(C.var(axis=0, ddof=1), 0.0))
        return mu, sd

    @property
    def n_trees(self) -> int:
        return len(self._trees)
