"""engine.store: the persistent content-addressed evaluation store.

Locks the PR's acceptance contract:

* a repeated ``run_search`` against a *fresh* evaluator (new process
  semantics) with ``store_path=`` set performs **zero**
  ``_measure_batch`` calls on the second run — all store hits — while
  producing byte-identical ``(features, labels, times)`` to the cold
  run, on sim / vectorized / pool, noisy and noiseless;
* a cold run with a store attached is byte-identical to a storeless
  run (the store is invisible until it is warm);
* the file format is crash-safe: corrupt tails are truncated on open,
  intact records always survive, concurrent writers interleave whole
  records;
* fingerprints separate graphs, machines, and objectives — results
  can never collide across them.
"""
import os
import struct
import zlib

import numpy as np
import pytest

import repro.core as C
import repro.engine as E
import repro.search as S
from repro.core.costmodel import Machine
from repro.core.dag import spmv_dag_fine
from repro.engine.store import (FINGERPRINT_SIZE, MAGIC, EvalStore,
                                store_fingerprint)


@pytest.fixture()
def store_path(tmp_path):
    return str(tmp_path / "eval.store")


def _fp(tag: bytes = b"a") -> bytes:
    return (tag * FINGERPRINT_SIZE)[:FINGERPRINT_SIZE]


# -- the file format ---------------------------------------------------------

def test_store_roundtrip_and_persistence(store_path):
    fp = _fp()
    with EvalStore(store_path) as st:
        assert len(st) == 0
        assert st.get(fp, b"k1") is None
        assert st.put_many(fp, [(b"k1", 1.5), (b"k2", 2.5)]) == 2
        assert st.get(fp, b"k1") == 1.5
        # Content-addressed: re-putting an existing key is a no-op.
        assert st.put_many(fp, [(b"k1", 9.9), (b"k3", 3.5)]) == 1
        assert st.get(fp, b"k1") == 1.5
    with EvalStore(store_path) as st2:     # fresh process semantics
        assert len(st2) == 3
        assert st2.get(fp, b"k1") == 1.5
        assert st2.get(fp, b"k2") == 2.5
        assert st2.get(fp, b"k3") == 3.5
        assert st2.n_truncated_bytes == 0


def test_store_truncates_corrupt_tail(store_path):
    fp = _fp()
    with EvalStore(store_path) as st:
        st.put_many(fp, [(b"good1", 1.0), (b"good2", 2.0)])
    size_ok = os.path.getsize(store_path)
    # A crashed writer leaves half a record at the tail.
    with open(store_path, "ab") as f:
        payload = fp + b"half-written" + struct.pack("<d", 3.0)
        rec = struct.pack("<I", len(payload)) + payload
        f.write(rec[:len(rec) - 7])
    with EvalStore(store_path) as st:
        assert len(st) == 2                # intact records survive
        assert st.get(fp, b"good1") == 1.0
        assert st.n_truncated_bytes > 0
    assert os.path.getsize(store_path) == size_ok   # tail cut off
    # And the store keeps working after recovery.
    with EvalStore(store_path) as st:
        st.put(fp, b"good3", 3.0)
    assert EvalStore(store_path).get(fp, b"good3") == 3.0


def test_store_truncates_bad_checksum_tail(store_path):
    fp = _fp()
    with EvalStore(store_path) as st:
        st.put(fp, b"keep", 1.0)
    with open(store_path, "ab") as f:
        payload = fp + b"flipped" + struct.pack("<d", 2.0)
        f.write(struct.pack("<I", len(payload)) + payload +
                struct.pack("<I", zlib.crc32(payload) ^ 0xFF))
    with EvalStore(store_path) as st:
        assert len(st) == 1
        assert st.get(fp, b"keep") == 1.0
        assert st.n_truncated_bytes > 0


def test_store_rejects_foreign_file(tmp_path):
    path = tmp_path / "not-a-store"
    path.write_bytes(b"something else entirely")
    with pytest.raises(ValueError, match="magic"):
        EvalStore(path)


def test_store_concurrent_writers_interleave(store_path):
    """Two open handles appending alternately (the multi-writer case:
    both use O_APPEND whole-record writes) — a reopen sees the union."""
    fp = _fp()
    a, b = EvalStore(store_path), EvalStore(store_path)
    a.put(fp, b"from-a-1", 1.0)
    b.put(fp, b"from-b-1", 2.0)
    a.put(fp, b"from-a-2", 3.0)
    b.close(), a.close()
    with EvalStore(store_path) as st:
        assert len(st) == 3
        assert st.get(fp, b"from-b-1") == 2.0


def _mp_store_writer(path: str, tag: int, n: int, barrier) -> None:
    """One writer process: append ``n`` records with disjoint keys.

    The barrier lines every process up on an already-open handle so the
    appends genuinely race (each ``put`` is one whole-record O_APPEND
    write — the safety property under test).
    """
    fp = bytes([tag]) * FINGERPRINT_SIZE
    with EvalStore(path) as st:
        barrier.wait()
        for i in range(n):
            st.put(fp, b"w%d-key-%04d" % (tag, i), float(tag * 1000 + i))


def test_store_multiprocess_concurrent_writers(store_path):
    """The O_APPEND claim, for real: N *processes* appending disjoint
    keys simultaneously; one reader then sees every record, correct
    values, and no torn tail."""
    import multiprocessing

    EvalStore(store_path).close()          # pre-create header
    n_writers, n_each = 4, 50
    ctx = multiprocessing.get_context("spawn")
    barrier = ctx.Barrier(n_writers)
    procs = [ctx.Process(target=_mp_store_writer,
                         args=(store_path, tag, n_each, barrier))
             for tag in range(1, n_writers + 1)]
    for p in procs:
        p.start()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    with EvalStore(store_path) as st:
        assert st.n_truncated_bytes == 0
        assert len(st) == n_writers * n_each
        for tag in range(1, n_writers + 1):
            fp = bytes([tag]) * FINGERPRINT_SIZE
            for i in range(n_each):
                assert st.get(fp, b"w%d-key-%04d" % (tag, i)) == \
                    float(tag * 1000 + i)


def test_store_duplicate_records_first_wins(store_path):
    """Two racing writers may both append the same key (each checked
    its own in-memory index); on load the first record wins."""
    fp = _fp()
    a, b = EvalStore(store_path), EvalStore(store_path)
    a.put(fp, b"k", 1.0)
    b.put(fp, b"k", 2.0)                    # b hasn't seen a's record
    a.close(), b.close()
    with EvalStore(store_path) as st:
        assert len(st) == 1
        assert st.get(fp, b"k") == 1.0


def test_store_write_after_close_raises(store_path):
    st = EvalStore(store_path)
    st.put(_fp(), b"k", 1.0)
    st.close()
    st.close()                              # idempotent
    assert st.get(_fp(), b"k") == 1.0       # reads keep working
    with pytest.raises(ValueError, match="closed"):
        st.put(_fp(), b"k2", 2.0)


# -- the fingerprint contract ------------------------------------------------

def test_fingerprint_separates_graph_machine_objective():
    g1, g2 = C.spmv_dag(), spmv_dag_fine()
    m1, m2 = Machine(), Machine(flops_per_s=100e12)
    from repro.core.costmodel import op_durations
    fps = {
        store_fingerprint(g1, m1, op_durations(g1, m1), "analytic"),
        store_fingerprint(g2, m1, op_durations(g2, m1), "analytic"),
        store_fingerprint(g1, m2, op_durations(g1, m2), "analytic"),
        store_fingerprint(g1, m1, op_durations(g1, m1),
                          "wallclock:repeats=5:warmup=1"),
    }
    assert len(fps) == 4                    # pairwise distinct
    assert all(len(fp) == FINGERPRINT_SIZE for fp in fps)
    # Deterministic across calls (and across processes by blake2b).
    assert store_fingerprint(g1, m1, op_durations(g1, m1),
                             "analytic") in fps


def test_analytic_backends_share_fingerprint_wallclock_does_not():
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    sim = E.make_evaluator(g, "sim")
    vec = E.make_evaluator(g, "vectorized")
    with E.make_evaluator(g, "pool", n_workers=2) as pool:
        assert sim.store_fingerprint == vec.store_fingerprint \
            == pool.store_fingerprint
    impls, env = E.demo_spmv_impls(g, n=8)
    wc = E.make_evaluator(g, "wallclock", impls=impls, env=env)
    assert wc.store_fingerprint != sim.store_fingerprint
    # store_tag separates otherwise-identical configurations.
    tagged = E.make_evaluator(g, "sim", store_tag="impl-v2")
    assert tagged.store_fingerprint != sim.store_fingerprint


def test_wrong_fingerprint_never_serves(store_path):
    """Entries written under one graph are invisible to another: the
    second evaluator re-measures instead of reading a foreign time."""
    g1, g2 = C.spmv_dag(), spmv_dag_fine()
    scheds1 = list(C.enumerate_schedules(g1, 2))[:10]
    scheds2 = list(C.enumerate_schedules(g2, 2))[:10]
    with EvalStore(store_path) as st:
        ev1 = E.make_evaluator(g1, "sim", store=st)
        ev1.evaluate(scheds1)
        assert ev1.cache_misses == 10
        ev2 = E.make_evaluator(g2, "sim", store=st)
        ev2.evaluate(scheds2)
        assert (ev2.store_hits, ev2.cache_misses) == (0, 10)
        assert len(st.fingerprints()) == 2


# -- the evaluator seam ------------------------------------------------------

def test_store_hit_counts_once_then_memory_hits(store_path):
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))[:8]
    with E.make_evaluator(g, "sim", store_path=store_path) as ev:
        cold = ev.evaluate(scheds)
    ev2 = E.make_evaluator(g, "sim", store_path=store_path)
    warm = ev2.evaluate(scheds + scheds)
    assert warm == cold + cold
    st = ev2.stats()
    assert (st["memory_hits"], st["store_hits"], st["misses"]) \
        == (8, 8, 0)
    assert st["hit_rate"] == 1.0            # nothing was measured
    ev2.close()


def test_cold_run_with_store_is_byte_identical_to_storeless(store_path):
    g = spmv_dag_fine()
    res_plain = S.run_search(g, S.MCTSSearch(g, 2, seed=3), budget=80,
                             batch_size=4, backend="sim")
    res_store = S.run_search(g, S.MCTSSearch(g, 2, seed=3), budget=80,
                             batch_size=4, backend="sim",
                             store_path=store_path)
    assert res_store.times == res_plain.times
    assert [s.items for s in res_store.schedules] \
        == [s.items for s in res_plain.schedules]
    assert (res_store.cache_hits, res_store.cache_misses) \
        == (res_plain.cache_hits, res_plain.cache_misses)
    assert res_plain.store_hits == res_store.store_hits == 0
    fa, la, ta = res_plain.dataset()
    fb, lb, tb = res_store.dataset()
    assert ta.tobytes() == tb.tobytes()
    assert fa.X.tobytes() == fb.X.tobytes()
    assert np.array_equal(la.labels, lb.labels)


@pytest.mark.parametrize("noise", [0.0, 0.05])
@pytest.mark.parametrize("backend,kwargs", [
    ("sim", {}),
    ("vectorized", {}),
    ("pool", {"n_workers": 2, "min_shard": 1}),
])
def test_warm_run_measures_nothing_and_replays_exactly(
        store_path, backend, kwargs, noise, monkeypatch):
    """THE acceptance lock: second run in a fresh process = zero
    ``_measure_batch`` calls, byte-identical (features, labels, times),
    for every analytic backend, noisy and noiseless."""
    g = spmv_dag_fine()
    bk = dict(kwargs, noise_sigma=noise, noise_seed=7)

    def run():
        return S.run_search(g, S.MCTSSearch(g, 2, seed=5), budget=None,
                            sim_budget=40, batch_size=8,
                            backend=backend, backend_kwargs=dict(bk),
                            store_path=store_path)

    cold = run()
    assert cold.cache_misses > 0 and cold.store_hits == 0

    # "Fresh process": a brand-new evaluator whose only shared state is
    # the store file; any measurement attempt is an instant failure.
    def no_measuring(self, schedules, encoded=None):
        raise AssertionError(
            "warm run called _measure_batch — store missed")
    monkeypatch.setattr(E.BACKENDS[backend], "_measure_batch",
                        no_measuring)
    warm = run()
    assert warm.cache_misses == 0
    assert warm.store_hits == cold.cache_misses
    assert warm.cache_hits == cold.cache_hits
    assert warm.times == cold.times
    assert [s.items for s in warm.schedules] \
        == [s.items for s in cold.schedules]
    fa, la, ta = cold.dataset()
    fb, lb, tb = warm.dataset()
    assert ta.tobytes() == tb.tobytes()
    assert fa.X.tobytes() == fb.X.tobytes()
    assert fa.names() == fb.names()
    assert np.array_equal(la.labels, lb.labels)


def test_store_holds_noiseless_base_times(store_path):
    """Noise stays parent-side: a noisy search writes *base* times, so
    a warm noiseless run sees the clean values and a warm noisy run
    redraws the identical (canonical key, draw index) jitter."""
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))[:12]
    with E.make_evaluator(g, "sim", store_path=store_path,
                          noise_sigma=0.05, noise_seed=9) as ev:
        noisy_cold = ev.evaluate(scheds)
    clean = E.make_evaluator(g, "sim").evaluate(scheds)
    with E.make_evaluator(g, "sim", store_path=store_path) as ev2:
        assert ev2.evaluate(scheds) == clean     # base times stored
        assert ev2.cache_misses == 0
    with E.make_evaluator(g, "sim", store_path=store_path,
                          noise_sigma=0.05, noise_seed=9) as ev3:
        assert ev3.evaluate(scheds) == noisy_cold
        assert ev3.cache_misses == 0


def test_store_cross_backend_warm_start(store_path):
    """The analytic family shares one fingerprint: a store warmed by
    the vectorized backend serves sim and pool."""
    g = spmv_dag_fine()
    scheds = list(C.enumerate_schedules(g, 2))[:30]
    with E.make_evaluator(g, "vectorized",
                          store_path=store_path) as ev:
        base = ev.evaluate(scheds)
    for backend, kwargs in (("sim", {}),
                            ("pool", {"n_workers": 2, "min_shard": 1})):
        with E.make_evaluator(g, backend, store_path=store_path,
                              **kwargs) as ev2:
            assert ev2.evaluate(scheds) == base
            assert (ev2.store_hits, ev2.cache_misses) == (30, 0)


def test_wallclock_store_seam(store_path):
    """Wallclock measurements persist too: a fresh evaluator replays
    them as store hits without re-measuring (times are memoized real
    measurements, so the values match exactly)."""
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    impls, env = E.demo_spmv_impls(g, n=8)
    scheds = list(C.enumerate_schedules(g, 2))[:4]
    with E.make_evaluator(g, "wallclock", impls=impls, env=env,
                          repeats=2, store_path=store_path) as ev:
        cold = ev.evaluate(scheds)
        assert ev.cache_misses == 4
    with E.make_evaluator(g, "wallclock", impls=impls, env=env,
                          repeats=2, store_path=store_path) as ev2:
        assert ev2.evaluate(scheds) == cold
        assert (ev2.store_hits, ev2.cache_misses) == (4, 0)
        assert ev2.n_checked == 0           # nothing re-run


def test_shared_store_object_not_closed_by_evaluator(store_path):
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))[:5]
    store = EvalStore(store_path)
    with E.make_evaluator(g, "sim", store=store) as ev:
        ev.evaluate(scheds)
    # The evaluator owned nothing: the caller's store is still open.
    store.put(_fp(), b"still-open", 1.0)
    store.close()


def test_store_and_store_path_mutually_exclusive(store_path):
    g = C.spmv_dag()
    with EvalStore(store_path) as store:
        with pytest.raises(ValueError, match="not both"):
            E.make_evaluator(g, "sim", store=store,
                             store_path=store_path)
        with pytest.raises(ValueError, match="preconfigured"):
            S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=4,
                         evaluator=E.make_evaluator(g, "sim"),
                         store=store)


def test_salvaged_measurements_reach_the_store(store_path):
    """A wallclock batch aborted by the value gate still persists its
    completed (paid) measurements — a fresh process replays them."""
    import jax.numpy as jnp
    g = C.spmv_dag(rows_per_rank=32, nnz_per_rank=128)
    impls, env = E.demo_spmv_impls(g, n=8)
    bad = dict(impls)
    bad["yR"] = C.op_impl(lambda x, y: x + y, ["xR", "yL"], ["yR"])
    env = dict(env)
    env["yL"] = jnp.zeros((8,), jnp.float32)
    scheds = list(C.enumerate_schedules(g, 2))
    ref = E.reference_schedule(g)

    def yl_first(s):
        order = s.order()
        return order.index("yL") < order.index("yR")

    good = next(s for s in scheds if yl_first(s) == yl_first(ref))
    target = next(s for s in scheds if yl_first(s) != yl_first(ref))
    with E.make_evaluator(g, "wallclock", impls=bad, env=env, repeats=1,
                          store_path=store_path) as ev:
        with pytest.raises(AssertionError):
            ev.evaluate([good, target])
    with E.make_evaluator(g, "wallclock", impls=bad, env=env, repeats=1,
                          store_path=store_path) as ev2:
        t = ev2.evaluate_one(good)
        assert t > 0.0
        assert (ev2.store_hits, ev2.cache_misses) == (1, 0)
