"""Kernel-autotune rows (``autotune_*``): what the DesignSpace stack
buys when the candidates are the repo's own Pallas kernel parameters.

Two pairs of rows over the same ``spmv_mulsum`` block-size grid
(interpret mode, so the numbers are CPU-portable and CI-safe; a real
TPU tuning run uses the same code with ``interpret=None``):

* ``autotune_store_{cold,warm}`` — the persistent-store warm start for
  kernel sweeps: a fresh :class:`repro.engine.params.
  KernelWallclockEvaluator` against an empty store file (cold: every
  candidate compiled, gated, and timed, then written through) vs
  against the warmed file (warm: every candidate replayed from disk,
  zero kernel executions). The derived column reports the speedup and
  the replay-identity verdict — warm times must equal the memoized
  cold measurements exactly.
* ``autotune_compile_{batch,per_candidate}`` — what batch-ahead
  compilation amortizes: ``compile_mode="batch"`` compiles + gates the
  whole miss batch before any timing, ``"per_candidate"`` interleaves
  compile/gate/time per candidate. Both measure the same quantity
  (identical store fingerprint), so the row pair is pure
  compile-scheduling overhead.
"""
from __future__ import annotations

import os
import tempfile
import time

import repro.engine as E

REPS = 3


def _grid():
    # Fresh space per use: closures (and their jit caches) are not
    # shared across reps, so every timed pass pays its own compiles.
    from repro.kernels.autotune import spmv_mulsum_space
    return spmv_mulsum_space(n=256, k=8, block_values=(32, 64, 128),
                             interpret=True)


def autotune_benches() -> list[str]:
    rows = []
    n = _grid().n_candidates()
    label = f"spmv_mulsum_{n}"

    # Cold vs store-warmed sweep (best-of-REPS, fresh store per rep).
    best_cold = best_warm = float("inf")
    cold_out = warm_out = None
    with tempfile.TemporaryDirectory() as tmp:
        for rep in range(REPS):
            path = os.path.join(tmp, f"autotune.{rep}.evalstore")
            sp = _grid()
            cands = list(sp.enumerate_candidates())
            with E.make_evaluator(sp, "wallclock", repeats=1,
                                  store_path=path) as ev:
                t0 = time.perf_counter()
                cold_out = ev.evaluate(cands)
                best_cold = min(best_cold, time.perf_counter() - t0)
                assert ev.cache_misses == n
            with E.make_evaluator(_grid(), "wallclock", repeats=1,
                                  store_path=path) as ev:
                t0 = time.perf_counter()
                warm_out = ev.evaluate(cands)
                best_warm = min(best_warm, time.perf_counter() - t0)
                assert (ev.store_hits, ev.cache_misses) == (n, 0)
        size_kb = os.path.getsize(path) / 1024
    ident = "identical" if warm_out == cold_out else "MISMATCH"
    rows.append(f"autotune_store_cold_{label},"
                f"{best_cold / n * 1e6:.2f},store_{size_kb:.1f}KiB")
    rows.append(f"autotune_store_warm_{label},"
                f"{best_warm / n * 1e6:.2f},"
                f"{best_cold / best_warm:.2f}x_vs_cold_{ident}")

    # Batch-ahead vs per-candidate compilation over the same grid.
    best = {"batch": float("inf"), "per_candidate": float("inf")}
    for _ in range(REPS):
        for mode in ("batch", "per_candidate"):
            sp = _grid()
            cands = list(sp.enumerate_candidates())
            with E.make_evaluator(sp, "wallclock", repeats=1,
                                  compile_mode=mode) as ev:
                t0 = time.perf_counter()
                ev.evaluate(cands)
                best[mode] = min(best[mode],
                                 time.perf_counter() - t0)
                assert ev.n_checked == n
    rows.append(f"autotune_compile_batch_{label},"
                f"{best['batch'] / n * 1e6:.2f},{n}_candidates")
    rows.append(f"autotune_compile_per_candidate_{label},"
                f"{best['per_candidate'] / n * 1e6:.2f},"
                f"{best['per_candidate'] / best['batch']:.2f}"
                f"x_vs_batch")
    return rows
