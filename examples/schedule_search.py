"""The paper's technique as a framework feature: discover collective-
overlap design rules for OUR OWN train step.

The LM train step decomposes into an op-DAG (per-layer fwd/bwd compute,
per-layer gradient reduce-scatters, the optimizer update). "Streams" are
the TPU compute stream + ICI channels. The search portfolio (greedy
seeding → MCTS refinement → surrogate-screened exploitation) + the
machine model search the (emission order x channel assignment) space;
the decision tree then emits human-readable rules like "rs0 before
bwd2" or "rs1 different stream than bwd1" — exactly the paper's
output, for a 2026 workload.

With ``--space`` the same pipeline runs over any *registered* design
space instead of the train-step DAG: the paper's schedule spaces
(``spmv``, ``spmv_fine``, ``halo3d``) or the repo's own Pallas kernel
parameter grids (``flash_attention``, ``spmv_mulsum``, ``pack`` —
autotuned through the wall-clock runner, emitting block-size design
rules; ``demo`` is an analytic grid needing no JAX).

Usage: PYTHONPATH=src python examples/schedule_search.py
           [--arch qwen2.5-32b] [--layers 4] [--iters 600]
           [--space spmv|halo3d|flash_attention|...]
           [--strategy portfolio|mcts]
           [--backend sim|vectorized|pool|wallclock|rpc]
           [--hosts host:port,host:port]
           [--surrogate ridge|boost]
           [--acquisition argmin_topk|ucb|expected_improvement]
           [--rules [PATH]] [--store PATH]
           [--trace PATH] [--telemetry]
"""
import argparse

import repro.rules as R
import repro.search as S
from repro import obs
from repro.configs import get_config
from repro.driver import ACQUISITIONS
from repro.core.stepdag import StepCosts, train_step_dag, \
    with_comm_durations
from repro.launch.costs import HBM_BW, LINK_BW, PEAK_FLOPS
from repro.space import SPACES, ParamSpace, make_space


def costs_from_arch(arch: str, layers: int, tokens_per_chip: int,
                    tp: int = 16, dp: int = 16) -> StepCosts:
    cfg = get_config(arch)
    n_per_layer = cfg.active_param_count() / cfg.n_layers
    # Per-chip, per-(coarsened)-layer costs; `layers` coarse stages.
    coarse = cfg.n_layers / layers
    fwd_flops = 2 * n_per_layer * tokens_per_chip * coarse / tp
    fwd_bytes = fwd_flops / 50.0          # ~50 flops/byte at bf16
    grad_bytes = n_per_layer * coarse * 4 / tp * (dp - 1) / dp
    return StepCosts(fwd_flops=fwd_flops, bwd_flops=2 * fwd_flops,
                     fwd_bytes=fwd_bytes, bwd_bytes=2 * fwd_bytes,
                     grad_bytes=grad_bytes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--layers", type=int, default=4,
                    help="coarse pipeline stages in the DAG")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--channels", type=int, default=2)
    ap.add_argument("--space", choices=tuple(sorted(SPACES)),
                    default=None,
                    help="search a registered design space "
                         "(repro.space registry) instead of the "
                         "train-step DAG; kernel grids default to the "
                         "wall-clock runner")
    ap.add_argument("--strategy", choices=("portfolio", "mcts"),
                    default="portfolio",
                    help="portfolio = greedy seeding + MCTS refinement "
                         "+ surrogate-screened exploitation "
                         "(graph spaces only; kernel grids always "
                         "use mcts)")
    ap.add_argument("--backend",
                    choices=("sim", "vectorized", "pool", "wallclock",
                             "rpc"),
                    default=None,
                    help="evaluation engine (repro.engine registry); "
                         "all analytic backends are bit-identical — "
                         "a pure throughput choice. Default: sim for "
                         "analytic spaces, wallclock for kernel "
                         "grids (see src/repro/engine/README.md). "
                         "rpc requires --hosts")
    ap.add_argument("--hosts", default=None, metavar="H:P,H:P",
                    help="comma-separated host:port evaluation servers "
                         "for --backend rpc (each running python -m "
                         "repro.engine.server on a matching --space)")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="schedules per propose() call; default 1 for "
                         "the sim backend (the paper's strictly "
                         "sequential loop) and 32 for vectorized/pool, "
                         "which only amortize across batches")
    ap.add_argument("--surrogate", choices=tuple(sorted(S.SURROGATES)),
                    default="ridge",
                    help="screening model for the portfolio's "
                         "exploitation phase (repro.search surrogate "
                         "registry; 'boost' = gradient-boosted trees)")
    ap.add_argument("--acquisition",
                    choices=tuple(sorted(ACQUISITIONS)),
                    default="argmin_topk",
                    help="how the candidate pool is ranked "
                         "(repro.driver acquisition registry; ucb / "
                         "expected_improvement add the boosted "
                         "ensemble's per-tree uncertainty — pair them "
                         "with --surrogate boost)")
    ap.add_argument("--store", default=None, metavar="PATH",
                    help="persistent content-addressed evaluation "
                         "store (repro.engine.EvalStore): base times "
                         "measured this run are appended, and a later "
                         "run on the same graph/machine replays them "
                         "as store hits without re-simulating — "
                         "warm-start across processes and backends")
    ap.add_argument("--rules", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="render the full design-rule report "
                         "(repro.rules.distill) to PATH, or to stdout "
                         "when given without a value")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event / Perfetto JSON "
                         "trace of the whole run (driver rounds, "
                         "evaluator batches, store traffic, distill "
                         "stages) to PATH — open it at "
                         "https://ui.perfetto.dev. Trace-enabled runs "
                         "attach an ephemeral evaluation store when "
                         "--store is not given, so the store layer "
                         "shows up in the trace (results are "
                         "byte-identical either way)")
    ap.add_argument("--telemetry", action="store_true",
                    help="print the telemetry summary table (span "
                         "walls, counters, gauges) after the run")
    args = ap.parse_args()

    tel = None
    if args.trace or args.telemetry:
        exporters = [obs.PerfettoExporter(args.trace)] if args.trace \
            else []
        tel = obs.Telemetry(exporters=exporters)
        obs.set_current(tel)
    ephemeral_store = None
    if args.trace and args.store is None:
        # A pure observer: the store holds noiseless base times, and
        # cold runs with a store attached are byte-identical to
        # storeless ones (locked by tests/test_engine_store.py) — so a
        # throwaway store is a free way to get store-layer spans into
        # the trace.
        import tempfile
        ephemeral_store = tempfile.mkdtemp(prefix="repro-trace-")
        args.store = f"{ephemeral_store}/trace.evalstore"

    if args.space is not None:
        try:
            target = make_space(args.space, n_streams=args.channels)
        except TypeError:  # parameter grids take no n_streams
            target = make_space(args.space)
        graph = getattr(target, "graph", None)
        kind = "parameter grid" if isinstance(target, ParamSpace) \
            else "schedule space"
        print(f"design space {target.name!r} ({kind})")
    else:
        costs = costs_from_arch(args.arch, args.layers,
                                tokens_per_chip=16 * 4096 // 16)
        graph = with_comm_durations(train_step_dag(args.layers, costs),
                                    LINK_BW)
        target = graph
        print(f"train-step DAG for {args.arch}: "
              f"{graph.n_vertices()} ops, {args.layers} stages")

    kernel_grid = isinstance(target, ParamSpace) \
        and target.runner is not None
    if args.backend is None:
        args.backend = "wallclock" if kernel_grid else "sim"
    if args.batch_size is None:
        args.batch_size = 1 if args.backend == "sim" else 32
    backend_kwargs = None
    if args.backend == "rpc":
        if not args.hosts:
            ap.error("--backend rpc requires --hosts host:port[,...]")
        hosts = [h.strip() for h in args.hosts.split(",") if h.strip()]
        backend_kwargs = {"hosts": hosts}
        print(f"evaluation fleet: {len(hosts)} host(s) "
              f"({', '.join(hosts)})")
    elif args.hosts:
        ap.error("--hosts only applies to --backend rpc")

    if args.strategy == "portfolio" and graph is not None:
        strategy = S.PortfolioSearch(graph, args.channels, seed=0,
                                     surrogate=args.surrogate,
                                     acquisition=args.acquisition)
    else:  # graph-less spaces: the space-generic MCTS
        strategy = S.MCTSSearch(target, seed=0) if graph is None \
            else S.MCTSSearch(graph, args.channels, seed=0)
    res = S.run_search(target, strategy, budget=args.iters,
                       backend=args.backend, batch_size=args.batch_size,
                       backend_kwargs=backend_kwargs,
                       store_path=args.store)
    times = res.times_array()
    best, best_t = res.best()
    print(f"explored {len(res.schedules)} schedules "
          f"({res.n_proposed} evaluations, {res.cache_hits} memo hits); "
          f"best {times.min() * 1e3:.2f} ms, "
          f"worst {times.max() * 1e3:.2f} ms "
          f"({times.max() / times.min():.2f}x)")
    if args.store is not None:
        print(f"evaluation store {args.store}: {res.store_hits} warm "
              f"hits, {res.cache_misses} new measurements appended")
    if args.strategy == "portfolio" and graph is not None:
        q = strategy.screening_quality()
        print(f"surrogate screened {q['n_screened']} candidates "
              f"({q['n_compared']} simulated; rank corr "
              f"{q['spearman']:.2f})")
    if graph is None:
        print(f"best parameters: {target.describe(best)}")
    else:
        print("best emission order:",
              " ".join(str(i) for i in best.items
                       if i.name not in ("start", "end")))

    report = R.distill(res)
    print(f"\n{report.labeling.n_classes} performance classes; "
          f"design rules:")
    print(R.render_rules_table(report.grouped(), top_k=2))
    if args.rules == "-":
        print("\n" + report.render())
    elif args.rules is not None:
        path = report.write(args.rules)
        print(f"\nfull design-rule report written to {path}")

    if tel is not None:
        if args.telemetry:
            print("\n" + tel.summary())
        if res.telemetry:
            r_last = res.telemetry[-1]
            print(f"\ntelemetry: {len(res.telemetry)} driver rounds; "
                  f"final round {r_last['round']} "
                  f"(best {r_last['best'] * 1e6:.2f} us, "
                  f"{r_last['misses']} misses)")
        tel.close()
        if args.trace:
            print(f"trace written to {args.trace} — open it at "
                  "https://ui.perfetto.dev")
        obs.set_current(None)
    if ephemeral_store is not None:
        import shutil
        shutil.rmtree(ephemeral_store, ignore_errors=True)

    # Roofline context for the fastest train-step schedule.
    if args.space is None:
        total_flops = sum(op.flops for op in graph.ops.values())
        print(f"\ncompute-only bound "
              f"{total_flops / PEAK_FLOPS * 1e3:.2f} ms;"
              f" best overlap schedule {times.min() * 1e3:.2f} ms "
              f"({total_flops / PEAK_FLOPS / times.min():.0%} of peak)")


if __name__ == "__main__":
    main()
