"""Pallas TPU kernels for ELL sparse-matrix x vector products.

Hardware adaptation (see DESIGN.md): the paper's cuSPARSE-style SpMV is
gather-bound. TPUs have no per-lane hardware gather, so a mechanical port
is wrong. The TPU-native decomposition is:

  * ``ell_mulsum``  — the arithmetic half: y = sum_k vals[k] * x_gathered[k]
    as a lane-parallel fused multiply-reduce over a K-major ("ELL-T")
    layout: vals_t (K, N) so the short K axis sits on sublanes and the
    long row axis on lanes. The gather itself is done by XLA's gather HLO
    (efficient on TPU for VMEM/HBM-resident vectors) in the ops wrapper.

  * ``ell_onehot_mv`` — a fully in-kernel variant for *narrow-band*
    matrices: each row-block's columns fall in a width-W window, so the
    gather is cast as a one-hot matmul against the window — an MXU-
    friendly pattern. Wasteful for the paper's wide band (W ~ n/2;
    overhead ~W/K), ideal for W <~ 1024; the ops wrapper picks per input.

Both are validated against ref.py in interpret mode across shape/dtype
sweeps (tests/test_kernels_spmv.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
SUBLANES = 8


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# Kernel A: fused multiply-reduce over pre-gathered operands (ELL-T layout)
# ---------------------------------------------------------------------------

def _mulsum_body(vals_ref, xg_ref, y_ref):
    v = vals_ref[...].astype(jnp.float32)
    g = xg_ref[...].astype(jnp.float32)
    y_ref[...] = jnp.sum(v * g, axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def ell_mulsum(vals_t: jax.Array, xg_t: jax.Array,
               block_n: int = 512, interpret: bool = True) -> jax.Array:
    """y (N,) = sum over K of vals_t (K, N) * xg_t (K, N).

    K is padded to the sublane tile, N to ``block_n`` (lane-aligned).
    """
    k, n = vals_t.shape
    kp = _round_up(max(k, 1), SUBLANES)
    np_ = _round_up(n, block_n)
    vals_p = jnp.zeros((kp, np_), vals_t.dtype).at[:k, :n].set(vals_t)
    xg_p = jnp.zeros((kp, np_), xg_t.dtype).at[:k, :n].set(xg_t)

    grid = (np_ // block_n,)
    out = pl.pallas_call(
        _mulsum_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((kp, block_n), lambda j: (0, j)),
            pl.BlockSpec((kp, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=interpret,
    )(vals_p, xg_p)
    return out[0, :n]


# ---------------------------------------------------------------------------
# Kernel B: in-kernel gather via one-hot MXU matmul (narrow-band windows)
# ---------------------------------------------------------------------------

def _onehot_body(vals_ref, cols_ref, xwin_ref, y_ref, *, block_r: int,
                 window: int, k: int):
    xw = xwin_ref[0, :].astype(jnp.float32)          # (W,)
    acc = jnp.zeros((block_r,), jnp.float32)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_r, window), 1)
    for kk in range(k):  # K is small and static: unrolled
        c = cols_ref[kk, :]                          # (R,) int32
        v = vals_ref[kk, :].astype(jnp.float32)      # (R,)
        onehot = (iota == c[:, None]).astype(jnp.float32)   # (R, W)
        gathered = jax.lax.dot_general(
            onehot, xw[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        acc = acc + v * gathered
    y_ref[...] = acc[None, :]


@functools.partial(jax.jit,
                   static_argnames=("block_r", "window", "interpret"))
def ell_onehot_mv(vals_t: jax.Array, cols_win_t: jax.Array,
                  x_windows: jax.Array, block_r: int = 256,
                  window: int | None = None,
                  interpret: bool = True) -> jax.Array:
    """Narrow-band SpMV with in-kernel one-hot gather.

    vals_t / cols_win_t: (K, N) ELL-T; cols are *window-relative* per row
    block (see ops.ell_matvec_onehot). x_windows: (N // block_r, W) — the
    width-W slice of (wrap-padded) x covering each row block's columns.
    """
    k, n = vals_t.shape
    nblocks, w = x_windows.shape
    assert n % block_r == 0 and nblocks == n // block_r
    window = w if window is None else window
    kp = _round_up(max(k, 1), SUBLANES)
    vals_p = jnp.zeros((kp, n), vals_t.dtype).at[:k].set(vals_t)
    # Padding rows gather window slot 0 with val 0: harmless.
    cols_p = jnp.zeros((kp, n), jnp.int32).at[:k].set(cols_win_t)

    out = pl.pallas_call(
        functools.partial(_onehot_body, block_r=block_r, window=w, k=kp),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((kp, block_r), lambda b: (0, b)),
            pl.BlockSpec((kp, block_r), lambda b: (0, b)),
            pl.BlockSpec((1, w), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_r), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block_r), jnp.float32),
        interpret=interpret,
    )(vals_p, cols_p, x_windows)
    return out.reshape(n)
