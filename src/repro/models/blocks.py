"""Transformer/SSM block assembly: pre-norm mixer + (dense|MoE) MLP.

A :class:`LayerDesc` describes one layer of a repeating *period*:
mixer kind (attn / mamba / rwkv), MoE or dense MLP, optional
cross-attention sublayer (enc-dec decoder), causal or bidirectional.
Periods are scanned with stacked parameters; layers inside a period are
python-unrolled (heterogeneous kinds allowed — Jamba's 1:7 interleave).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import rwkv6 as rwk
from repro.models.config import ModelConfig
from repro.models.layers import mlp, mlp_specs, norm_spec, rmsnorm
from repro.models.moe import moe_mlp, moe_specs


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str               # attn | mamba | rwkv
    moe: bool = False
    cross: bool = False
    causal: bool = True


def block_specs(cfg: ModelConfig, desc: LayerDesc) -> dict:
    d = cfg.d_model
    out: dict = {"norm_mix": norm_spec(d)}
    if desc.kind == "attn":
        out["mixer"] = attn.attention_specs(cfg)
    elif desc.kind == "mamba":
        out["mixer"] = mam.mamba_specs(cfg)
    elif desc.kind == "rwkv":
        out["mixer"] = rwk.rwkv_specs(cfg)
    else:
        raise ValueError(desc.kind)
    if desc.cross:
        out["norm_cross"] = norm_spec(d)
        out["cross"] = attn.attention_specs(cfg)
    out["norm_mlp"] = norm_spec(d)
    out["mlp"] = moe_specs(cfg) if desc.moe else mlp_specs(cfg)
    return out


def _mlp_part(p: dict, x: jax.Array, cfg: ModelConfig, desc: LayerDesc,
              moe_capacity: int | None = None):
    h = rmsnorm(x, p["norm_mlp"], cfg.rms_eps)
    if desc.moe:
        y, aux = moe_mlp(p["mlp"], h, cfg, capacity=moe_capacity)
    else:
        y, aux = mlp(p["mlp"], h, cfg.mlp), 0.0
    return x + y, aux


def block_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                  desc: LayerDesc, positions: jax.Array,
                  memory: jax.Array | None = None,
                  memory_valid: jax.Array | None = None,
                  rwkv_chunk: int | None = None):
    """Full-sequence mode (training / encoding). Returns (x, aux)."""
    h = rmsnorm(x, p["norm_mix"], cfg.rms_eps)
    if desc.kind == "attn":
        y = attn.attn_forward(p["mixer"], h, cfg, positions,
                              causal=desc.causal)
    elif desc.kind == "mamba":
        y, _ = mam.mamba_forward(p["mixer"], h, cfg)
    else:
        y, _ = rwk.rwkv_forward(p["mixer"], h, cfg, chunk=rwkv_chunk)
    x = x + y
    if desc.cross:
        h = rmsnorm(x, p["norm_cross"], cfg.rms_eps)
        x = x + attn.attn_forward(p["cross"], h, cfg, positions,
                                  memory=memory,
                                  memory_valid=memory_valid)
    return _mlp_part(p, x, cfg, desc)


def init_cache(cfg: ModelConfig, desc: LayerDesc, batch: int,
               t_max: int, n_memory: int, dtype) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hkv = cfg.head_layout()[0]   # stored-KV width (duplicated heads)
    if desc.kind == "attn":
        c = {"k": jnp.zeros((batch, t_max, hkv, dh), dtype),
             "v": jnp.zeros((batch, t_max, hkv, dh), dtype)}
    elif desc.kind == "mamba":
        di = cfg.mamba_expand * d
        c = {"conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
             "h": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32)}
    else:
        n = cfg.rwkv_head_dim
        c = {"shift": jnp.zeros((batch, d), dtype),
             "s": jnp.zeros((batch, d // n, n, n), jnp.float32)}
    if desc.cross:
        c["ck"] = jnp.zeros((batch, n_memory, hkv, dh), dtype)
        c["cv"] = jnp.zeros((batch, n_memory, hkv, dh), dtype)
    return c


def block_prefill(p: dict, x: jax.Array, cfg: ModelConfig,
                  desc: LayerDesc, positions: jax.Array, t_max: int,
                  memory: jax.Array | None = None,
                  rwkv_chunk: int | None = None):
    """Like block_forward but also returns the decode cache entry."""
    b, s, _ = x.shape
    h = rmsnorm(x, p["norm_mix"], cfg.rms_eps)
    cache: dict = {}
    if desc.kind == "attn":
        q, k, v = attn.project_qkv(p["mixer"], h, h, cfg)
        q = attn.rope(q, positions, cfg.rope_theta)
        k = attn.rope(k, positions, cfg.rope_theta)
        kv_val = jnp.ones(s, bool)
        k_rep, v_rep = attn.repeat_kv(cfg, k), attn.repeat_kv(cfg, v)
        o = attn.streaming_attention(
            q, k_rep, v_rep,
            positions, positions, kv_val, causal=desc.causal,
            window=cfg.attn_window, softcap=cfg.attn_logit_softcap)
        y = attn.out_proj(p["mixer"], o, cfg)
        pad = t_max - s
        cache["k"] = jnp.pad(k_rep, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache["v"] = jnp.pad(v_rep, ((0, 0), (0, pad), (0, 0), (0, 0)))
    elif desc.kind == "mamba":
        y, (conv, hst) = mam.mamba_forward(p["mixer"], h, cfg)
        cache["conv"], cache["h"] = conv, hst
    else:
        y, (shift, sst) = rwk.rwkv_forward(p["mixer"], h, cfg,
                                           chunk=rwkv_chunk)
        cache["shift"], cache["s"] = shift, sst
    x = x + y
    if desc.cross:
        hc = rmsnorm(x, p["norm_cross"], cfg.rms_eps)
        qc, ck, cv = attn.project_qkv(p["cross"], hc, memory, cfg)
        kv_pos = jnp.arange(memory.shape[1])
        ck, cv = attn.repeat_kv(cfg, ck), attn.repeat_kv(cfg, cv)
        o = attn.streaming_attention(
            qc, ck, cv,
            positions, kv_pos,
            jnp.ones(memory.shape[1], bool), causal=False)
        x = x + attn.out_proj(p["cross"], o, cfg)
        cache["ck"], cache["cv"] = ck, cv
    x, aux = _mlp_part(p, x, cfg, desc)
    return x, aux, cache


def block_decode(p: dict, x: jax.Array, cfg: ModelConfig,
                 desc: LayerDesc, pos: jax.Array, cache: dict):
    """Single-token step. x: (B, 1, d). Returns (x, new_cache)."""
    cache = dict(cache)
    h = rmsnorm(x, p["norm_mix"], cfg.rms_eps)
    if desc.kind == "attn":
        y, cache["k"], cache["v"] = attn.attn_decode(
            p["mixer"], h, cfg, pos, cache["k"], cache["v"])
    elif desc.kind == "mamba":
        y, (cache["conv"], cache["h"]) = mam.mamba_decode(
            p["mixer"], h, cfg, (cache["conv"], cache["h"]))
    else:
        y, (cache["shift"], cache["s"]) = rwk.rwkv_decode(
            p["mixer"], h, cfg, (cache["shift"], cache["s"]))
    x = x + y
    if desc.cross:
        hc = rmsnorm(x, p["norm_cross"], cfg.rms_eps)
        q = attn.project_qkv(p["cross"], hc, hc, cfg)[0]
        t = cache["ck"].shape[1]
        o = attn._decode_attention(
            q, cache["ck"], cache["cv"],
            jnp.asarray(t, jnp.int32), jnp.arange(t),
            window=None, softcap=None)
        x = x + attn.out_proj(p["cross"], o, cfg)
    # Decode is dropless: capacity = token count (exact routing).
    x, _ = _mlp_part(p, x, cfg, desc, moe_capacity=x.shape[0])
    return x, cache
