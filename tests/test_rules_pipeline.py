"""The distill() pipeline, RuleReport rendering, and the vectorized
label/accuracy helpers locked to their loop references."""
import numpy as np
import pytest

import repro.core as C
import repro.rules as R
import repro.search as S


@pytest.fixture(scope="module")
def spmv_results():
    g = C.spmv_dag()
    full = S.run_search(g, S.ExhaustiveSearch(g, 2), budget=None,
                        batch_size=64)
    subset = S.run_search(g, S.MCTSSearch(g, 2, seed=2), budget=100)
    return full, subset


# -- distill ------------------------------------------------------------------

def test_distill_end_to_end(spmv_results):
    full, _ = spmv_results
    rep = R.distill(full)
    assert isinstance(rep, R.RuleReport)
    assert rep.n_schedules == len(full.schedules)
    assert rep.labeling.n_classes >= 2
    assert rep.rulesets and rep.tree.n_leaves() == len(rep.rulesets)
    assert rep.training_error == 0.0
    assert not rep.annotated and rep.class_range_acc is None
    s = rep.summary()
    assert s["n_rulesets"] == len(rep.rulesets)
    assert s["algorithm1_trials"] == len(rep.trace.max_leaf_nodes)
    assert "class_range_acc" not in s


def test_distill_matches_hand_wired_pipeline(spmv_results):
    """distill() is the same five steps every consumer used to wire by
    hand — identical tree and rulesets."""
    full, _ = spmv_results
    rep = R.distill(full)
    fm, lab, times = full.dataset()
    tree = C.algorithm1(fm.X, lab.labels)
    np.testing.assert_array_equal(rep.tree.predict(fm.X),
                                  tree.predict(fm.X))
    want = C.extract_rulesets(tree, fm.features)
    assert [rs.atoms() for rs in rep.rulesets] == \
        [rs.atoms() for rs in want]


def test_distill_full_space_accuracy(spmv_results):
    full, subset = spmv_results
    space = (full.schedules, full.times_array())
    rep = R.distill(subset, full_space=space)
    assert rep.class_range_acc is not None
    assert 0.0 <= rep.class_range_acc <= 1.0
    # widening the ranges can only help
    rep_w = R.distill(subset, full_space=space, range_widen=0.5)
    assert rep_w.class_range_acc >= rep.class_range_acc


def test_distill_canonical_annotation(spmv_results):
    full, subset = spmv_results
    canon = R.distill(full)
    rep = R.distill(subset, canonical=canon)
    assert rep.annotated
    s = rep.summary()
    assert "n_overconstrained" in s and "n_underconstrained" in s
    # a report annotated against itself is never underconstrained
    self_rep = R.distill(full, canonical=canon)
    assert self_rep.summary()["n_underconstrained"] == 0
    # a raw ruleset list works too (non-RuleReport canonical)
    rep2 = R.distill(subset, canonical=canon.rulesets)
    assert [rs.insufficient for rs in rep2.rulesets] == \
        [rs.insufficient for rs in rep.rulesets]


def test_distill_pluggable_labeler(spmv_results):
    full, _ = spmv_results
    calls = []

    def labeler(times):
        calls.append(len(times))
        return R.label_times(times, prominence_percentile=90.0)

    rep = R.distill(full, labeler=labeler)
    assert calls == [len(full.schedules)]
    assert rep.labeling.n_classes >= 1


def test_rule_report_render_and_write(tmp_path, spmv_results):
    full, subset = spmv_results
    rep = R.distill(subset, canonical=R.distill(full),
                    full_space=(full.schedules, full.times_array()))
    text = rep.render()
    assert "# design-rule report" in text
    assert "performance class 1" in text
    assert "class-range accuracy" in text
    assert "vs canonical rules" in text
    out = rep.write(tmp_path / "sub" / "rules.md")
    assert out.read_text() == text


def test_render_rules_table_matches_report_sections(spmv_results):
    full, _ = spmv_results
    rep = R.distill(full)
    table = R.render_rules_table(rep.grouped(), top_k=3)
    assert table in rep.render(top_k=3)


# -- vectorized helpers locked to their loop references -----------------------

def test_peak_prominences_vectorized_equals_loop():
    rng = np.random.default_rng(0)
    for n in (3, 10, 100, 1000):
        for _ in range(5):
            x = rng.random(n)
            if rng.random() < 0.3:      # plateau-heavy signals
                x = np.round(x, 1)
            peaks = R.find_peaks(x)
            np.testing.assert_allclose(
                R.peak_prominences(x, peaks),
                R.peak_prominences_loop(x, peaks))
    # edge: peak at the array boundary windows
    x = np.array([0.0, 2.0, 1.0, 3.0, 0.0])
    p = R.find_peaks(x)
    np.testing.assert_allclose(R.peak_prominences(x, p),
                               R.peak_prominences_loop(x, p))


def test_class_range_accuracy_vectorized_equals_loop(spmv_results):
    full, subset = spmv_results
    fm, lab, _ = subset.dataset()
    tree = C.algorithm1(fm.X, lab.labels)
    Xf = C.featurize_like(full.graph, full.schedules, fm)
    times = full.times_array()
    ranges = lab.class_ranges()
    assert R.class_range_accuracy(tree, Xf, times, ranges) == \
        pytest.approx(
            R.class_range_accuracy_loop(tree, Xf, times, ranges))
    # empty space edge case
    assert R.class_range_accuracy(
        tree, np.zeros((0, fm.X.shape[1])), np.zeros(0), ranges) == 0.0


def test_labeling_unchanged_by_vectorization():
    """label_times (searchsorted labels, numpy prominences) matches the
    documented §IV-A semantics on structured data."""
    rng = np.random.default_rng(0)
    times = np.concatenate([
        1.00 + 0.01 * rng.random(400),
        1.50 + 0.01 * rng.random(300),
        2.00 + 0.01 * rng.random(300),
    ])
    rng.shuffle(times)
    lab = R.label_times(times)
    assert 3 <= lab.n_classes <= 5
    srt = lab.labels[np.argsort(times, kind="stable")]
    assert (np.diff(srt) >= 0).all()
    # every boundary index bumps the class exactly once
    assert lab.n_classes == len(lab.boundaries) + 1


# -- benchmarks plumbing ------------------------------------------------------

def test_tables678_writes_explicit_path(tmp_path):
    from benchmarks.paper import tables678_rules

    out = tmp_path / "rules_canonical.md"
    rows = tables678_rules(rules_path=out)
    assert len(rows) == 3
    text = out.read_text()
    assert "# design-rule report" in text
    assert "performance class 1" in text
