"""Two-stage (surrogate-screened) search and the strategy portfolio.

The discrete-event simulation behind :class:`BatchEvaluator` is the
expensive resource: one call per proposal caps how much of a >5e5-point
space (``spmv_dag_fine``, ``halo3d_dag``) any strategy can see. The
OptiML-style answer is two-stage evaluation: a cheap learned surrogate
screens a large candidate pool, and only the surrogate's top-k reach
the simulator. Everything here rides the existing
``SearchStrategy``/``BatchEvaluator`` seam — the evaluator still owns
simulation; the surrogate only decides *which* proposals are worth it.

  * :class:`RidgeSurrogate` — ridge regression over the §IV-B
    order/stream feature vectors, trained online from ``observe``d
    (schedule, time) pairs via the incremental
    :class:`repro.core.features.FeatureBasis` (new schedules are
    absorbed without re-expanding the corpus).
  * The **surrogate registry** — :func:`make_surrogate` /
    :func:`register_surrogate` resolve surrogate models by name behind
    one protocol (``observe`` / ``predict`` / ``n_observations``,
    shared via :class:`repro.rules.boost.OnlineSurrogateBase`).
    Built-ins: ``"ridge"`` (here) and ``"boost"``
    (:class:`repro.rules.boost.GradientBoostedSurrogate`, regression
    trees on the same features — the nonlinear upgrade for spaces
    where makespan depends on feature interactions).
  * :class:`SurrogateGuided` — generates a candidate pool (uniform
    rollouts + elite prefix mutations through ``eligible_items``),
    scores the pool with the surrogate (``surrogate="ridge"|"boost"``
    or any protocol object), and proposes only the argmin top-k. Every
    screened→simulated pair is logged, so screening quality (Spearman
    rank correlation, relative error) is reportable.
  * :class:`PortfolioSearch` — greedy seeding → MCTS refinement →
    surrogate-guided exploitation behind the plain strategy protocol,
    the ROADMAP recipe for the at-scale spaces.
"""
from __future__ import annotations

import random

import numpy as np

from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.core.features import Feature
from repro.driver.acquisitions import resolve_acquisition
from repro.rules.boost import GradientBoostedSurrogate, OnlineSurrogateBase
from repro.search.mcts import MCTSSearch
from repro.search.strategy import GreedyCostModel
from repro.space.base import DesignSpace, as_space


# -- rank statistics ---------------------------------------------------------

def _average_ranks(x: np.ndarray) -> np.ndarray:
    """Ranks with ties sharing their average rank (Spearman convention)."""
    x = np.asarray(x, dtype=np.float64)
    uniq, inv, counts = np.unique(x, return_inverse=True,
                                  return_counts=True)
    ends = np.cumsum(counts)
    starts = ends - counts
    return (0.5 * (starts + ends - 1))[inv]


def spearman(a, b) -> float:
    """Spearman rank correlation; 0.0 on degenerate (constant) input."""
    a = np.asarray(a, dtype=np.float64)
    b = np.asarray(b, dtype=np.float64)
    if a.size != b.size:
        raise ValueError(f"length mismatch {a.size} != {b.size}")
    if a.size < 2:
        return 0.0
    ra = _average_ranks(a) - (a.size - 1) / 2.0
    rb = _average_ranks(b) - (b.size - 1) / 2.0
    denom = float(np.sqrt((ra * ra).sum() * (rb * rb).sum()))
    if denom == 0.0:
        return 0.0
    return float((ra * rb).sum() / denom)


# -- the surrogate models ----------------------------------------------------

class RidgeSurrogate(OnlineSurrogateBase):
    """Online ridge regression over order/stream feature vectors.

    Corpus bookkeeping and lazy geometric-backoff refits come from
    :class:`~repro.rules.boost.OnlineSurrogateBase`; the fit solves
    the regularized normal equations on the constant-pruned feature
    matrix — in the dual (n×n) form when there are more features than
    observations, so wide spaces like ``halo3d_dag`` stay cheap. With
    no (or degenerate) data it predicts the observed mean.
    """

    def __init__(self, graph: "Graph | DesignSpace", l2: float = 1e-3,
                 refit_every: int = 8):
        super().__init__(graph, refit_every=refit_every)
        self.l2 = l2
        self._features: list[Feature] = []
        self._w: np.ndarray | None = None
        self._x_mean: np.ndarray | None = None
        self._y_mean = 0.0

    def _fit(self) -> None:
        self._fitted_n = len(self._times)
        y = np.asarray(self._times, dtype=np.float64)
        self._y_mean = float(y.mean()) if y.size else 0.0
        self._features, self._w, self._x_mean = [], None, None
        if y.size < 2:
            return
        fm = self.basis.matrix()
        if not fm.features:
            return  # all observations identical: mean is the best guess
        X = fm.X.astype(np.float64)
        self._features = fm.features
        self._x_mean = X.mean(axis=0)
        Xc = X - self._x_mean
        yc = y - self._y_mean
        n, d = X.shape
        lam = self.l2 * n
        if d <= n:
            self._w = np.linalg.solve(
                Xc.T @ Xc + lam * np.eye(d), Xc.T @ yc)
        else:  # dual form: identical w, but an n×n solve
            alpha = np.linalg.solve(Xc @ Xc.T + lam * np.eye(n), yc)
            self._w = Xc.T @ alpha

    def predict(self, schedules: list[Schedule]) -> np.ndarray:
        """Predicted times, one per schedule (refits if stale)."""
        if self._stale():
            self._fit()
        if self._w is None:
            return np.full(len(schedules), self._y_mean, dtype=np.float64)
        X = self.space.apply_features(schedules, self._features) \
            .astype(np.float64)
        return self._y_mean + (X - self._x_mean) @ self._w


# -- the surrogate registry --------------------------------------------------

SURROGATES: dict[str, type] = {}
"""Registry of surrogate factories: name -> ``cls(graph, **kwargs)``."""


def register_surrogate(name: str, factory: type) -> None:
    """Add a surrogate model to the :data:`SURROGATES` registry.

    Factories are called as ``factory(graph, **kwargs)`` and must
    return an object with the online-surrogate protocol:
    ``observe(schedule, time)``, ``predict(schedules) -> np.ndarray``,
    and ``n_observations``.
    """
    SURROGATES[name] = factory


register_surrogate("ridge", RidgeSurrogate)
register_surrogate("boost", GradientBoostedSurrogate)


def make_surrogate(graph: "Graph | DesignSpace",
                   surrogate: str = "ridge", **kwargs):
    """Construct a surrogate model by registry name."""
    try:
        factory = SURROGATES[surrogate]
    except KeyError:
        raise ValueError(
            f"unknown surrogate {surrogate!r}; registered: "
            f"{sorted(SURROGATES)}") from None
    return factory(graph, **kwargs)


# -- the two-stage strategy --------------------------------------------------

class SurrogateGuided:
    """Propose argmin-of-surrogate candidates from a screened pool.

    Each ``propose(k)`` builds a pool of ``pool_factor * k`` candidate
    schedules — uniform random rollouts mixed with *elite mutations*
    (truncate one of the best observed schedules at a random point and
    recomplete it randomly through ``eligible_items``, so every
    candidate is canonical by construction) — scores the pool with the
    ridge surrogate, and returns the ``k`` candidates with the lowest
    predicted time. Already-simulated schedules are excluded from the
    pool, so the downstream evaluator spends its simulations on new
    implementations.

    Until ``warmup`` observations have arrived the strategy proposes
    uniform rollouts (there is nothing to fit yet). Every prediction
    that reaches simulation is logged in ``screen_log`` as
    (predicted, simulated); :meth:`screening_quality` summarizes it.

    ``surrogate`` selects the screening model: a :data:`SURROGATES`
    registry name (``"ridge"`` default, ``"boost"`` for the
    gradient-boosted trees) with ``surrogate_kwargs`` forwarded to its
    factory, or a pre-built object implementing the protocol. The
    legacy ``refit_every`` argument forwards to any named surrogate
    (both built-ins share it via ``OnlineSurrogateBase``); ``l2`` is
    ridge-only and raises if combined with another name — never
    silently dropped.

    ``acquisition`` selects how the pool is ranked: a
    :data:`repro.driver.ACQUISITIONS` registry name (``"argmin_topk"``
    default — rank purely by predicted time, the original behavior;
    ``"ucb"`` / ``"expected_improvement"`` add the surrogate's
    predictive uncertainty, which needs a model with
    ``predict_with_std`` such as ``"boost"``) with
    ``acquisition_kwargs`` forwarded to its factory, or a pre-built
    ``acq(surrogate, pool, best=) -> (scores, mu)`` callable. The
    strategy implements the
    :class:`~repro.search.strategy.PoolSearchStrategy` protocol
    (``propose_pool`` / ``screen`` / ``pad``), so
    :class:`repro.driver.SearchDriver` can also override the
    acquisition per run without touching strategy state.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 n_streams: int | None = None, seed: int = 0,
                 warmup: int = 32, pool_factor: int = 10,
                 elite_frac: float = 0.25, mutation_prob: float = 0.5,
                 l2: float | None = None, refit_every: int | None = None,
                 surrogate="ridge", surrogate_kwargs: dict | None = None,
                 acquisition="argmin_topk",
                 acquisition_kwargs: dict | None = None):
        if pool_factor < 1:
            raise ValueError("pool_factor must be >= 1")
        self.space = as_space(graph, n_streams)
        self.graph = getattr(self.space, "graph", None)
        self.n_streams = getattr(self.space, "n_streams", None)
        self.rng = random.Random(seed)
        self.warmup = warmup
        self.pool_factor = pool_factor
        self.elite_frac = elite_frac
        self.mutation_prob = mutation_prob
        if isinstance(surrogate, str):
            kwargs = dict(surrogate_kwargs or {})
            if l2 is not None:
                if surrogate != "ridge":
                    raise ValueError(
                        "l2 only applies to the ridge surrogate; use "
                        "surrogate_kwargs for model-specific options")
                kwargs.setdefault("l2", l2)
            if refit_every is not None:
                kwargs.setdefault("refit_every", refit_every)
            self.surrogate = make_surrogate(self.space, surrogate,
                                            **kwargs)
        else:
            if (surrogate_kwargs is not None or l2 is not None
                    or refit_every is not None):
                raise ValueError(
                    "surrogate_kwargs/l2/refit_every only apply when "
                    "surrogate is a registry name, not a pre-built "
                    "object")
            self.surrogate = surrogate
        self.acquisition = resolve_acquisition(acquisition,
                                               acquisition_kwargs)
        self._observed: dict[tuple, float] = {}     # canonical key -> time
        self._elites: list[tuple[float, Schedule]] = []
        self._pending: dict[tuple, float] = {}      # key -> predicted time
        self.n_screened = 0                         # surrogate-scored pool
        self.screen_log: list[tuple[float, float]] = []  # (pred, actual)

    # -- candidate generation ------------------------------------------
    def _mutate(self, elite: Schedule) -> Schedule:
        return self.space.mutate(elite, self.rng)

    def _candidate(self) -> Schedule:
        if self._elites and self.rng.random() < self.mutation_prob:
            _, elite = self.rng.choice(self._elites)
            return self._mutate(elite)
        return self.space.random_candidate(self.rng)

    def _pool(self, size: int) -> list[Schedule]:
        """Up to ``size`` novel candidates (deduped, not yet simulated)."""
        pool: list[Schedule] = []
        keys: set[tuple] = set()
        for _ in range(4 * size):
            if len(pool) >= size:
                break
            s = self._candidate()
            key = self.space.candidate_key(s)
            if key in keys or key in self._observed:
                continue
            keys.add(key)
            pool.append(s)
        return pool

    # -- pool protocol (PoolSearchStrategy) ----------------------------
    def propose_pool(self, budget: int) -> list[Schedule] | None:
        """The raw candidate pool one ``propose(budget)`` would screen.

        ``None`` while the surrogate is still warming up (nothing to
        fit — ``propose`` falls back to uniform rollouts), else up to
        ``pool_factor * budget`` novel candidates.
        """
        if budget <= 0 or self.surrogate.n_observations < self.warmup:
            return None
        return self._pool(self.pool_factor * budget)

    def best_observed(self) -> float | None:
        """Best simulated time seen so far (the EI incumbent)."""
        return self._elites[0][0] if self._elites else None

    def screen(self, pool: list[Schedule], budget: int,
               acquisition) -> list[Schedule]:
        """Rank ``pool`` with ``acquisition`` and keep the best ``budget``.

        Pools no larger than ``budget`` pass through unranked (space
        nearly exhausted: nothing to screen). Every chosen candidate's
        *predicted time* — the acquisition's ``mu``, never its score —
        is parked in the pending log so ``screening_quality()``
        compares predictions against simulation regardless of which
        acquisition ranked the pool.
        """
        if len(pool) <= budget:
            return list(pool)
        scores, preds = acquisition(self.surrogate, pool,
                                    best=self.best_observed())
        self.n_screened += len(pool)
        top = np.argsort(scores, kind="stable")[:budget]
        chosen = [pool[i] for i in top]
        for i in top:
            self._pending[self.space.candidate_key(pool[i])] = \
                float(preds[i])
        return chosen

    def pad(self, chosen: list[Schedule],
            budget: int) -> list[Schedule]:
        """Fill with uniform rollouts — never starve the search loop."""
        while len(chosen) < budget:
            chosen.append(self.space.random_candidate(self.rng))
        return chosen

    # -- strategy protocol ---------------------------------------------
    def propose(self, budget: int) -> list[Schedule]:
        if budget <= 0:
            return []
        pool = self.propose_pool(budget)
        if pool is None:  # warmup: nothing to fit yet
            return [self.space.random_candidate(self.rng)
                    for _ in range(budget)]
        return self.pad(self.screen(pool, budget, self.acquisition),
                        budget)

    def observe(self, schedule: Schedule, time: float) -> None:
        key = self.space.candidate_key(schedule)
        pred = self._pending.pop(key, None)
        if pred is not None:
            self.screen_log.append((pred, float(time)))
        if key in self._observed:
            # Re-proposed duplicate: the memoized evaluator returned the
            # same makespan, so training on it again only grows the
            # basis/refit cost without adding information.
            return
        self._observed[key] = float(time)
        self._elites.append((float(time), schedule))
        self._elites.sort(key=lambda e: e[0])
        n_elite = max(1, min(32, int(self.elite_frac
                                     * len(self._observed))))
        del self._elites[n_elite:]
        self.surrogate.observe(schedule, time)

    # -- reporting ------------------------------------------------------
    def screening_quality(self) -> dict:
        """Surrogate-vs-simulated accuracy over everything screened."""
        if not self.screen_log:
            return {"n_screened": self.n_screened, "n_compared": 0,
                    "spearman": 0.0, "mean_rel_err": float("nan")}
        pred, actual = map(np.asarray, zip(*self.screen_log))
        rel = np.abs(pred - actual) / np.maximum(actual, 1e-30)
        return {"n_screened": self.n_screened,
                "n_compared": len(self.screen_log),
                "spearman": spearman(pred, actual),
                "mean_rel_err": float(rel.mean())}


# -- the portfolio -----------------------------------------------------------

class PortfolioSearch:
    """Greedy seeding → MCTS refinement → surrogate exploitation.

    One strategy-protocol object that spends its proposal stream in
    three phases: ``seed_proposals`` epsilon-greedy constructions (fast
    good anchors for the surrogate), ``mcts_proposals`` of the paper's
    coverage-guided MCTS (diverse structure), then surrogate-guided
    two-stage exploitation for the rest of the budget. Every
    observation — whatever phase proposed it — feeds both the MCTS tree
    (via path materialization) and the surrogate's training set, so the
    exploitation phase starts from everything the earlier phases
    learned. ``**surrogate_kwargs`` reaches :class:`SurrogateGuided`,
    so ``PortfolioSearch(..., surrogate="boost")`` exploits with the
    gradient-boosted tree model (and ``acquisition="ucb"`` screens
    with it). The portfolio also speaks the
    :class:`~repro.search.strategy.PoolSearchStrategy` protocol by
    delegating to its exploitation phase, so a driver-level
    acquisition override reaches the surrogate phase too.

    Budget accounting caveat: the greedy phase scores candidate
    extensions with *prefix* simulations of its own
    (``GreedyCostModel.n_prefix_sims``), which the evaluator's
    ``sim_budget`` meter does not see. For strict equal-simulation
    comparisons (benchmarks/at_scale.py, the regression test), pass
    ``seed_proposals=0``. The greedy phase also simulates under
    ``machine`` — when the evaluator runs a non-default machine, pass
    the same one here or the seeds will optimize the wrong objective.
    """

    def __init__(self, graph: Graph, n_streams: int,
                 machine: Machine | None = None, seed: int = 0,
                 seed_proposals: int = 16, mcts_proposals: int = 128,
                 **surrogate_kwargs):
        self.greedy = GreedyCostModel(graph, n_streams, machine=machine,
                                      seed=seed)
        self.mcts = MCTSSearch(graph, n_streams, seed=seed)
        self.surrogate = SurrogateGuided(graph, n_streams, seed=seed,
                                         **surrogate_kwargs)
        self.seed_proposals = seed_proposals
        self.mcts_proposals = mcts_proposals
        self._n = 0

    def propose(self, budget: int) -> list[Schedule]:
        b1 = self.seed_proposals
        b2 = self.seed_proposals + self.mcts_proposals
        while True:
            if self._n < b1:
                batch = self.greedy.propose(min(budget, b1 - self._n))
                if not batch:
                    self._n = b1
                    continue
            elif self._n < b2:
                batch = self.mcts.propose(min(budget, b2 - self._n))
                if not batch:  # tiny space fully explored by MCTS
                    self._n = b2
                    continue
            else:
                batch = self.surrogate.propose(budget)
            self._n += len(batch)
            return batch

    def observe(self, schedule: Schedule, time: float) -> None:
        self.mcts.observe(schedule, time)
        self.surrogate.observe(schedule, time)

    # -- pool protocol: delegate to the exploitation phase -------------
    def propose_pool(self, budget: int) -> list[Schedule] | None:
        """``None`` through the greedy/MCTS phases (those proposals are
        never screened), then the surrogate phase's raw pool — so an
        acquisition-overriding :class:`repro.driver.SearchDriver`
        screens exactly the proposals the built-in acquisition would
        have. Phase progress is tracked by ``propose``, which the
        driver still calls whenever this returns ``None``."""
        if self._n < self.seed_proposals + self.mcts_proposals:
            return None
        return self.surrogate.propose_pool(budget)

    def screen(self, pool: list[Schedule], budget: int,
               acquisition) -> list[Schedule]:
        return self.surrogate.screen(pool, budget, acquisition)

    def pad(self, chosen: list[Schedule],
            budget: int) -> list[Schedule]:
        return self.surrogate.pad(chosen, budget)

    def screening_quality(self) -> dict:
        return self.surrogate.screening_quality()
