"""Step-granular checkpointing: sharded-layout-agnostic, async, atomic.

Layout:  <dir>/step-<N>/arrays.npz + meta.json, plus <dir>/LATEST
written last (atomic rename), so a crash mid-write never corrupts the
restore path. Arrays are saved in *logical* (unsharded) form; restore
re-places them under any mesh (this is what makes elastic re-meshing
trivial — see repro.ft.elastic). The async writer runs on a thread;
``wait()`` joins before the next save or shutdown.

On a real multi-host pod each host saves its addressable shards under
``shard-<k>``; the single-process container exercises the same code path
with one shard.
"""
from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(tree_like, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = flat[key]
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                      else arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointStore:
    def __init__(self, directory: str | os.PathLike,
                 keep_last: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self._q: queue.Queue = queue.Queue()
        self._worker: threading.Thread | None = None

    # -- write ------------------------------------------------------------
    def save(self, step: int, state: dict, blocking: bool = True,
             extra_meta: dict | None = None) -> None:
        flat = _flatten(state)
        meta = {"step": int(step), **(extra_meta or {})}
        if blocking:
            self._write(step, flat, meta)
        else:
            self.wait()
            self._worker = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, flat: dict, meta: dict) -> None:
        tmp = self.dir / f".tmp-step-{step}"
        final = self.dir / f"step-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "meta.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        latest_tmp = self.dir / ".LATEST.tmp"
        latest_tmp.write_text(str(step))
        latest_tmp.rename(self.dir / "LATEST")  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # -- read -------------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("-", 1)[1])
                      for p in self.dir.glob("step-*"))

    def latest_step(self) -> int | None:
        marker = self.dir / "LATEST"
        if marker.exists():
            s = int(marker.read_text())
            if (self.dir / f"step-{s}").exists():
                return s
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None):
        """Returns (step, state) re-shaped like ``tree_like``."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        with np.load(self.dir / f"step-{step}" / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        return step, _unflatten(tree_like, flat)

    def meta(self, step: int) -> dict:
        return json.loads(
            (self.dir / f"step-{step}" / "meta.json").read_text())
