"""Pluggable evaluation engine: one contract, five backends.

Evaluation is the bottleneck resource of the whole pipeline — MCTS and
the surrogate portfolio explore thousands of schedules, and every
makespan historically came from one serial Python discrete-event loop.
This package makes evaluation the fast, swappable part: every backend
subclasses :class:`~repro.engine.base.EvaluatorBase` (shared memo
cache, hit/miss budget meters, order-independent noise) and is selected
by name through :func:`make_evaluator`::

    ev = make_evaluator(graph, backend="vectorized")
    run_search(graph, strategy, backend="pool",
               backend_kwargs={"n_workers": 4})

Backends (see README.md in this package for the full matrix):

  ``sim``         the serial reference: one discrete-event simulation
                  per canonical-unique schedule.
  ``vectorized``  numpy batch simulator — bit-identical to ``sim``,
                  evaluates a whole miss batch with array ops.
  ``pool``        ``sim``'s math sharded over a process pool; cache and
                  accounting stay in the parent, results byte-identical.
  ``wallclock``   real measurements (median-of-k + value-correctness
                  gate): the jitted token-chain executor for schedule
                  spaces, the kernel-runner sweep for parameter spaces
                  (:func:`make_evaluator` dispatches on the space).
  ``rpc``         evaluation as a service: miss batches sharded over a
                  fleet of :mod:`repro.engine.server` hosts with
                  pipelined dispatch, retry/hedging fault tolerance,
                  and local fallback — byte-identical to ``sim``.

Every backend accepts a :class:`~repro.core.dag.Graph` (wrapped into
the paper's schedule space) or any
:class:`~repro.space.base.DesignSpace` as its first argument; the
analytic backends need a space with an analytic cost model.
"""
from __future__ import annotations

from repro.core.costmodel import Machine
from repro.core.dag import Graph
from repro.engine.base import (BatchEvaluator, EvalBatch, EvaluatorBase,
                               canonical_key)
from repro.engine.params import KernelWallclockEvaluator
from repro.engine.pool import PoolEvaluator
from repro.engine.rpc import (RpcError, RpcEvaluator, RpcHandshakeError,
                              RpcProtocolError)
from repro.engine.store import EvalStore, store_fingerprint
from repro.engine.vectorized import (GraphTables, VectorizedEvaluator,
                                     simulate_batch, simulate_encoded)
from repro.engine.wallclock import (ExecutorEvaluator,
                                    assert_outputs_close,
                                    demo_spmv_impls, reference_schedule)
from repro.space.params import ParamSpace

BACKENDS: dict[str, type[EvaluatorBase]] = {
    "sim": BatchEvaluator,
    "vectorized": VectorizedEvaluator,
    "pool": PoolEvaluator,
    "wallclock": ExecutorEvaluator,
    "rpc": RpcEvaluator,
}


def __getattr__(name: str):
    # The server module is imported lazily so that
    # ``python -m repro.engine.server`` does not trip runpy's
    # already-in-sys.modules warning (and a bare ``import repro.engine``
    # never pays for the subprocess/CLI machinery).
    if name in ("EvalServer", "ServerProcess", "spawn_server_process"):
        from repro.engine import server

        return getattr(server, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def register_backend(name: str, cls: type[EvaluatorBase]) -> None:
    """Add (or replace) an evaluation backend under ``name``."""
    if not (isinstance(cls, type) and issubclass(cls, EvaluatorBase)):
        raise TypeError(f"{cls!r} is not an EvaluatorBase subclass")
    BACKENDS[name] = cls


def make_evaluator(graph: Graph, backend: str = "sim", *,
                   machine: Machine | None = None,
                   **kwargs) -> EvaluatorBase:
    """Construct the named evaluation backend for ``graph``.

    ``kwargs`` are backend-specific (``n_workers`` for ``pool``;
    ``impls``/``env``/``repeats`` for ``wallclock``; ``hosts`` for
    ``rpc``) plus the shared
    base-layer knobs everywhere: ``noise_sigma`` / ``noise_seed`` and
    the persistent cross-run store (``store=`` a shared
    :class:`~repro.engine.store.EvalStore`, or ``store_path=`` a file
    the evaluator opens and owns; see engine/README.md).
    """
    try:
        cls = BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown evaluation backend {backend!r}; available: "
            f"{sorted(BACKENDS)}") from None
    if backend == "wallclock" and isinstance(graph, ParamSpace):
        # Parameter spaces measure through their KernelRunner, not the
        # schedule executor; same registry name, same search-visible
        # contract.
        cls = KernelWallclockEvaluator
    return cls(graph, machine=machine, **kwargs)


__all__ = [
    "BACKENDS", "make_evaluator", "register_backend",
    "EvaluatorBase", "BatchEvaluator", "EvalBatch", "canonical_key",
    "VectorizedEvaluator", "GraphTables", "simulate_batch",
    "simulate_encoded",
    "PoolEvaluator",
    "RpcEvaluator", "RpcError", "RpcHandshakeError", "RpcProtocolError",
    "EvalServer", "ServerProcess", "spawn_server_process",
    "EvalStore", "store_fingerprint",
    "ExecutorEvaluator", "KernelWallclockEvaluator",
    "assert_outputs_close", "demo_spmv_impls", "reference_schedule",
    "Machine",
]
