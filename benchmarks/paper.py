"""Benchmarks reproducing each paper table/figure on our SpMV space.

Every search below — exhaustive, MCTS, noisy MCTS — runs through the
unified ``repro.search.run_search`` pipeline, and every labels -> tree
-> rules pass runs through ``repro.rules.distill`` (one code path with
the examples and the smoke test). Each function returns rows as CSV
lines ``name,us_per_call,derived``.
"""
from __future__ import annotations

import pathlib
import time

import numpy as np

import repro.core as C
import repro.rules as R
import repro.search as S

_RULES_MD = pathlib.Path(__file__).resolve().parents[1] \
    / "experiments" / "rules_canonical.md"


def _space(n_streams: int = 2):
    """Exhaustive SpMV design space via the unified search pipeline."""
    g = C.spmv_dag()
    res = S.run_search(g, S.ExhaustiveSearch(g, n_streams), budget=None,
                       batch_size=64)
    return res


def _mcts(g, iters: int, seed: int, noise_sigma: float = 0.0):
    """MCTS through the same pipeline (batch_size=1: the paper's loop)."""
    evaluator = S.BatchEvaluator(g, noise_sigma=noise_sigma,
                                 noise_seed=7)
    return S.run_search(g, S.MCTSSearch(g, 2, seed=seed), budget=iters,
                        evaluator=evaluator)


def fig1_spread() -> list[str]:
    """Fig. 1: sorted exhaustive-search times; fastest vs slowest."""
    t0 = time.perf_counter()
    res = _space()
    wall = (time.perf_counter() - t0) / max(1, len(res.schedules)) * 1e6
    s = np.sort(res.times_array())
    rows = [
        f"fig1_n_implementations,{wall:.2f},{len(res.schedules)}",
        f"fig1_speedup_spread,{wall:.2f},{s[-1] / s[0]:.3f}",
        f"fig1_fastest_us,{wall:.2f},{s[0] * 1e6:.2f}",
        f"fig1_slowest_us,{wall:.2f},{s[-1] * 1e6:.2f}",
    ]
    return rows


def fig4_labels() -> list[str]:
    """Fig. 4: convolution + peak detection class labeling (via the
    distillation pipeline; the row wall is the labeling stage only, so
    the us_per_call trajectory stays comparable across BENCH_N files)."""
    res = _space()
    rep = R.distill(res)
    wall = rep.stage_seconds["label"] * 1e6
    lab = rep.labeling
    sizes = np.bincount(lab.labels)
    return [
        f"fig4_n_classes,{wall:.2f},{lab.n_classes}",
        f"fig4_class_sizes,{wall:.2f},{'/'.join(map(str, sizes))}",
        f"fig4_boundaries,{wall:.2f},"
        f"{'/'.join(map(str, lab.boundaries.tolist()))}",
    ]


def fig5_tree() -> list[str]:
    """Fig. 5: Algorithm 1 hyperparameter search trace (row wall: the
    tree stage only, comparable with earlier BENCH_N files)."""
    res = _space()
    rep = R.distill(res)
    wall = rep.stage_seconds["tree"] * 1e6
    s = rep.summary()
    return [
        f"fig5_final_leaves,{wall:.2f},{s['n_leaves']}",
        f"fig5_final_depth,{wall:.2f},{s['tree_depth']}",
        f"fig5_final_error,{wall:.2f},{s['training_error']:.4f}",
        f"fig5_trials,{wall:.2f},{s['algorithm1_trials']}",
    ]


def table5_accuracy() -> list[str]:
    """Table V: MCTS iterations vs class-range accuracy on the full
    space (paper: 0.75/0.83/0.96/0.99/1.0 at 50/100/200/400/2036)."""
    res_full = _space()
    g = res_full.graph
    full = (res_full.schedules, res_full.times_array())
    rows = []
    for iters in (25, 50, 100, 200, 1200):
        t0 = time.perf_counter()
        res = _mcts(g, iters, seed=1)
        rep = R.distill(res, full_space=full)
        wall = (time.perf_counter() - t0) / iters * 1e6
        rows.append(f"table5_acc_iters{iters},{wall:.2f},"
                    f"{rep.class_range_acc:.3f}")
    return rows


def tables678_rules(rules_path: "str | pathlib.Path" = _RULES_MD
                    ) -> list[str]:
    """Tables VI-VIII: rulesets per class for reduced MCTS budgets,
    annotated against the canonical (exhaustive-search) rules.

    The canonical report is rendered to ``rules_path`` — an explicit
    argument (default: experiments/rules_canonical.md), not a hidden
    side effect.
    """
    res_full = _space()
    g = res_full.graph
    canon = R.distill(res_full)
    rows = []
    for iters in (50, 100, 200):
        t0 = time.perf_counter()
        res = _mcts(g, iters, seed=2)
        rep = R.distill(res, canonical=canon)
        s = rep.summary()
        wall = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"tables678_iters{iters},{wall:.2f},"
            f"rulesets={s['n_rulesets']}/over={s['n_overconstrained']}"
            f"/under={s['n_underconstrained']}")
    canon.write(rules_path)
    return rows


def stepdag_overlap() -> list[str]:
    """Beyond-paper: the technique applied to our own train step
    (collective-overlap schedule search, TPU machine model)."""
    from repro.core.stepdag import StepCosts, train_step_dag, \
        with_comm_durations
    costs = StepCosts(fwd_flops=2e12, bwd_flops=4e12, fwd_bytes=1e9,
                      bwd_bytes=2e9, grad_bytes=2e9)
    g = with_comm_durations(train_step_dag(4, costs), 50e9)
    t0 = time.perf_counter()
    res = S.run_search(g, S.MCTSSearch(g, 2, seed=0), budget=400)
    wall = (time.perf_counter() - t0) / 400 * 1e6
    best = min(res.times)
    worst = max(res.times)
    return [
        f"stepdag_best_ms,{wall:.2f},{best * 1e3:.3f}",
        f"stepdag_worst_ms,{wall:.2f},{worst * 1e3:.3f}",
        f"stepdag_speedup,{wall:.2f},{worst / best:.3f}",
    ]


def granularity_ablation() -> list[str]:
    """Beyond-paper: the paper's §III-A granularity trade-off, measured.

    Fine-grained per-neighbor Pack/Send/Recv vertices remove false
    dependencies but (a) explode the space (>5e5 vs 280) and (b) add
    per-op launch/host overhead that outweighs the overlap they enable
    at these message sizes. The fine space is searched with the
    greedy→MCTS→surrogate portfolio (the at-scale recipe; plain MCTS
    vs portfolio is raced head-to-head in benchmarks/at_scale.py)."""
    from repro.core.dag import spmv_dag_fine
    g_fine = spmv_dag_fine()
    t0 = time.perf_counter()
    res = S.run_search(g_fine, S.PortfolioSearch(g_fine, 2, seed=0),
                       budget=2000)
    wall = (time.perf_counter() - t0) / 2000 * 1e6
    tf = res.times_array()
    tc = _space().times_array()
    return [
        f"granularity_fine_best_us,{wall:.2f},{tf.min() * 1e6:.2f}",
        f"granularity_coarse_best_us,{wall:.2f},{tc.min() * 1e6:.2f}",
        f"granularity_fine_spread,{wall:.2f},{tf.max() / tf.min():.3f}",
        f"granularity_overhead_ratio,{wall:.2f},"
        f"{tf.min() / tc.min():.3f}",
    ]


def noise_robustness() -> list[str]:
    """Beyond-paper: labeling robustness under measurement noise (the
    paper's empirical times are noisy; our machine model lets us dose
    noise explicitly). Reports Table-V-style accuracy at 200 MCTS
    iterations under multiplicative Gaussian noise, widening the class
    ranges by the noise level (``distill(range_widen=3*sigma)``)."""
    res_full = _space()
    g = res_full.graph
    full = (res_full.schedules, res_full.times_array())
    rows = []
    for sigma in (0.0, 0.01, 0.05):
        t0 = time.perf_counter()
        res = _mcts(g, 200, seed=3, noise_sigma=sigma)
        rep = R.distill(res, full_space=full, range_widen=3 * sigma)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append(
            f"noise_acc_sigma{sigma},{wall:.2f},"
            f"{rep.class_range_acc:.3f}/classes="
            f"{rep.labeling.n_classes}")
    return rows
