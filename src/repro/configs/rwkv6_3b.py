"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay
[arXiv:2404.05892]. Channel-mix uses squared ReLU (RWKV convention)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, mlp="relu2", rwkv_head_dim=64,
)

REDUCED = ModelConfig(
    name="rwkv6-3b-reduced", family="ssm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, mlp="relu2", rwkv_head_dim=16,
)
