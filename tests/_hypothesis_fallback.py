"""Seeded-random stand-in for ``hypothesis`` (tier-1 has no such dep).

The tier-1 container guarantees only numpy/jax/pytest; property tests
import hypothesis when it exists and fall back to this module otherwise:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_fallback import given, settings, strategies as st

The shim covers exactly the subset this repo's tests use — ``given``
over positional strategies, ``settings(max_examples=..., deadline=...)``,
and the ``floats`` / ``integers`` / ``lists`` / ``sampled_from``
strategies. Examples are drawn from ``random.Random`` seeded per example
index, so failures reproduce exactly across runs and machines (no
shrinking, no database — deterministic generation is the point).
"""
from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace
from typing import Any, Callable

_DEFAULT_MAX_EXAMPLES = 20
_SEED_STRIDE = 7919  # prime stride decorrelates per-example streams


class _Strategy:
    """A draw function rng -> value."""

    def __init__(self, draw: Callable[[random.Random], Any]):
        self.draw = draw


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return _Strategy(draw)


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES,
             **_ignored) -> Callable:
    """Record the example budget on the (already ``given``-wrapped)
    test function; other hypothesis knobs (deadline, ...) are ignored."""

    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*strategies: _Strategy) -> Callable:
    """Run the test once per generated example, appending drawn values
    after any pytest-provided arguments (fixtures)."""

    def deco(fn: Callable) -> Callable:
        # The strategies bind to the trailing parameters (hypothesis
        # semantics for positional ``given``); anything before them is a
        # pytest fixture, which pytest passes by keyword.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        split = len(params) - len(strategies)
        drawn_names = [p.name for p in params[split:]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        _DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(17 + _SEED_STRIDE * i)
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strategies)}
                fn(*args, **kwargs, **drawn)

        # Hide the strategy-bound parameters from pytest so it doesn't
        # look for fixtures with those names.
        wrapper.__signature__ = sig.replace(parameters=params[:split])
        del wrapper.__wrapped__  # keep pytest off the original signature
        return wrapper

    return deco


strategies = SimpleNamespace(floats=floats, integers=integers,
                             lists=lists, sampled_from=sampled_from)
