"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q: (B, H, Sq, D); k, v: (B, H, Skv, D). Softmax in f32."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        # Aligned on the right: query i attends keys <= i + (Skv - Sq).
        qi = jnp.arange(sq)[:, None] + (skv - sq)
        ki = jnp.arange(skv)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
