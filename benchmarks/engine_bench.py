"""Evaluation-engine race: serial vs vectorized vs process pool, plus
the persistent-store warm-vs-cold pair.

The evaluator is the search pipeline's bottleneck resource; this bench
measures exactly what ``run_search`` buys from each backend — time to
evaluate the same 2000+ canonical-unique halo3d schedules through the
full evaluator contract (canonical keys, memo cache, accounting), plus
the exhaustive paper-SpMV space as a bit-identity checksum. Analytic
backends must agree float-for-float; the rows report the per-backend
throughput and the speedup over the serial reference.

The ``engine_store_{cold,warm}`` rows measure the cross-run cache
(:mod:`repro.engine.store`): the same traffic through a fresh
evaluator, first against an empty store file (cold: every schedule
simulated + written through) and then against the warmed file (warm:
every schedule replayed from disk, zero simulations) — the CI/sweep
warm-start speedup, with the identity verdict in the derived column.

The ``engine_rpc_{cold,warm,hedged}`` rows race evaluation as a
service (:mod:`repro.engine.rpc`): the same traffic sharded over a
freshly spawned two-host localhost fleet of ``repro.engine.server``
subprocesses hosting the vectorized backend (cold — must beat serial,
and be float-identical to it), replayed from the client's store with
zero dispatches (warm), and dispatched against a fleet containing a
deliberate straggler host so the hedging path is what the row times
(hedged).
"""
from __future__ import annotations

import os
import random
import tempfile
import time

import repro.engine as E
from repro.core.dag import halo3d_dag, spmv_dag
from repro.core.enumerate import enumerate_schedules
from repro.engine.base import canonical_key
from repro.search.strategy import random_schedule

N_SCHEDULES = 2000


def _unique_schedules(graph, n, n_streams=2, seed=0):
    rng = random.Random(seed)
    seen, out = set(), []
    while len(out) < n:
        s = random_schedule(graph, n_streams, rng)
        key = canonical_key(s)
        if key not in seen:
            seen.add(key)
            out.append(s)
    return out


def engine_benches(n_schedules: int = N_SCHEDULES) -> list[str]:
    rows = []

    # Bit-identity checksum over the whole coarse-SpMV space.
    g = spmv_dag()
    space = list(enumerate_schedules(g, 2))
    base = E.make_evaluator(g, "sim").evaluate(space)
    for backend in ("vectorized", "pool"):
        with E.make_evaluator(g, backend) as ev:
            agree = ev.evaluate(space) == base
        rows.append(f"engine_{backend}_spmv_exhaustive,0.00,"
                    f"identical_{len(space)}_of_{len(space)}"
                    if agree else
                    f"engine_{backend}_spmv_exhaustive,0.00,MISMATCH")

    # The race: same unique halo3d schedules through every backend.
    # A small disjoint warmup batch first-touches each evaluator (pool
    # worker startup, numpy buffer allocation) so the timed number is
    # steady-state throughput, not one-time setup. Reps are
    # *interleaved* across backends (each samples the same load
    # phases) and best-of-5 is reported: on shared machines background
    # noise only ever inflates a measurement, so per-backend minima
    # are the intrinsic-speed comparison.
    g = halo3d_dag()
    schedules = _unique_schedules(g, n_schedules + 16)
    warmup, schedules = schedules[:16], schedules[16:]
    backends = (("sim", {}), ("vectorized", {}),
                ("pool", {"n_workers": os.cpu_count()}))
    best: dict[str, float] = {b: float("inf") for b, _ in backends}
    results: dict[str, list[float]] = {}
    for _ in range(5):
        for backend, kwargs in backends:
            with E.make_evaluator(g, backend, **kwargs) as ev:
                ev.evaluate(warmup)
                t0 = time.perf_counter()
                out = ev.evaluate(schedules)
                best[backend] = min(best[backend],
                                    time.perf_counter() - t0)
            results[backend] = out

    for backend, _ in backends:
        us = best[backend] / len(schedules) * 1e6
        if backend == "sim":
            derived = f"{len(schedules)}_schedules"
        else:
            ident = "identical" if results[backend] == results["sim"] \
                else "MISMATCH"
            derived = f"{best['sim'] / best[backend]:.2f}" \
                      f"x_vs_serial_{ident}"
        rows.append(f"engine_{backend}_halo3d_{len(schedules)},"
                    f"{us:.2f},{derived}")
    rows.extend(store_benches(g, schedules))
    rows.extend(rpc_benches(g, schedules, warmup, best["sim"],
                            results["sim"]))
    return rows


def store_benches(graph, schedules) -> list[str]:
    """Warm-vs-cold rows for the persistent evaluation store."""
    rows = []
    n = len(schedules)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "bench.evalstore")
        best_cold = best_warm = float("inf")
        warm_out = None
        for rep in range(3):
            rep_path = f"{path}.{rep}"
            with E.make_evaluator(graph, "sim",
                                  store_path=rep_path) as ev:
                t0 = time.perf_counter()
                cold_out = ev.evaluate(schedules)
                best_cold = min(best_cold, time.perf_counter() - t0)
                assert ev.cache_misses == n
            with E.make_evaluator(graph, "sim",
                                  store_path=rep_path) as ev:
                t0 = time.perf_counter()
                warm_out = ev.evaluate(schedules)
                best_warm = min(best_warm, time.perf_counter() - t0)
                assert (ev.store_hits, ev.cache_misses) == (n, 0)
            size_kb = os.path.getsize(rep_path) / 1024
        ident = "identical" if warm_out == cold_out else "MISMATCH"
        rows.append(f"engine_store_cold_halo3d_{n},"
                    f"{best_cold / n * 1e6:.2f},"
                    f"store_{size_kb:.0f}KiB")
        rows.append(f"engine_store_warm_halo3d_{n},"
                    f"{best_warm / n * 1e6:.2f},"
                    f"{best_cold / best_warm:.2f}x_vs_cold_{ident}")
    return rows


def rpc_benches(graph, schedules, warmup, serial_s,
                serial_out) -> list[str]:
    """Cold / warm / hedged rows for the ``rpc`` evaluation service.

    Each cold rep spawns a *fresh* two-host localhost fleet (server
    memo caches persist across requests, so reusing a fleet would turn
    later reps into server-side cache replays) hosting the vectorized
    backend — the fleet's advertised use: the inner backend is the
    host's choice. The warmup batch first-touches the connections and
    the servers' numpy buffers so the timed number is steady-state
    dispatch throughput. The warm rep runs a fresh client against the
    store the cold rep wrote through: zero measurements, zero
    dispatches. The hedged rep adds a deliberate straggler host
    (``--delay``) so the row times the hedged re-dispatch path.
    """
    from repro.engine.server import spawn_server_process

    rows = []
    n = len(schedules)
    best_cold = best_warm = float("inf")
    cold_out = warm_out = None
    warm_misses = -1
    with tempfile.TemporaryDirectory() as tmp:
        for rep in range(2):
            path = os.path.join(tmp, f"rpc.{rep}.evalstore")
            servers = [spawn_server_process("halo3d",
                                            backend="vectorized")
                       for _ in range(2)]
            try:
                hosts = [s.addr for s in servers]
                with E.make_evaluator(graph, "rpc", hosts=hosts,
                                      store_path=path) as ev:
                    ev.evaluate(warmup)
                    t0 = time.perf_counter()
                    cold_out = ev.evaluate(schedules)
                    best_cold = min(best_cold,
                                    time.perf_counter() - t0)
                    assert ev.local_evals == 0
                with E.make_evaluator(graph, "rpc", hosts=hosts,
                                      store_path=path) as ev:
                    t0 = time.perf_counter()
                    warm_out = ev.evaluate(schedules)
                    best_warm = min(best_warm,
                                    time.perf_counter() - t0)
                    warm_misses = ev.cache_misses
            finally:
                for s in servers:
                    s.terminate()
    ident = "identical" if cold_out == serial_out else "MISMATCH"
    rows.append(f"engine_rpc_cold_halo3d_{n},"
                f"{best_cold / n * 1e6:.2f},"
                f"{serial_s / best_cold:.2f}x_vs_serial_{ident}")
    ident = "identical" if warm_out == cold_out else "MISMATCH"
    rows.append(f"engine_rpc_warm_halo3d_{n},"
                f"{best_warm / n * 1e6:.2f},"
                f"{best_cold / best_warm:.2f}x_vs_cold_{ident}_"
                f"{warm_misses}_measurements")

    best_hedged = float("inf")
    hedged_out = None
    hedges = 0
    servers = [spawn_server_process("halo3d", backend="vectorized"),
               spawn_server_process("halo3d", backend="vectorized",
                                    delay=0.05)]
    try:
        hosts = [s.addr for s in servers]
        with E.make_evaluator(graph, "rpc", hosts=hosts,
                              max_inflight=2) as ev:
            ev.evaluate(warmup)
            t0 = time.perf_counter()
            hedged_out = ev.evaluate(schedules)
            best_hedged = time.perf_counter() - t0
            hedges = sum(h["hedged"] for h in
                         ev.rpc_stats()["hosts"].values())
    finally:
        for s in servers:
            s.terminate()
    ident = "identical" if hedged_out == serial_out else "MISMATCH"
    rows.append(f"engine_rpc_hedged_halo3d_{n},"
                f"{best_hedged / n * 1e6:.2f},"
                f"{hedges}_hedges_{ident}")
    return rows
