"""Parameter design spaces for the repo's own Pallas kernels.

The ROADMAP's "autotune the repo's own stack" item: the block/tile
sizes hard-coded in :mod:`repro.kernels` become searchable
:class:`~repro.space.params.ParamSpace` instances, evaluated through
the param-space ``wallclock`` backend (:class:`repro.engine.params.
KernelWallclockEvaluator` — value-correctness gate against the
kernel's reference implementation, batch-ahead compilation, persistent
:class:`~repro.engine.store.EvalStore` warm starts) and distilled into
per-platform block-size design rules by :func:`repro.rules.distill`.

Each factory closes the kernel over one fixed, seeded problem instance
(the instance is part of the space — its shape/seed go into the
``signature`` hashed by the store fingerprint, so measurements from
different instances never alias). Shapes default small enough that the
interpret-mode (CPU) sweep stays in test budgets; pass bigger ones for
a real tuning run on TPU.

These constructors import JAX; :mod:`repro.space` registers them
lazily (``make_space("flash_attention")``) so the protocol layer stays
importable on JAX-free installs.
"""
from __future__ import annotations

import numpy as np

from repro.space.params import KernelRunner, ParamSpace

__all__ = ["flash_attention_space", "spmv_mulsum_space", "pack_space"]


def _divisors_of(seq: int, values) -> tuple[int, ...]:
    out = tuple(int(v) for v in values if seq % int(v) == 0)
    if not out:
        raise ValueError(
            f"no candidate block size in {tuple(values)} divides "
            f"sequence length {seq}")
    return out


def flash_attention_space(*, batch: int = 1, heads: int = 2,
                          seq: int = 128, head_dim: int = 64,
                          block_values=(16, 32, 64, 128),
                          causal: bool = True, seed: int = 0,
                          interpret: bool | None = None) -> ParamSpace:
    """(block_q, block_k) grid for :func:`repro.kernels.
    flash_attention.ops.mha` on one seeded self-attention instance.

    Block values are filtered to divisors of ``seq`` so the padded and
    unpadded paths measure the same problem (and causal right-aligned
    masking needs equal q/kv padding anyway).
    """
    import jax.numpy as jnp

    from repro.kernels.flash_attention.ops import mha
    from repro.kernels.flash_attention.ref import attention_ref

    blocks = _divisors_of(seq, block_values)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal(
        (batch, heads, seq, head_dim)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal(
        (batch, heads, seq, head_dim)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal(
        (batch, heads, seq, head_dim)).astype(np.float32))

    def build(params: dict):
        bq, bk = params["block_q"], params["block_k"]

        def run():
            return mha(q, k, v, causal=causal, block_q=bq,
                       block_k=bk, interpret=interpret)
        return run

    return ParamSpace(
        "flash_attention",
        [("block_q", blocks), ("block_k", blocks)],
        runner=KernelRunner(
            build=build,
            reference=lambda: attention_ref(q, k, v, causal=causal)),
        signature=(f"mha:b={batch}:h={heads}:sq={seq}:skv={seq}:"
                   f"d={head_dim}:causal={causal}:dtype=float32:"
                   f"seed={seed}"))


def spmv_mulsum_space(*, n: int = 1024, k: int = 8,
                      block_values=(64, 128, 256, 512),
                      seed: int = 0,
                      interpret: bool | None = None) -> ParamSpace:
    """block_n grid for the ELL SpMV fused multiply-reduce
    (:func:`repro.kernels.spmv.ops.ell_matvec`) on one seeded
    band-structured matrix."""
    import jax.numpy as jnp

    from repro.kernels.spmv.ops import ell_matvec
    from repro.kernels.spmv.ref import ell_matvec_ref

    rng = np.random.default_rng(seed)
    vals = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
    cols = jnp.asarray(
        rng.integers(0, n, size=(n, k)).astype(np.int32))
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))

    def build(params: dict):
        bn = params["block_n"]

        def run():
            return ell_matvec(vals, cols, x, block_n=bn,
                              interpret=interpret)
        return run

    return ParamSpace(
        "spmv_mulsum",
        [("block_n", tuple(int(v) for v in block_values))],
        runner=KernelRunner(
            build=build,
            reference=lambda: ell_matvec_ref(vals, cols, x)),
        signature=(f"ell_matvec:n={n}:k={k}:dtype=float32:"
                   f"seed={seed}"))


def pack_space(*, n: int = 4096, m: int = 512,
               block_c_values=(64, 128, 256),
               chunk_values=(256, 512, 1024),
               seed: int = 0, interpret: bool = True) -> ParamSpace:
    """(block_c, chunk) grid for the chunked one-hot gather kernel
    (:func:`repro.kernels.pack.kernel.pack`) on one seeded index set.

    Tunes the kernel directly (the :mod:`repro.kernels.pack.ops`
    wrapper pins the kernel defaults) — a winning rule here is exactly
    what that wrapper should adopt per platform.
    """
    import jax.numpy as jnp

    from repro.kernels.pack.kernel import pack as pack_kernel
    from repro.kernels.pack.ref import pack_ref

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, size=m).astype(np.int32))

    def build(params: dict):
        bc, ch = params["block_c"], params["chunk"]

        def run():
            return pack_kernel(x, idx, block_c=bc, chunk=ch,
                               interpret=interpret)
        return run

    return ParamSpace(
        "pack",
        [("block_c", tuple(int(v) for v in block_c_values)),
         ("chunk", tuple(int(v) for v in chunk_values))],
        runner=KernelRunner(
            build=build,
            reference=lambda: pack_ref(x, idx)),
        signature=f"pack:n={n}:m={m}:dtype=float32:seed={seed}")
