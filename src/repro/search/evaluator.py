"""Compatibility shim: the evaluator now lives in :mod:`repro.engine`.

``BatchEvaluator`` (the serial ``"sim"`` backend) and
``canonical_key`` moved to the pluggable evaluation-engine subsystem —
:mod:`repro.engine.base` — where they share the memo-cache / noise /
budget-accounting layer with the vectorized, process-pool, and
wall-clock backends. Import from :mod:`repro.engine` (or keep importing
from here / :mod:`repro.search`; both stay supported, with a
:class:`DeprecationWarning` so the shim can eventually be deleted —
every name here *is* the :mod:`repro.engine.base` object, asserted by
tests/test_shims.py).
"""
import warnings

warnings.warn(
    "repro.search.evaluator is a deprecated shim; import "
    "BatchEvaluator/EvaluatorBase/canonical_key from repro.engine "
    "(new home: repro.engine.base)",
    DeprecationWarning, stacklevel=2)

from repro.engine.base import BatchEvaluator, EvaluatorBase, canonical_key

__all__ = ["BatchEvaluator", "EvaluatorBase", "canonical_key"]
