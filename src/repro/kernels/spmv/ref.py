"""Pure-jnp oracle for the ELL SpMV kernels."""
from __future__ import annotations

import jax.numpy as jnp


def ell_matvec_ref(vals: jnp.ndarray, cols: jnp.ndarray,
                   x: jnp.ndarray) -> jnp.ndarray:
    """y[i] = sum_k vals[i, k] * x[cols[i, k]].

    Padding convention: padded entries have vals == 0 (cols may point
    anywhere valid), so they contribute nothing.
    """
    return jnp.sum(vals * x[cols], axis=1)


def ell_matvec_f32_ref(vals, cols, x):
    return ell_matvec_ref(vals.astype(jnp.float32), cols,
                          x.astype(jnp.float32))
