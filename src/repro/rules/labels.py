"""Performance-class labeling (paper §IV-A, Fig. 4).

1. Sort the empirical times ascending.
2. Convolve with a ±r step kernel (k = -1 on [-r, 0], +1 on (0, r)),
   r = 0.5% of the number of measurements (minimum 1), computed only where
   the kernel fully overlaps the array.
3. Detect peaks (strictly greater than neighbors), compute prominences,
   keep peaks whose prominence is above the 98th percentile of all peak
   prominences.
4. Peak locations are class boundaries; each measurement gets the class of
   its bucket (class 0 = fastest).

Peak detection/prominence are implemented from scratch (the target
container has no guaranteed scipy); tests cross-check against
``scipy.signal.find_peaks`` when scipy is importable. Prominence is
computed with numpy index/slice ops; the original per-sample Python
walk survives as :func:`peak_prominences_loop`, the reference the
vectorized version is locked to by tests/test_rules_pipeline.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def step_convolve(sorted_times: np.ndarray, radius: int) -> np.ndarray:
    """Convolution of the sorted data with the paper's step kernel.

    The §IV-A kernel is -1 on [-r, 0] (r+1 values) and +1 on [1, r]
    (r values):

        out[i] = sum_{m=1..r} a[i+m] - sum_{m=-r..0} a[i+m]

    computed for i where both windows are in-bounds. Returned array is
    aligned with the input (non-computable entries are 0).
    """
    a = np.asarray(sorted_times, dtype=np.float64)
    n = a.size
    r = int(radius)
    out = np.zeros(n, dtype=np.float64)
    if n < 2 * r + 1:
        return out
    csum = np.concatenate([[0.0], np.cumsum(a)])

    def window(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        return csum[hi + 1] - csum[lo]

    idx = np.arange(r, n - r)
    right = window(idx + 1, idx + r)      # m = 1..r  (r values)
    left = window(idx - r, idx)           # m = -r..0 (r+1 values)
    out[idx] = right - left
    return out


def find_peaks(x: np.ndarray) -> np.ndarray:
    """Indices of simple local maxima (strictly greater than neighbors).

    Plateaus: the midpoint of a flat run that is higher than both sides is
    a peak (matches scipy.signal.find_peaks plateau handling).
    """
    x = np.asarray(x, dtype=np.float64)
    n = x.size
    peaks = []
    i = 1
    while i < n - 1:
        if x[i] > x[i - 1]:
            # scan plateau
            j = i
            while j < n - 1 and x[j + 1] == x[i]:
                j += 1
            if j < n - 1 and x[j + 1] < x[i]:
                peaks.append((i + j) // 2)
            i = j + 1
        else:
            i += 1
    return np.asarray(peaks, dtype=np.int64)


def peak_prominences_loop(x: np.ndarray,
                          peaks: np.ndarray) -> np.ndarray:
    """Reference prominence: per-sample Python walks (scipy's definition).

    For each peak: walk left/right until the signal exceeds the peak height
    (or the array ends); the base on each side is the minimum in that
    window; prominence = peak height - max(left base, right base).
    """
    x = np.asarray(x, dtype=np.float64)
    out = np.empty(len(peaks), dtype=np.float64)
    for k, p in enumerate(peaks):
        h = x[p]
        i = p - 1
        left_min = h
        while i >= 0 and x[i] <= h:
            left_min = min(left_min, x[i])
            i -= 1
        j = p + 1
        right_min = h
        while j < x.size and x[j] <= h:
            right_min = min(right_min, x[j])
            j += 1
        out[k] = h - max(left_min, right_min)
    return out


def peak_prominences(x: np.ndarray, peaks: np.ndarray) -> np.ndarray:
    """Prominence per scipy's definition, computed with numpy ops.

    The per-sample Python walks of :func:`peak_prominences_loop` become
    per-peak index arithmetic: the nearest strictly-higher sample on
    each side bounds the base window, and the base is a slice min.
    Locked to the loop reference by tests.
    """
    x = np.asarray(x, dtype=np.float64)
    peaks = np.asarray(peaks, dtype=np.int64)
    out = np.empty(len(peaks), dtype=np.float64)
    for k, p in enumerate(peaks):
        h = x[p]
        higher_left = np.flatnonzero(x[:p] > h)
        lb = int(higher_left[-1]) + 1 if higher_left.size else 0
        higher_right = np.flatnonzero(x[p + 1:] > h)
        rb = p + 1 + int(higher_right[0]) if higher_right.size else x.size
        left_min = x[lb:p].min() if lb < p else h
        right_min = x[p + 1:rb].min() if p + 1 < rb else h
        out[k] = h - max(left_min, right_min)
    return out


@dataclasses.dataclass
class Labeling:
    order: np.ndarray          # argsort of the input times
    sorted_times: np.ndarray
    convolution: np.ndarray
    boundaries: np.ndarray     # indices into sorted_times (class edges)
    labels: np.ndarray         # class per *input* measurement (unsorted)
    n_classes: int

    def class_ranges(self) -> list[tuple[float, float]]:
        """(t_min, t_max) per class, from the sorted data."""
        edges = [0, *list(self.boundaries + 1), self.sorted_times.size]
        out = []
        for c in range(self.n_classes):
            seg = self.sorted_times[edges[c]:edges[c + 1]]
            out.append((float(seg.min()), float(seg.max())))
        return out


def label_times(times: np.ndarray,
                radius_frac: float = 0.005,
                prominence_percentile: float = 98.0) -> Labeling:
    """Full labeling pipeline of §IV-A."""
    times = np.asarray(times, dtype=np.float64)
    order = np.argsort(times, kind="stable")
    s = times[order]
    r = max(1, int(round(radius_frac * s.size)))
    conv = step_convolve(s, r)
    peaks = find_peaks(conv)
    if peaks.size:
        prom = peak_prominences(conv, peaks)
        thresh = np.percentile(prom, prominence_percentile)
        keep = peaks[prom >= thresh]
        # A boundary must mark an actual jump in the sorted times (the
        # convolution peak detects "a large increase", §IV-A); on
        # structureless data the top-percentile filter alone admits
        # ties between float-rounding micro-peaks.
        if keep.size and s.size > 1:
            diffs = np.diff(s)
            med = np.median(diffs)
            keep = keep[diffs[np.clip(keep, 0, diffs.size - 1)]
                        > 3.0 * med]
    else:
        keep = peaks
    boundaries = np.sort(keep)
    # Label sorted positions, then scatter back to input order:
    # position i's class = number of boundaries strictly below i.
    sorted_labels = np.searchsorted(boundaries, np.arange(s.size),
                                    side="left").astype(np.int64)
    labels = np.empty(s.size, dtype=np.int64)
    labels[order] = sorted_labels
    return Labeling(order=order, sorted_times=s, convolution=conv,
                    boundaries=boundaries, labels=labels,
                    n_classes=int(sorted_labels.max()) + 1 if s.size else 0)
