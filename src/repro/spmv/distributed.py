"""Distributed SpMV with shard_map (the paper's workload, TPU-native).

The paper's MPI point-to-point halo exchange maps to two
``lax.ppermute`` shifts over a 1-D device mesh axis ("ranks"):
each rank sends its x block to its right and left neighbors, which
together assemble the halo = [left block, right block]. Local and remote
multiplications use the ELL kernels from :mod:`repro.kernels.spmv`.

The op decomposition intentionally mirrors the paper's DAG:

    Pack      -> (band matrices: the pack is the identity on the block —
                  contiguous halo; the general gather kernel lives in
                  repro.kernels.pack and is exercised for irregular inputs)
    PostSend/PostRecv/Wait -> ppermute (XLA schedules the wire transfer;
                  emission order = our schedule decision)
    yL / yR   -> ELL multiply kernels
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.compat import shard_map
from repro.kernels.spmv import ops as spmv_ops

AXIS = "ranks"


def _halo_exchange(x_block: jax.Array, axis: str = AXIS) -> jax.Array:
    """Assemble halo = [left neighbor block, right neighbor block]."""
    # lax.axis_size is missing on older jax; psum(1) is its identity.
    n = getattr(lax, "axis_size", lambda a: lax.psum(1, a))(axis)
    # perm (i -> i+1) means device j receives from j-1: its LEFT neighbor.
    from_left = lax.ppermute(x_block, axis,
                             [(i, (i + 1) % n) for i in range(n)])
    from_right = lax.ppermute(x_block, axis,
                              [(i, (i - 1) % n) for i in range(n)])
    return jnp.concatenate([from_left, from_right], axis=0)


def spmv_shard(local_vals, local_cols, remote_vals, remote_cols, x_block,
               *, use_kernel: bool = True, overlap_local: bool = True,
               axis: str = AXIS):
    """Per-shard body: one distributed SpMV step.

    ``overlap_local``: emit the local multiply before the halo exchange's
    consumer so XLA can overlap compute with the permutes (the schedule
    decision the paper's rules produce for the fast class: local multiply
    runs while communication is in flight).
    """
    mv = spmv_ops.ell_matvec if use_kernel else spmv_ops.ell_matvec_ref

    if overlap_local:
        halo = _halo_exchange(x_block, axis)
        y_local = mv(local_vals, local_cols, x_block)
        y_remote = mv(remote_vals, remote_cols, halo)
    else:
        # Slow-class ordering: remote path fully serialized first.
        halo = _halo_exchange(x_block, axis)
        y_remote = mv(remote_vals, remote_cols, halo)
        y_local = mv(local_vals, local_cols, x_block)
    return y_local + y_remote


def make_distributed_spmv(mesh: Mesh, *, use_kernel: bool = True,
                          overlap_local: bool = True):
    """jit-compiled distributed SpMV over ``mesh`` axis "ranks".

    Inputs are the stacked per-rank arrays from
    :func:`repro.spmv.matrix.stack_partitions` (leading rank axis) and
    the stacked x blocks (n_ranks, m).
    """
    spec = P(AXIS)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=spec,
        # pallas_call outputs carry no varying-mesh-axis metadata yet.
        check_vma=False)
    def _shard(lv, lc, rv, rc, xb):
        y = spmv_shard(lv[0], lc[0], rv[0], rc[0], xb[0],
                       use_kernel=use_kernel,
                       overlap_local=overlap_local)
        return y[None]

    sharding = NamedSharding(mesh, spec)

    @jax.jit
    def run(lv, lc, rv, rc, xb):
        args = [jax.device_put(a, sharding) for a in (lv, lc, rv, rc, xb)]
        return _shard(*args)

    return run
