"""Sequence-to-vector feature transformation (paper §IV-B).

For a set of expanded schedules (original ops + inserted sync ops):

  * one *ordering* feature per ordered pair (u, v) of items:
      1 if u appears before v in the expanded sequence, else 0
    (only (u, v) with u < v lexicographically are kept; the reverse pair is
    its complement and adds no information);
  * one *stream* feature per unordered pair of GPU ops:
      1 if both are bound to the same stream, else 0.

Features that take the same value in every schedule (e.g. DAG-implied
orderings) are dropped — they have no discriminatory power.

The matrix fill is vectorized: per schedule we store only the positions
of its expanded items (one integer per item), and the full
(schedules × pairs) matrix is produced by numpy index operations over a
position matrix — no per-feature Python loop. :class:`FeatureBasis`
exposes this incrementally: new schedules can be absorbed without
re-expanding (the expensive sync-insertion step) the already-featurized
corpus, which is what the online surrogate in
:mod:`repro.search.surrogate` trains on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dag import Graph, Schedule
from repro.core.sync import expanded_names


@dataclasses.dataclass(frozen=True)
class Feature:
    kind: str  # 'order' | 'stream'
    u: str
    v: str

    def describe(self, value: int) -> str:
        """Human-readable rule text for this feature taking ``value``."""
        if self.kind == "order":
            return (f"{self.u} before {self.v}" if value
                    else f"{self.v} before {self.u}")
        return (f"{self.u} same stream as {self.v}" if value
                else f"{self.u} different stream than {self.v}")


@dataclasses.dataclass
class FeatureMatrix:
    features: list[Feature]
    X: np.ndarray  # (n_schedules, n_features) int8

    def names(self) -> list[str]:
        return [f"{f.kind}:{f.u}<{f.v}" for f in self.features]


class DegenerateFeatureSpaceError(ValueError):
    """Raised when a corpus has no discriminating features.

    After constant-column pruning, a corpus of zero or one *distinct*
    schedules has an empty feature matrix; the downstream learning stack
    (``algorithm1``) cannot split on nothing, so the error is raised
    here, at the point where the cause is nameable.
    """


class FeatureBasis:
    """Incremental featurizer over a growing schedule corpus.

    ``add`` absorbs schedules by expanding them once (sync insertion,
    :func:`repro.core.sync.expanded_names`) and caching only their item
    positions and stream bindings; ``matrix`` then materializes the
    pruned :class:`FeatureMatrix` for everything absorbed so far with
    vectorized index ops. Absorbing more schedules never re-expands the
    existing corpus — items first seen in later schedules are simply
    absent (feature value 0) in earlier rows, exactly as the pairwise
    definition above prescribes.
    """

    # Position sentinel for "item absent from this schedule": larger
    # than any real position, so ``absent < anything`` is never true.
    _ABSENT = np.int32(2 ** 30)

    def __init__(self, graph: Graph):
        self.graph = graph
        self.gpu = sorted(graph.gpu_ops())
        self._gpu_col = {n: i for i, n in enumerate(self.gpu)}
        self._universe: dict[str, int] = {}  # item name -> column id
        # Per absorbed schedule: universe column ids in sequence order
        # (the position of an item IS its index in that array) and the
        # stream binding per GPU op (row into the stream matrix).
        self._rows: list[np.ndarray] = []
        self._streams: list[np.ndarray] = []

    def __len__(self) -> int:
        return len(self._rows)

    def add(self, schedules: list[Schedule]) -> "FeatureBasis":
        uni = self._universe
        for s in schedules:
            names = expanded_names(self.graph, s)
            self._rows.append(np.asarray(
                [uni.setdefault(n, len(uni)) for n in names],
                dtype=np.int32))
            srow = np.full(len(self.gpu), -1, dtype=np.int32)
            for it in s.items:
                if it.stream is not None:
                    j = self._gpu_col.get(it.name)
                    if j is not None:
                        srow[j] = it.stream
            self._streams.append(srow)
        return self

    # -- vectorized matrix construction -----------------------------------
    def _position_matrix(self) -> tuple[list[str], np.ndarray]:
        """(sorted universe, (n_schedules, |universe|) position matrix).

        Entry [i, u] is the position of item u in schedule i's expanded
        sequence, or the ``_ABSENT`` sentinel if it does not occur.
        """
        names = sorted(self._universe)
        remap = np.empty(len(self._universe), dtype=np.int64)
        for sorted_col, n in enumerate(names):
            remap[self._universe[n]] = sorted_col
        P = np.full((len(self._rows), len(names)), self._ABSENT,
                    dtype=np.int32)
        for i, cols in enumerate(self._rows):
            P[i, remap[cols]] = np.arange(cols.size, dtype=np.int32)
        return names, P

    def _raw(self) -> tuple[list[Feature], np.ndarray]:
        """All candidate features (order pairs, then stream pairs) and
        their unpruned value matrix."""
        names, P = self._position_matrix()
        n_sched = len(self._rows)
        iu, iv = np.triu_indices(len(names), k=1)
        # A[i, a, b] = "a before b in schedule i, both present": the
        # absent sentinel is never < anything (so an absent a never
        # fires), and absent b columns are masked off. One contiguous
        # (n, U, U) broadcast beats two (n, pairs) int32 gathers.
        A = P[:, :, None] < P[:, None, :]
        A &= (P != self._ABSENT)[:, None, :]
        X_order = A[:, iu, iv]

        S = (np.vstack(self._streams) if self._streams
             else np.empty((0, len(self.gpu)), dtype=np.int32))
        gu, gv = np.triu_indices(len(self.gpu), k=1)
        X_stream = S[:, gu] == S[:, gv]

        feats = [Feature("order", names[a], names[b])
                 for a, b in zip(iu, iv)]
        feats += [Feature("stream", self.gpu[a], self.gpu[b])
                  for a, b in zip(gu, gv)]
        X = np.concatenate([X_order, X_stream], axis=1) if feats else \
            np.zeros((n_sched, 0), dtype=bool)
        return feats, X

    def matrix(self) -> FeatureMatrix:
        """Constant-pruned feature matrix for the absorbed corpus."""
        feats, X = self._raw()
        if X.shape[0]:
            keep = np.flatnonzero(X.min(axis=0) != X.max(axis=0))
        else:
            keep = np.array([], dtype=np.int64)
        # bool and int8 share layout with values 0/1: the view is free
        # and keeps the public int8 contract.
        return FeatureMatrix([feats[j] for j in keep],
                             np.ascontiguousarray(X[:, keep])
                             .view(np.int8))


class FeatureUniverse:
    """Names-only candidate-feature tracker for out-of-core corpora.

    The O(|items|) companion of :class:`FeatureBasis`: ``add`` absorbs
    schedules by recording *which* expanded items occur — never their
    positions — so memory stays independent of corpus size.
    ``candidate_features()`` lists the same candidate features, in the
    same order, as ``FeatureBasis._raw()`` over an equal corpus
    (sorted-universe order pairs, then sorted-GPU stream pairs), which
    is what lets a histogram sink prune constant columns blockwise
    with :func:`apply_features` and still match the in-memory basis
    feature for feature. ``merge`` unions two hosts' universes.
    """

    def __init__(self, graph: Graph):
        self.graph = graph
        self.gpu = sorted(graph.gpu_ops())
        self._names: set[str] = set()

    def __len__(self) -> int:
        return len(self._names)

    def add(self, schedules: list[Schedule]) -> "FeatureUniverse":
        for s in schedules:
            self._names.update(expanded_names(self.graph, s))
        return self

    def merge(self, other: "FeatureUniverse") -> "FeatureUniverse":
        """Absorb another universe (sharded hosts); in place."""
        self._names |= other._names
        return self

    def candidate_features(self) -> list[Feature]:
        """Unpruned candidate features in ``FeatureBasis._raw()`` order."""
        names = sorted(self._names)
        iu, iv = np.triu_indices(len(names), k=1)
        feats = [Feature("order", names[a], names[b])
                 for a, b in zip(iu, iv)]
        gu, gv = np.triu_indices(len(self.gpu), k=1)
        feats += [Feature("stream", self.gpu[a], self.gpu[b])
                  for a, b in zip(gu, gv)]
        return feats


def featurize(graph: Graph, schedules: list[Schedule]) -> FeatureMatrix:
    """Build the (pruned) feature matrix for ``schedules``.

    Raises :class:`DegenerateFeatureSpaceError` when the corpus has no
    discriminating features (zero or one distinct schedules): every
    column would be pruned as constant and the downstream tree fit
    (``algorithm1``) would silently consume a 0-feature matrix.
    """
    fm = FeatureBasis(graph).add(schedules).matrix()
    if not fm.features:
        raise DegenerateFeatureSpaceError(
            f"corpus of {len(schedules)} schedule(s) has no "
            "discriminating features after constant-column pruning "
            "(all schedules are identical, or the corpus is empty); "
            "at least 2 distinct schedules are required")
    return fm


def apply_features(graph: Graph, schedules: list[Schedule],
                   features: list[Feature]) -> np.ndarray:
    """Evaluate an explicit feature list on ``schedules`` (vectorized).

    The basis is fixed by ``features``: items unseen there contribute
    nothing, items absent from a schedule give 0 on their order pairs.
    """
    order_cols = [j for j, f in enumerate(features) if f.kind == "order"]
    stream_cols = [j for j, f in enumerate(features) if f.kind == "stream"]
    X = np.zeros((len(schedules), len(features)), dtype=np.int8)
    if not schedules or not features:
        return X

    if order_cols:
        names = sorted({n for j in order_cols
                        for n in (features[j].u, features[j].v)})
        col = {n: i for i, n in enumerate(names)}
        P = np.full((len(schedules), len(names)), -1, dtype=np.int64)
        for i, s in enumerate(schedules):
            for pos, n in enumerate(expanded_names(graph, s)):
                c = col.get(n)
                if c is not None:
                    P[i, c] = pos
        iu = np.array([col[features[j].u] for j in order_cols])
        iv = np.array([col[features[j].v] for j in order_cols])
        Pu, Pv = P[:, iu], P[:, iv]
        X[:, order_cols] = ((Pu >= 0) & (Pv >= 0) & (Pu < Pv)) \
            .astype(np.int8)

    if stream_cols:
        gpu = sorted({n for j in stream_cols
                      for n in (features[j].u, features[j].v)})
        gcol = {n: i for i, n in enumerate(gpu)}
        S = np.full((len(schedules), len(gpu)), -1, dtype=np.int64)
        for i, s in enumerate(schedules):
            for n, stream in s.streams().items():
                c = gcol.get(n)
                if c is not None:
                    S[i, c] = stream
        gu = np.array([gcol[features[j].u] for j in stream_cols])
        gv = np.array([gcol[features[j].v] for j in stream_cols])
        X[:, stream_cols] = (S[:, gu] == S[:, gv]).astype(np.int8)

    return X


def featurize_like(graph: Graph, schedules: list[Schedule],
                   reference: FeatureMatrix) -> np.ndarray:
    """Feature values for new schedules in an existing feature basis.

    Used by Table V evaluation: classify the *entire* space with a tree
    trained on an MCTS subset (whose feature pruning defined the basis).
    """
    return apply_features(graph, schedules, reference.features)
