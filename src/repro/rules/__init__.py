"""Rules distillation subsystem: labels -> trees -> design rules.

The paper's headline deliverable (§IV, Algorithm 1, Tables VI-VIII) as
one subsystem, mirroring the :mod:`repro.engine` refactor:

* :mod:`repro.rules.labels` — §IV-A convolution/peak performance-class
  labeling;
* :mod:`repro.rules.trees` — vectorized sort-based CART
  (:class:`DecisionTree`), the warm-started Algorithm-1 sweep, and the
  :class:`RegressionTree` base learner, all on one shared split kernel
  and :class:`Presort` cache;
* :mod:`repro.rules.rulesets` — §IV-D/§V ruleset extraction,
  canonical-annotation, Table-V class-range accuracy;
* :mod:`repro.rules.boost` — :class:`GradientBoostedSurrogate`, the
  tree-ensemble cost model behind the ``"boost"`` surrogate backend;
* :mod:`repro.rules.pipeline` — :func:`distill`, the end-to-end
  search-result -> :class:`RuleReport` API.

This package never imports :mod:`repro.search` at runtime — the
dependency points search -> rules (``repro.core`` re-exports the
moved names for one-stop imports; the old ``core/{labels,dtree,
rules}.py`` shim modules are deleted). See README.md in this
directory for the subsystem map and determinism guarantees.
"""
from repro.rules.boost import GradientBoostedSurrogate, OnlineSurrogateBase
from repro.rules.labels import (Labeling, find_peaks, label_times,
                                peak_prominences, peak_prominences_loop,
                                step_convolve)
from repro.rules.pipeline import RuleReport, distill
from repro.rules.rulesets import (Rule, RuleSet, annotate_vs_canonical,
                                  class_range_accuracy,
                                  class_range_accuracy_loop,
                                  extract_rulesets, render_rules_table,
                                  rules_by_class)
from repro.rules.trees import (ClassCountHistogram, DecisionTree,
                               HistogramGrower, Presort, RegressionTree,
                               TreeSearchTrace, algorithm1,
                               algorithm1_from_histograms,
                               fit_from_histograms)

__all__ = [
    "GradientBoostedSurrogate", "OnlineSurrogateBase",
    "Labeling", "find_peaks", "label_times", "peak_prominences",
    "peak_prominences_loop", "step_convolve",
    "RuleReport", "distill",
    "Rule", "RuleSet", "annotate_vs_canonical", "class_range_accuracy",
    "class_range_accuracy_loop", "extract_rulesets", "render_rules_table",
    "rules_by_class",
    "ClassCountHistogram", "DecisionTree", "HistogramGrower",
    "Presort", "RegressionTree", "TreeSearchTrace", "algorithm1",
    "algorithm1_from_histograms", "fit_from_histograms",
]
