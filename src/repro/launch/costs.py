"""Roofline terms for one (arch x shape x mesh) cell.

Hardware constants (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.

Sources per term (all per chip = per partition):

  compute    HLO dot flops from the loop-corrected analyzer
             (repro.launch.hlo) over the compiled per-partition module.
  memory     traffic model over the compiled memory_analysis numbers:
             train: params+opt are read and written (2x arguments) and
             live temps stream through HBM twice (write+read);
             serve: arguments (weights + caches) are read once per step,
             temps twice.
  collective per-chip collective operand bytes (loop-corrected) over the
             per-link ICI bandwidth.

MODEL_FLOPS (analytic; the brief's definition): 6*N*D for dense training
(N = active params, D = tokens), 2*N*D for single-pass inference. The
ratio MODEL_FLOPS / (HLO flops x chips) exposes remat/redundancy waste
(and dispatch overcompute for MoE).
"""
from __future__ import annotations

import dataclasses

from repro.configs.shapes import SHAPES
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_chip: float
    hlo_collective_bytes_per_chip: float
    mem_traffic_bytes_per_chip: float
    chips: int
    collective_s_tpu: float = 0.0   # f32->bf16-adjusted (see hlo.py)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s_tpu or
                 self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap estimate: the slowest resource wins."""
        return max(self.compute_s, self.memory_s,
                   self.collective_s_tpu or self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the per-chip peak at the estimated
        step time — the score §Perf drives up."""
        if self.step_time_s <= 0:
            return 0.0
        useful = self.model_flops / self.chips
        return useful / (self.step_time_s * PEAK_FLOPS)

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "collective_s_tpu": self.collective_s_tpu,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "model_flops": self.model_flops,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_collective_bytes_per_chip":
                self.hlo_collective_bytes_per_chip,
            "mem_traffic_bytes_per_chip":
                self.mem_traffic_bytes_per_chip,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }


def model_flops(cfg: ModelConfig, shape: str) -> float:
    """The brief's MODEL_FLOPS definition (+ attention quadratic term,
    which 6ND omits but which dominates prefill_32k)."""
    cell = SHAPES[shape]
    n_active = cfg.active_param_count()
    kinds = cfg.block_kinds()
    n_attn = sum(k == "attn" for k in kinds)
    hq, dh = cfg.n_heads, cfg.head_dim
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "train":
        tokens = b * s
        attn = 6.0 * n_attn * b * (s * s / 2) * hq * dh * 2
        return 6.0 * n_active * tokens + attn
    if cell.kind == "prefill":
        tokens = b * s
        attn = 2.0 * n_attn * b * (s * s / 2) * hq * dh * 2
        return 2.0 * n_active * tokens + attn
    # decode: one token per sequence; attention reads the full cache.
    window = cfg.attn_window or cell.seq_len
    attn = 4.0 * n_attn * b * min(window, cell.seq_len) * hq * dh
    return 2.0 * n_active * b + attn


def roofline(cfg: ModelConfig, shape: str, kind: str, chips: int,
             hlo_flops_per_chip: float,
             collective_bytes_per_chip: float,
             memory_stats: dict,
             collective_bytes_f32: float = 0.0) -> Roofline:
    arg = memory_stats.get("argument_size_in_bytes", 0)
    temp = memory_stats.get("temp_size_in_bytes", 0)
    out = memory_stats.get("output_size_in_bytes", 0)
    alias = memory_stats.get("alias_size_in_bytes", 0)
    if kind == "train":
        traffic = 2 * arg + 2 * temp + out - alias
    else:
        traffic = arg + 2 * temp + out
    return Roofline(
        compute_s=hlo_flops_per_chip / PEAK_FLOPS,
        memory_s=traffic / HBM_BW,
        collective_s=collective_bytes_per_chip / LINK_BW,
        collective_s_tpu=(collective_bytes_per_chip -
                          0.5 * collective_bytes_f32) / LINK_BW,
        model_flops=model_flops(cfg, shape),
        hlo_flops_per_chip=hlo_flops_per_chip,
        hlo_collective_bytes_per_chip=collective_bytes_per_chip,
        mem_traffic_bytes_per_chip=float(traffic),
        chips=chips,
    )
