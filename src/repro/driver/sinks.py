"""Streaming result sinks: consume evaluated batches as they land.

The pre-driver pipeline materialized the whole deduplicated schedule
list and re-featurized it from scratch whenever the rules pipeline ran
(``SearchResult.dataset()`` -> ``featurize`` -> the full double
expansion). Sinks invert that: the :class:`~repro.driver.driver.
SearchDriver` streams every evaluated :class:`~repro.engine.base.
EvalBatch` (plus the run-level freshness mask) to each attached sink
*during* the search, so by the time the search returns, the dataset is
already folded.

``dataset`` — :class:`DatasetSink`
    Folds each batch's fresh (first-seen canonical) schedules into an
    incremental :class:`~repro.core.features.FeatureBasis` (schedules
    are sync-expanded exactly once, never re-featurized) and an
    incremental time histogram. ``dataset()`` then emits the same
    ``(features, labels, times)`` triple ``SearchResult.dataset()``
    computes from scratch — byte-identical, locked by test — and
    ``distill()`` hands the streamed matrix straight to
    :func:`repro.rules.distill` (``features=``), skipping the
    re-featurization pass entirely.

``histogram`` — :class:`HistogramSink`
    The out-of-core variant: stores only compact canonical encodings
    and folded count histograms — never a ``(rows x features)``
    matrix — and ``distill()`` trains the design-rule tree blockwise
    through :class:`repro.rules.trees.HistogramGrower`, bit-identical
    to the in-memory path. Sinks from sharded hosts ``merge()``.

``trace`` — :class:`TraceSink`
    Records one row per driver round (canonical keys chosen, fresh
    count, running best) — the determinism probe used by the
    cross-backend acquisition tests and the benchmark race logs.

Sinks implement one method::

    consume(batch: EvalBatch, fresh: np.ndarray) -> None

where ``fresh[i]`` marks the first occurrence of ``batch.keys[i]``
within the driver run (the same dedup that builds
``SearchResult.schedules``). Registered factories are constructed as
``factory(graph, **kwargs)`` via :func:`make_sink`.
"""
from __future__ import annotations

import math
from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.dag import Graph, Schedule
from repro.core.features import (DegenerateFeatureSpaceError,
                                 FeatureMatrix)
from repro.engine.base import EvalBatch
from repro.space.base import DesignSpace, as_space


@runtime_checkable
class Sink(Protocol):
    """Consumer of evaluated batches streamed by the search driver."""

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        """Fold one evaluated batch (with run-level freshness mask)."""
        ...


class StreamingHistogram:
    """Fixed-width counts over a range that doubles on overflow.

    The incremental form of ``np.histogram``: ``add`` folds a batch
    into ``2 * half_bins`` equal-width bins spanning ``[0, hi)``; when
    a value lands past ``hi`` the range doubles and adjacent bin pairs
    merge (counts are preserved exactly), so the memory footprint is
    constant no matter how many observations stream through. This is
    the label-histogram seed for out-of-core distillation: class
    boundaries can be estimated from the folded counts without holding
    every observation.

    ``hi`` is always a power of two (the smallest strictly above the
    largest value seen), so it is a pure function of that maximum —
    independent of batch order or sharding — and any two histograms'
    ranges nest by exact doublings. That is what makes :meth:`merge`
    associative, commutative, and bin-for-bin equal to single-stream
    ``add`` of the concatenated observations (hypothesis-locked in
    tests/test_histogram_trees.py).
    """

    def __init__(self, half_bins: int = 128):
        if half_bins < 1:
            raise ValueError("half_bins must be >= 1")
        self.n_bins = 2 * half_bins
        self.counts = np.zeros(self.n_bins, dtype=np.int64)
        self.hi = 0.0                      # upper edge; 0 = no data yet

    def add(self, values: np.ndarray) -> None:
        v = np.asarray(values, dtype=np.float64)
        if v.size == 0:
            return
        if np.any(v < 0.0):
            raise ValueError("times must be non-negative")
        vmax = float(v.max())
        if self.hi == 0.0:
            # Smallest power of two strictly above vmax (frexp gives
            # vmax = m * 2**e with m in [0.5, 1), so 2**e > vmax).
            self.hi = math.ldexp(1.0, math.frexp(vmax)[1]) \
                if vmax > 0.0 else 1.0
        while vmax >= self.hi:
            # Doubling merges adjacent bin pairs: counts are preserved
            # exactly, and because the doubled edges coincide with
            # every second old edge (scaling by 2 is exact in binary
            # floating point), the merged counts are exactly what
            # np.histogram would produce over the new edges.
            self.counts = (self.counts[0::2] + self.counts[1::2])
            self.counts = np.concatenate(
                [self.counts, np.zeros(self.n_bins // 2, np.int64)])
            self.hi *= 2.0
        idx = np.minimum((v / self.hi * self.n_bins).astype(np.int64),
                         self.n_bins - 1)
        # np.histogram's boundary correction: the scaled floor can land
        # one bin off when v sits within a rounding error of an edge;
        # nudge against the actual edges so counts match np.histogram
        # on edges() bin for bin.
        edges = self.edges()
        idx[v < edges[idx]] -= 1
        idx[(v >= edges[idx + 1]) & (idx != self.n_bins - 1)] += 1
        np.add.at(self.counts, idx, 1)

    @property
    def n(self) -> int:
        return int(self.counts.sum())

    def edges(self) -> np.ndarray:
        """Bin edges, ``np.histogram`` convention (n_bins + 1 values)."""
        return np.linspace(0.0, self.hi, self.n_bins + 1)

    def _rebin(self, counts: np.ndarray, hi: float,
               target: float) -> np.ndarray:
        """Counts rebinned to a larger power-of-two range (exact —
        each doubling merges adjacent pairs, see :meth:`add`)."""
        counts = counts.copy()
        while hi < target:
            counts = counts[0::2] + counts[1::2]
            counts = np.concatenate(
                [counts, np.zeros(self.n_bins // 2, np.int64)])
            hi *= 2.0
        if hi != target:
            raise ValueError(
                f"ranges do not nest: hi={hi} vs target={target}")
        return counts

    def merge(self, other: "StreamingHistogram") -> "StreamingHistogram":
        """Fold another histogram in (sharded hosts; in place).

        Both ranges double up to the larger one and the counts add —
        exactly the histogram single-stream ``add`` of both hosts'
        observations would have produced, in any merge order, because
        ``hi`` is a power-of-two function of the maximum value seen.
        """
        if not isinstance(other, StreamingHistogram):
            raise TypeError(f"expected StreamingHistogram, got "
                            f"{type(other).__name__}")
        if other.n_bins != self.n_bins:
            raise ValueError(
                f"cannot merge {other.n_bins}-bin histogram into "
                f"{self.n_bins}-bin histogram")
        if other.hi == 0.0:
            return self
        if self.hi == 0.0:
            self.hi = other.hi
            self.counts = other.counts.copy()
            return self
        target = max(self.hi, other.hi)
        self.counts = (self._rebin(self.counts, self.hi, target)
                       + other._rebin(other.counts, other.hi, target))
        self.hi = target
        return self


class _CanonicalKeySet:
    """Vectorized sink-lifetime dedup over canonical cache keys.

    Keys are fixed-width byte strings (canonical encoding rows), so
    membership is numpy ``S``-dtype array work instead of a Python set
    probe per element: the seen set is one sorted array (searchsorted
    membership) plus small unsorted pending chunks (``np.isin``),
    compacted geometrically so the amortized cost per batch stays
    O(batch · log seen).
    """

    def __init__(self):
        self._sorted: np.ndarray | None = None     # sorted S-dtype keys
        self._pending: list[np.ndarray] = []       # recent, unsorted
        self._n_pending = 0

    def __len__(self) -> int:
        n = 0 if self._sorted is None else self._sorted.size
        return n + self._n_pending

    def _compact(self) -> None:
        parts = ([] if self._sorted is None else [self._sorted]) \
            + self._pending
        self._sorted = np.sort(np.concatenate(parts))
        self._pending = []
        self._n_pending = 0

    def filter_new(self, keys, fresh: np.ndarray) -> np.ndarray:
        """Indices of ``keys`` that are fresh, unseen, and first within
        the batch (first-appearance order), then marks them seen."""
        arr = np.asarray(keys, dtype=np.bytes_)
        if arr.size == 0:
            return np.zeros(0, dtype=np.int64)
        if self._sorted is not None \
                and arr.dtype.itemsize != self._sorted.dtype.itemsize:
            raise ValueError(
                f"canonical keys must be fixed-width: got "
                f"{arr.dtype} vs seen {self._sorted.dtype}")
        new = np.asarray(fresh, dtype=bool).copy()
        if self._sorted is not None and self._sorted.size:
            pos = np.searchsorted(self._sorted, arr)
            pos_c = np.minimum(pos, self._sorted.size - 1)
            new &= self._sorted[pos_c] != arr
        for chunk in self._pending:
            new &= ~np.isin(arr, chunk)
        # First occurrence within the batch: the driver's fresh mask
        # already dedups within a run, but a sink fed raw keys (merge)
        # must not admit an intra-batch duplicate twice.
        _, first = np.unique(arr, return_index=True)
        keep = np.zeros(arr.size, dtype=bool)
        keep[first] = True
        new &= keep
        idx = np.flatnonzero(new)
        if idx.size:
            self._pending.append(arr[idx])
            self._n_pending += idx.size
            n_sorted = 0 if self._sorted is None else self._sorted.size
            if self._n_pending * 4 >= max(n_sorted, 256):
                self._compact()
        return idx


class DatasetSink:
    """Incremental ``(features, labels, times)`` accumulator.

    Mirrors the ``SearchResult`` dedup contract — the first observation
    per canonical schedule, in first-appearance order — so
    :meth:`dataset` is byte-identical to ``SearchResult.dataset()``
    while featurizing each schedule exactly once, the round it arrives.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 half_bins: int = 128):
        self.space = as_space(graph)
        self.graph = getattr(self.space, "graph", None)
        self.basis = self.space.feature_basis()
        self.schedules: list[Schedule] = []
        self.times: list[float] = []
        self.histogram = StreamingHistogram(half_bins=half_bins)
        self.n_consumed = 0                # every evaluation, dups too
        self._seen = _CanonicalKeySet()    # sink-lifetime dedup
        self._matrix_cache: FeatureMatrix | None = None
        self._matrix_rows = -1

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        self.n_consumed += len(batch)
        # The fresh mask is *per driver run*; the sink keeps its own
        # canonical-key set so one sink fed by several runs (e.g. over
        # a shared memoized evaluator) still holds each implementation
        # exactly once.
        idx = self._seen.filter_new(batch.keys, fresh)
        if idx.size == 0:
            return
        self._matrix_cache = None
        new = [batch.schedules[i] for i in idx]
        self.basis.add(new)
        self.schedules.extend(new)
        t_new = np.asarray(batch.times)[idx]
        self.times.extend(float(t) for t in t_new)
        self.histogram.add(t_new)

    # -- the streamed corpus -------------------------------------------
    def times_array(self) -> np.ndarray:
        return np.asarray(self.times, dtype=np.float64)

    def matrix(self) -> FeatureMatrix:
        """Constant-pruned feature matrix of everything streamed so far.

        Same contract as :func:`repro.core.features.featurize`
        (including :class:`DegenerateFeatureSpaceError` on a corpus
        with no discriminating features) — but the expansion work was
        already paid batch by batch, and the pruning pass is cached
        per corpus length (``distill()`` then ``dataset()`` on an
        unchanged corpus prunes once, not twice; ``consume``
        invalidates).
        """
        if self._matrix_cache is not None \
                and self._matrix_rows == len(self.schedules):
            return self._matrix_cache
        fm = self.basis.matrix()
        if not fm.features:
            raise DegenerateFeatureSpaceError(
                f"streamed corpus of {len(self.schedules)} schedule(s) "
                "has no discriminating features after constant-column "
                "pruning; at least 2 distinct schedules are required")
        self._matrix_cache = fm
        self._matrix_rows = len(self.schedules)
        return fm

    def dataset(self):
        """(features, labels, times) — ``SearchResult.dataset()`` shape."""
        from repro.rules.labels import label_times
        times = self.times_array()
        return self.matrix(), label_times(times), times

    def distill(self, **kwargs):
        """:func:`repro.rules.distill` on the streamed corpus.

        Passes the incrementally-built matrix via ``features=`` so the
        rules pipeline never re-featurizes the schedule list.
        """
        from repro.rules.pipeline import distill
        return distill(self, features=self.matrix(), **kwargs)


class HistogramSink:
    """Out-of-core corpus accumulator: compact encodings + count
    histograms, never a ``(rows x features)`` matrix.

    The scale unlock of the ROADMAP's out-of-core distillation item.
    Per fresh (first-seen canonical) evaluation the sink stores only
    the canonical int32 encoding row (the cache-key bytes
    reinterpreted — ``(2, N)`` order/stream form for schedule spaces,
    value indices for parameter grids), the observed time, and folds
    the time into the :class:`StreamingHistogram`; the item universe
    that names candidate features is tracked names-only through the
    space's ``feature_universe()``. Memory is O(rows x encoding) +
    O(features) — for the paper's spaces roughly 50x under the dense
    feature matrix — and :meth:`distill` runs the labels->tree pass
    blockwise in O(features x bins) extra memory via
    :class:`repro.rules.trees.HistogramGrower`, producing the same
    report bit for bit.

    The sink doubles as the corpus handle ``repro.rules.distill``
    consumes through its ``histograms=`` seam: ``n_rows`` /
    ``times`` / ``feature_list()`` / ``value_grids()`` / ``blocks()``.
    Blocks are decoded (``decode_batch``) and featurized
    (``apply_features``) on the fly, ``block_rows`` rows at a time —
    every tree level re-pays that featurization, which is the
    memory/CPU trade the out-of-core path makes. :meth:`merge` folds
    another host's sink in (sharded search), with the same
    first-appearance dedup the driver applies.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 half_bins: int = 128, block_rows: int = 4096):
        if block_rows < 1:
            raise ValueError("block_rows must be >= 1")
        self.space = as_space(graph)
        self.graph = getattr(self.space, "graph", None)
        self.universe = self.space.feature_universe()
        self.block_rows = int(block_rows)
        self.times: list[float] = []
        self.histogram = StreamingHistogram(half_bins=half_bins)
        self.n_consumed = 0
        self._seen = _CanonicalKeySet()
        self._rows: list[np.ndarray] = []  # flat int32 canonical rows
        self._pruned: tuple[list, list[np.ndarray]] | None = None
        self._pruned_rows = -1

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        self.n_consumed += len(batch)
        idx = self._seen.filter_new(batch.keys, fresh)
        if idx.size == 0:
            return
        enc = [np.frombuffer(batch.keys[i], dtype=np.int32)
               for i in idx]
        # Universe names come from the *decoded* canonical candidates,
        # so stored rows and candidate features stay consistent even if
        # a caller ever feeds non-canonical schedules.
        self.universe.add(self.space.decode_batch(np.stack(enc)))
        self._rows.extend(enc)
        t_new = np.asarray(batch.times)[idx]
        self.times.extend(float(t) for t in t_new)
        self.histogram.add(t_new)
        self._pruned = None

    # -- the streamed corpus (the ``histograms=`` protocol) ---------------
    @property
    def n_rows(self) -> int:
        return len(self._rows)

    def times_array(self) -> np.ndarray:
        return np.asarray(self.times, dtype=np.float64)

    def _encoded_blocks(self):
        for lo in range(0, len(self._rows), self.block_rows):
            yield np.stack(self._rows[lo:lo + self.block_rows])

    def _discover(self) -> tuple[list, list[np.ndarray]]:
        """(pruned features, per-feature value grids), cached per corpus
        length: one blockwise min/max fold over the candidate features
        replaces ``FeatureBasis.matrix()``'s in-memory pruning."""
        if self._pruned is not None \
                and self._pruned_rows == len(self._rows):
            return self._pruned
        cands = self.universe.candidate_features()
        lo = hi = None
        for enc in self._encoded_blocks():
            X = self.space.apply_features(
                self.space.decode_batch(enc), cands)
            if X.size and (X.min() < 0 or X.max() > 1):
                raise ValueError(
                    "histogram sinks require binary 0/1 features")
            bl, bh = X.min(axis=0), X.max(axis=0)
            lo = bl if lo is None else np.minimum(lo, bl)
            hi = bh if hi is None else np.maximum(hi, bh)
        keep = np.flatnonzero(lo != hi) if lo is not None \
            else np.zeros(0, dtype=np.int64)
        if keep.size == 0:
            raise DegenerateFeatureSpaceError(
                f"streamed corpus of {len(self._rows)} "
                "implementation(s) has no discriminating features "
                "after constant-column pruning; at least 2 distinct "
                "candidates are required")
        feats = [cands[j] for j in keep]
        grids = [np.array([0.0, 1.0]) for _ in feats]
        self._pruned = (feats, grids)
        self._pruned_rows = len(self._rows)
        return self._pruned

    def feature_list(self) -> list:
        """Pruned candidate features — matches what
        ``DatasetSink.matrix().features`` lists on an equal corpus."""
        return self._discover()[0]

    def value_grids(self) -> list[np.ndarray]:
        """Per-feature value grids for :class:`~repro.rules.trees.
        ClassCountHistogram` (binary 0/1 indicators here)."""
        return self._discover()[1]

    def blocks(self):
        """Feature blocks (int8, ``block_rows`` rows each) over the
        pruned features — decoded and featurized on the fly."""
        feats, _ = self._discover()
        for enc in self._encoded_blocks():
            yield self.space.apply_features(
                self.space.decode_batch(enc), feats)

    def distill(self, **kwargs):
        """:func:`repro.rules.distill` on the streamed corpus, through
        the out-of-core ``histograms=`` seam — the feature matrix is
        never materialized."""
        from repro.rules.pipeline import distill
        return distill(self, histograms=self, **kwargs)

    def merge(self, other: "HistogramSink") -> "HistogramSink":
        """Fold another host's streamed corpus in (in place).

        First-appearance dedup against ``self``: only times of rows
        unseen here fold into the doubling histogram, so the merged
        sink equals one sink that consumed both hosts' batches in
        sequence. ``StreamingHistogram.merge`` stays for genuinely
        disjoint shards; here overlap must not double-count.
        """
        if not isinstance(other, HistogramSink):
            raise TypeError(f"expected HistogramSink, got "
                            f"{type(other).__name__}")
        if self.space.name != other.space.name:
            raise ValueError(
                f"cannot merge sink over {other.space.name!r} into "
                f"sink over {self.space.name!r}")
        self.n_consumed += other.n_consumed
        if not other._rows:
            return self
        keys = [r.tobytes() for r in other._rows]
        idx = self._seen.filter_new(keys,
                                    np.ones(len(keys), dtype=bool))
        if idx.size:
            enc = [other._rows[i] for i in idx]
            self.universe.add(self.space.decode_batch(np.stack(enc)))
            self._rows.extend(enc)
            t_new = np.asarray(other.times)[idx]
            self.times.extend(float(t) for t in t_new)
            self.histogram.add(t_new)
            self._pruned = None
        return self


class TraceSink:
    """Per-round trace: what was chosen, what was fresh, running best.

    ``rounds[i]`` is a dict with ``round`` (the 0-based round index —
    the driver calls each sink exactly once per round, so this is the
    same numbering the driver's ``driver.round`` telemetry spans
    carry), ``keys`` (canonical cache keys of the round's batch, in
    proposal order), ``n_fresh``, and ``best`` (the minimum time
    observed up to and including that round). Canonical keys make
    traces comparable across evaluation backends — the cross-backend
    determinism tests assert exact equality of the key streams.
    """

    def __init__(self, graph: "Graph | DesignSpace | None" = None):
        self.rounds: list[dict] = []
        self._best = float("inf")

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        if len(batch):
            self._best = min(self._best, float(np.min(batch.times)))
        self.rounds.append({
            "round": len(self.rounds),
            "keys": tuple(batch.keys),
            "n_fresh": int(np.count_nonzero(fresh)),
            "best": self._best,
        })

    def key_stream(self, rounds: bool = False) -> tuple:
        """All chosen canonical keys, round-concatenated (for equality).

        The default shape is unchanged (a flat tuple of keys);
        ``rounds=True`` pairs every key with its round index —
        ``((round, key), ...)`` — so consumers can line the choice
        stream up against round-indexed telemetry spans.
        """
        if rounds:
            return tuple((r["round"], k)
                         for r in self.rounds for k in r["keys"])
        return tuple(k for r in self.rounds for k in r["keys"])


class TelemetrySink:
    """The obs-backed sink: stream per-round markers into the active
    telemetry registry (:mod:`repro.obs`).

    Emits one ``sink.round`` instant event per consumed batch (with
    the same 0-based round numbering as :class:`TraceSink` and the
    driver's ``driver.round`` spans — each sink sees exactly one
    ``consume`` per round), bumps the ``sink.consumed`` /
    ``sink.fresh`` counters, and tracks the running best as the
    ``sink.best`` gauge. Registered as ``"telemetry"`` in
    :data:`SINKS`, so ``SearchDriver(..., sinks=["telemetry"])`` puts
    round markers in a trace without any bespoke sink code. A no-op
    under the disabled default registry.
    """

    def __init__(self, graph: "Graph | DesignSpace | None" = None):
        self.n_rounds = 0
        self._best = float("inf")

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        from repro import obs
        tel = obs.current()
        if tel.enabled:
            n_fresh = int(np.count_nonzero(fresh))
            if len(batch):
                self._best = min(self._best,
                                 float(np.min(batch.times)))
            tel.event("sink.round", round=self.n_rounds, n=len(batch),
                      n_fresh=n_fresh,
                      best=self._best if self._best < float("inf")
                      else None)
            tel.counter("sink.consumed").add(len(batch))
            tel.counter("sink.fresh").add(n_fresh)
            if self._best < float("inf"):
                tel.gauge("sink.best").set(self._best)
        self.n_rounds += 1


# -- the registry -------------------------------------------------------------

SINKS: dict[str, Callable[..., Sink]] = {}
"""Sink factories: name -> ``factory(graph, **kwargs) -> sink``."""


def register_sink(name: str, factory: Callable[..., Sink]) -> None:
    """Add a sink factory to the :data:`SINKS` registry."""
    SINKS[name] = factory


register_sink("dataset", DatasetSink)
register_sink("histogram", HistogramSink)
register_sink("trace", TraceSink)
register_sink("telemetry", TelemetrySink)


def make_sink(sink: str, graph: "Graph | DesignSpace",
              **kwargs) -> Sink:
    """Construct a sink by registry name."""
    try:
        factory = SINKS[sink]
    except KeyError:
        raise ValueError(
            f"unknown sink {sink!r}; registered: {sorted(SINKS)}"
        ) from None
    return factory(graph, **kwargs)
