"""Search-driver benchmarks: acquisition races + streaming sinks.

``acquisition_benches`` races the :data:`repro.driver.ACQUISITIONS`
registry on halo3d at an equal 300-discrete-event-simulation budget
(``sim_budget``, batch_size=1 — the exact-cap configuration of the
PR 4 ``screen_*`` rows), all through the same ``SurrogateGuided``
boosted-surrogate strategy so the *only* difference between rows is
how the candidate pool is ranked:

  * ``argmin_topk`` — the original predicted-time screening
    (baseline; reproduces the PR 4 ``screen_boost`` numbers exactly);
  * ``ucb`` (beta=0.5) — the exploring operating point: spends
    simulations on uncertain candidates, trading screening Spearman
    for a better best-found makespan;
  * ``ei_greedy`` (xi=-0.08) — exploitation-leaning expected
    improvement: mean-first with per-tree ensemble uncertainty as the
    tie-breaker, which *raises* screening Spearman above argmin;
  * ``ei_balanced`` (xi=-0.15) — the both-targets point: matches the
    0.80 screening Spearman *and* finds the ucb-grade best makespan.

``sink_benches`` measures what the streaming ``DatasetSink`` buys:
the distillation-side featurize stage drops to zero (the corpus was
folded batch-by-batch during the search) with a byte-identical
feature matrix.
"""
from __future__ import annotations

import time

import numpy as np

import repro.rules as R
import repro.search as S
from repro.core.dag import halo3d_dag
from repro.driver import DatasetSink, SearchDriver

ACQ_SIMS = 300          # equal simulation budget (matches screen_*)

# (row tag, registry name, kwargs) — one ACQUISITIONS entry per row.
ACQ_CONFIGS = (
    ("argmin_topk", "argmin_topk", {}),
    ("ucb", "ucb", {"beta": 0.5}),
    ("ei_greedy", "expected_improvement", {"xi": -0.08}),
    ("ei_balanced", "expected_improvement", {"xi": -0.15}),
)


def _acq_race(tag: str, name: str, kwargs: dict) -> tuple[dict, list[str]]:
    g = halo3d_dag()
    strat = S.SurrogateGuided(g, 2, seed=0, surrogate="boost")
    ev = S.make_evaluator(g, "vectorized")
    t0 = time.perf_counter()
    res = SearchDriver(g, strat, evaluator=ev, budget=None,
                       sim_budget=ACQ_SIMS, batch_size=1,
                       acquisition=name, acquisition_kwargs=kwargs).run()
    wall = (time.perf_counter() - t0) / max(1, res.cache_misses) * 1e6
    ev.close()
    q = strat.screening_quality()
    stats = {"spearman": q["spearman"], "best": res.best()[1]}
    params = "/".join(f"{k}={v}" for k, v in kwargs.items()) or "default"
    rows = [
        f"acq_{tag}_halo3d_spearman,{wall:.2f},"
        f"{q['spearman']:.3f} ({params})",
        f"acq_{tag}_halo3d_best_us,{wall:.2f},{res.best()[1] * 1e6:.2f}",
        f"acq_{tag}_halo3d_sims,{wall:.2f},"
        f"{res.cache_misses}_of_{ACQ_SIMS}",
    ]
    return stats, rows


def acquisition_benches() -> list[str]:
    rows: list[str] = []
    stats: dict[str, dict] = {}
    for tag, name, kwargs in ACQ_CONFIGS:
        stats[tag], r = _acq_race(tag, name, kwargs)
        rows += r
    base = stats["argmin_topk"]
    unc = {t: s for t, s in stats.items() if t != "argmin_topk"}
    best_rho = max(unc.values(), key=lambda s: s["spearman"])
    best_mk = min(unc.values(), key=lambda s: s["best"])
    rows += [
        f"acq_best_spearman_vs_argmin,0.00,"
        f"{best_rho['spearman'] - base['spearman']:+.3f}",
        f"acq_best_makespan_vs_argmin,0.00,"
        f"{best_mk['best'] / base['best']:.4f}",
    ]
    return rows


def sink_benches() -> list[str]:
    """Streaming DatasetSink vs post-hoc featurize-from-scratch."""
    g = halo3d_dag()
    sink = DatasetSink(g)
    res = SearchDriver(g, S.RandomSearch(g, 2, seed=0), budget=1000,
                       batch_size=64, backend="vectorized",
                       sinks=[sink]).run()
    t0 = time.perf_counter()
    rep_stream = sink.distill()
    wall_stream = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep_batch = R.distill(res)
    wall_batch = time.perf_counter() - t0
    fm_s, _, _ = sink.dataset()
    fm_b = rep_batch.feature_matrix
    identical = bool(fm_s.features == fm_b.features
                     and fm_s.X.tobytes() == fm_b.X.tobytes()
                     and np.array_equal(rep_stream.labeling.labels,
                                        rep_batch.labeling.labels))
    featurize_ms = rep_batch.stage_seconds["featurize"] * 1e3
    return [
        f"driver_sink_stream_identical,{wall_stream * 1e6:.2f},"
        f"{identical}",
        f"driver_sink_distill_ms,{wall_stream * 1e6:.2f},"
        f"{wall_stream * 1e3:.1f}",
        f"driver_sink_batch_distill_ms,{wall_batch * 1e6:.2f},"
        f"{wall_batch * 1e3:.1f}",
        f"driver_sink_featurize_skipped_ms,{wall_stream * 1e6:.2f},"
        f"{featurize_ms:.1f}",
    ]


def driver_benches() -> list[str]:
    return acquisition_benches() + sink_benches()


if __name__ == "__main__":
    for row in driver_benches():
        print(row)
