"""Unified design-space search subsystem.

One strategy protocol (:class:`SearchStrategy`); strategies from
exhaustive enumeration to the surrogate-screened two-stage search and
the greedy→MCTS→surrogate portfolio; the pluggable evaluation engine
(:mod:`repro.engine`: serial, vectorized, process-pool, and wall-clock
backends behind one memoized contract, selected via
``run_search(backend=...)``); and the :func:`run_search` pipeline that
turns any strategy × backend into the (features, labels, times) dataset
the rules pipeline consumes. See README.md in this package and in
:mod:`repro.engine` for the contracts.
"""
from repro.engine import (BACKENDS, BatchEvaluator, EvaluatorBase,
                          ExecutorEvaluator, PoolEvaluator,
                          VectorizedEvaluator, canonical_key,
                          make_evaluator, register_backend)
from repro.search.mcts import MCTSSearch
from repro.search.pipeline import SearchResult, run_search
from repro.search.strategy import (ExhaustiveSearch, GreedyCostModel,
                                   RandomSearch, SearchStrategy,
                                   eligible_items, random_schedule)
from repro.search.surrogate import (SURROGATES, GradientBoostedSurrogate,
                                    PortfolioSearch, RidgeSurrogate,
                                    SurrogateGuided, make_surrogate,
                                    register_surrogate, spearman)

__all__ = [
    "BACKENDS", "BatchEvaluator", "EvaluatorBase", "ExecutorEvaluator",
    "PoolEvaluator", "VectorizedEvaluator", "canonical_key",
    "make_evaluator", "register_backend",
    "MCTSSearch",
    "SearchResult", "run_search",
    "ExhaustiveSearch", "GreedyCostModel", "RandomSearch",
    "SearchStrategy", "eligible_items", "random_schedule",
    "SURROGATES", "GradientBoostedSurrogate", "PortfolioSearch",
    "RidgeSurrogate", "SurrogateGuided", "make_surrogate",
    "register_surrogate", "spearman",
]
