"""jit'd public wrapper for the pack kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.pack.kernel import pack as _pack_kernel
from repro.kernels.pack.ref import pack_ref

__all__ = ["pack", "pack_ref"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def pack(x: jax.Array, idx: jax.Array,
         interpret: bool | None = None) -> jax.Array:
    interpret = _interpret_default() if interpret is None else interpret
    return _pack_kernel(x, idx, interpret=interpret)
