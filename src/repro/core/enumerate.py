"""Exhaustive enumeration of the implementation space.

Enumerates every (topological traversal x stream assignment) of a program
DAG, pruning stream-bijection-equivalent implementations by only emitting
canonical stream labelings (streams first used in increasing order,
paper §III-C2). Used for the paper's "2036 implementations" style
exhaustive baselines (Fig. 1) and for Table V generalization accuracy.
"""
from __future__ import annotations

from typing import Iterator

from repro.core.dag import BoundOp, Graph, OpKind, Schedule


def enumerate_schedules(graph: Graph, n_streams: int) -> Iterator[Schedule]:
    """Yield every canonical implementation of ``graph``.

    Canonical form: when a GPU op is bound, it may use any stream already in
    use, or the lowest-numbered unused stream (if any remain). This emits
    exactly one representative per stream-bijection equivalence class.
    """
    items: list[BoundOp] = []
    scheduled: set[str] = set()

    def rec() -> Iterator[Schedule]:
        if len(scheduled) == len(graph.ops):
            yield Schedule(tuple(items))
            return
        for name in graph.eligible(scheduled):
            op = graph.ops[name]
            if op.kind is OpKind.GPU:
                used = {i.stream for i in items if i.stream is not None}
                options = sorted(used)
                if len(used) < n_streams:
                    options.append(len(used))  # first unused stream
                for s in options:
                    items.append(BoundOp(name, s))
                    scheduled.add(name)
                    yield from rec()
                    scheduled.remove(name)
                    items.pop()
            else:
                items.append(BoundOp(name))
                scheduled.add(name)
                yield from rec()
                scheduled.remove(name)
                items.pop()

    yield from rec()


def count_schedules(graph: Graph, n_streams: int) -> int:
    return sum(1 for _ in enumerate_schedules(graph, n_streams))
