"""Synthetic token data pipeline — deterministic and stateless.

Production posture: the batch for step ``s`` is a pure function of
``(seed, s)``, so fault-tolerant restart needs only the step counter (no
opaque iterator state in checkpoints) and elastic re-sharding needs only
the new mesh. Two generators:

  * ``lm_batch``     — iid tokens (markov-ish mixture for non-trivial
    statistics; loss curves move under training).
  * ``packed_batch`` — variable-length documents packed to seq_len with
    EOS separators + loss mask (-1 labels on pad), the layout real LM
    pipelines produce.

Frontend stubs ([audio]/[vlm]): deterministic pseudo-embeddings keyed by
(seed, step) per the brief (precomputed frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 512
    global_batch: int = 8
    vocab: int = 32_000
    eos: int = 0
    packed: bool = False
    mean_doc_len: int = 192


def _key(cfg: DataConfig, step, salt: int) -> jax.Array:
    k = jax.random.PRNGKey(cfg.seed)
    k = jax.random.fold_in(k, salt)
    return jax.random.fold_in(k, step)


def lm_batch(cfg: DataConfig, step) -> dict:
    """Tokens with a repetition structure a model can learn."""
    k1, k2, k3 = jax.random.split(_key(cfg, step, 1), 3)
    b, s = cfg.global_batch, cfg.seq_len
    base = jax.random.randint(k1, (b, s), 0, cfg.vocab)
    # Mixture: with p=0.5 copy the previous token + 1 (learnable rule).
    copy = jnp.concatenate(
        [base[:, :1], (base[:, :-1] + 1) % cfg.vocab], axis=1)
    gate = jax.random.bernoulli(k2, 0.5, (b, s))
    tokens = jnp.where(gate, copy, base)
    labels = jnp.concatenate(
        [tokens[:, 1:],
         jax.random.randint(k3, (b, 1), 0, cfg.vocab)], axis=1)
    return {"tokens": tokens, "labels": labels}


def packed_batch(cfg: DataConfig, step) -> dict:
    """Documents packed to seq_len; EOS-separated; pad labels = -1."""
    k1, k2 = jax.random.split(_key(cfg, step, 2), 2)
    b, s = cfg.global_batch, cfg.seq_len
    tokens = jax.random.randint(k1, (b, s), 1, cfg.vocab)
    # Deterministic doc boundaries: geometric-ish via uniform threshold.
    u = jax.random.uniform(k2, (b, s))
    boundary = u < (1.0 / cfg.mean_doc_len)
    tokens = jnp.where(boundary, cfg.eos, tokens)
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((b, 1), cfg.eos)], axis=1)
    # No loss on predicting across a document boundary target pad.
    labels = jnp.where(labels == cfg.eos, -1, labels)
    return {"tokens": tokens, "labels": labels}


def frontend_batch(cfg: DataConfig, step, model_cfg: ModelConfig) -> dict:
    fe = model_cfg.frontend
    k = _key(cfg, step, 3)
    emb = jax.random.normal(
        k, (cfg.global_batch, fe.n_positions, fe.d_frontend),
        jnp.float32)
    return {"frontend": emb}


def batch_for(cfg: DataConfig, step, model_cfg: ModelConfig) -> dict:
    out = packed_batch(cfg, step) if cfg.packed else lm_batch(cfg, step)
    if model_cfg.frontend is not None:
        out.update(frontend_batch(cfg, step, model_cfg))
    return out
