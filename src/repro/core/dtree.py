"""Compatibility shim: trees now live in :mod:`repro.rules.trees`.

``DecisionTree`` / ``algorithm1`` moved into the rules distillation
subsystem — :mod:`repro.rules` — where the vectorized sort-based split
kernel is shared between the design-rule tree, the warm-started
Algorithm-1 sweep, and the gradient-boosted surrogate's
:class:`~repro.rules.trees.RegressionTree`. Import from
:mod:`repro.rules` (or keep importing from here / :mod:`repro.core`;
both stay supported, with a :class:`DeprecationWarning` so the shim
can eventually be deleted — every name here *is* the
:mod:`repro.rules.trees` object, asserted by tests/test_shims.py).
"""
import warnings

warnings.warn(
    "repro.core.dtree is a deprecated shim; import DecisionTree/"
    "algorithm1/... from repro.rules (new home: repro.rules.trees)",
    DeprecationWarning, stacklevel=2)

from repro.rules.trees import (DecisionTree, Presort, RegressionTree,
                               TreeNode, TreeSearchTrace, algorithm1)

__all__ = ["DecisionTree", "Presort", "RegressionTree", "TreeNode",
           "TreeSearchTrace", "algorithm1"]
