"""internvl2-2b [vlm]: InternViT + InternLM2 backbone; the ViT frontend
is a STUB — input_specs() provides precomputed patch embeddings
(batch, 256, 1024) [arXiv:2404.16821]."""
from repro.models.config import FrontendConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92553, mlp="swiglu",
    frontend=FrontendConfig(kind="vision", n_positions=256,
                            d_frontend=1024),
)

REDUCED = ModelConfig(
    name="internvl2-2b-reduced", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, mlp="swiglu",
    frontend=FrontendConfig(kind="vision", n_positions=8, d_frontend=32),
)
