"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained
experts [arXiv:2401.06066]."""
from repro.models.config import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, mlp="swiglu",
    moe=MoeConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
)

REDUCED = ModelConfig(
    name="deepseek-moe-16b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, mlp="swiglu",
    moe=MoeConfig(capacity_factor=8.0, n_experts=8, top_k=2, n_shared=1, d_expert=96),
)
