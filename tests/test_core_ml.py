"""Labels, features, decision tree, and rules — unit + property tests."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C
from repro.rules.labels import (find_peaks, label_times, peak_prominences,
                                step_convolve)


# -- labels -------------------------------------------------------------------

def test_label_synthetic_steps():
    """Three well-separated performance plateaus -> three classes."""
    rng = np.random.default_rng(0)
    times = np.concatenate([
        1.00 + 0.01 * rng.random(400),
        1.50 + 0.01 * rng.random(300),
        2.00 + 0.01 * rng.random(300),
    ])
    rng.shuffle(times)
    lab = label_times(times)
    # The two 0.5-wide plateau jumps must be detected (the 98th-pct
    # prominence filter may keep an occasional extra small peak, which
    # the paper tolerates too — class count is not known a priori).
    assert 3 <= lab.n_classes <= 5
    assert any(abs(b - 399) <= 10 for b in lab.boundaries)
    assert any(abs(b - 699) <= 10 for b in lab.boundaries)
    # class ids nondecreasing along the sorted order
    pred = lab.labels[np.argsort(times, kind="stable")]
    assert (np.diff(pred) >= 0).all()


def test_label_single_class_flat_data():
    times = np.linspace(1.0, 1.001, 300)  # no structure
    lab = label_times(times)
    assert lab.n_classes <= 2  # nothing prominent to split on


def test_step_convolve_peak_at_jump():
    a = np.array([1.0] * 50 + [2.0] * 50)
    c = step_convolve(a, 5)
    assert np.argmax(c) in (49, 50)


def test_step_convolve_matches_explicit_kernel():
    """Lock the §IV-A kernel: -1 on [-r, 0] (r+1 taps), +1 on [1, r]
    (r taps) — cross-checked against an explicit correlation and a
    brute-force double sum (and scipy, when importable)."""
    rng = np.random.default_rng(5)
    a = np.sort(rng.random(100))
    for r in (1, 3, 7):
        ours = step_convolve(a, r)
        kernel = np.array([-1.0] * (r + 1) + [1.0] * r)
        ref = np.zeros_like(a)
        ref[r:a.size - r] = np.correlate(a, kernel, mode="valid")
        np.testing.assert_allclose(ours, ref, atol=1e-12)
        for i in range(r, a.size - r):
            want = a[i + 1:i + r + 1].sum() - a[i - r:i + 1].sum()
            assert abs(ours[i] - want) < 1e-12
        try:
            import scipy.signal as sps
        except ImportError:
            continue
        sref = np.zeros_like(a)
        sref[r:a.size - r] = sps.correlate(a, kernel, mode="valid")
        np.testing.assert_allclose(ours, sref, atol=1e-12)


def test_step_convolve_too_short_is_zero():
    # The kernel spans 2r+1 taps; anything shorter has no valid window.
    assert (step_convolve(np.arange(6, dtype=float), 3) == 0).all()


def test_find_peaks_matches_scipy():
    scipy_signal = pytest.importorskip("scipy.signal")
    rng = np.random.default_rng(3)
    x = rng.random(500)
    ours = find_peaks(x)
    ref, _ = scipy_signal.find_peaks(x)
    np.testing.assert_array_equal(ours, ref)
    ours_p = peak_prominences(x, ours)
    ref_p = scipy_signal.peak_prominences(x, ref)[0]
    np.testing.assert_allclose(ours_p, ref_p)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(0.1, 10.0), min_size=10, max_size=300))
def test_label_properties(times):
    lab = label_times(np.array(times))
    assert lab.labels.shape == (len(times),)
    assert lab.n_classes >= 1
    assert lab.labels.max() == lab.n_classes - 1
    # class ranges must tile the sorted data in order
    ranges = lab.class_ranges()
    for (lo1, hi1), (lo2, _hi2) in zip(ranges, ranges[1:]):
        assert lo1 <= hi1 <= lo2


# -- features -----------------------------------------------------------------

@pytest.fixture(scope="module")
def spmv_space():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    return g, scheds


def test_feature_values_match_sequences(spmv_space):
    g, scheds = spmv_space
    fm = C.featurize(g, scheds)
    for i, s in enumerate(list(scheds)[:20]):
        names = C.expanded_names(g, s)
        pos = {n: j for j, n in enumerate(names)}
        streams = s.streams()
        for j, f in enumerate(fm.features):
            if f.kind == "order":
                if f.u in pos and f.v in pos:
                    assert fm.X[i, j] == (pos[f.u] < pos[f.v])
                else:
                    assert fm.X[i, j] == 0
            else:
                assert fm.X[i, j] == (streams.get(f.u) == streams.get(f.v))


def test_constant_features_dropped(spmv_space):
    g, scheds = spmv_space
    fm = C.featurize(g, scheds)
    for j in range(fm.X.shape[1]):
        assert fm.X[:, j].min() != fm.X[:, j].max()
    # DAG-implied orderings must be gone: Pack always before PostSend
    assert not any(f.kind == "order" and {f.u, f.v} == {"Pack", "PostSend"}
                   for f in fm.features)


def test_featurize_like_consistent_basis(spmv_space):
    g, scheds = spmv_space
    fm = C.featurize(g, scheds)
    X2 = C.featurize_like(g, scheds, fm)
    np.testing.assert_array_equal(fm.X, X2)


def test_featurize_degenerate_corpus_raises(spmv_space):
    """A corpus with <= 1 distinct schedule prunes every column; that
    must be a nameable error, not a 0-feature matrix handed to the
    tree fit."""
    g, scheds = spmv_space
    s = scheds[0]
    for corpus in ([], [s], [s, s, s]):
        with pytest.raises(C.DegenerateFeatureSpaceError,
                           match="distinct"):
            C.featurize(g, corpus)
    # the guard is a ValueError subclass, so legacy handlers still work
    assert issubclass(C.DegenerateFeatureSpaceError, ValueError)
    # two distinct schedules are the minimum viable corpus
    assert C.featurize(g, scheds[:2]).features


def test_feature_basis_incremental_equals_batch(spmv_space):
    """Absorbing the corpus in chunks must give the same basis/matrix
    as one featurize call over everything."""
    g, scheds = spmv_space
    basis = C.FeatureBasis(g)
    basis.add(scheds[:10]).add(scheds[10:75]).add(scheds[75:])
    inc = basis.matrix()
    ref = C.featurize(g, list(scheds))
    assert inc.features == ref.features
    np.testing.assert_array_equal(inc.X, ref.X)


# -- decision tree --------------------------------------------------------------

def test_dtree_fits_xor():
    X = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], dtype=float)
    y = np.array([0, 1, 1, 0])
    t = C.DecisionTree(max_leaf_nodes=4).fit(X, y)
    assert t.training_error(X, y) == 0.0
    np.testing.assert_array_equal(t.predict(X), y)


def test_dtree_max_leaves_respected():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 2, size=(200, 8)).astype(float)
    y = rng.integers(0, 3, size=200)
    for k in (2, 3, 5, 8):
        t = C.DecisionTree(max_leaf_nodes=k).fit(X, y)
        assert t.n_leaves() <= k


def test_dtree_balanced_weights_protect_minority():
    # 95/5 imbalance, single separating feature: balanced weights must
    # split rather than predict the majority everywhere.
    X = np.array([[0.0]] * 95 + [[1.0]] * 5)
    y = np.array([0] * 95 + [1] * 5)
    t = C.DecisionTree(max_leaf_nodes=2).fit(X, y)
    assert t.predict(np.array([[1.0]]))[0] == 1


def test_algorithm1_reaches_zero_error():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    trace = C.TreeSearchTrace([], [], [])
    tree = C.algorithm1(fm.X, lab.labels, trace=trace)
    assert tree.training_error(fm.X, lab.labels) == 0.0
    # Alg. 1 invariant: max_depth == max_leaf_nodes - 1 each trial
    assert all(d <= m - 1 for m, d in
               zip(trace.max_leaf_nodes, trace.depths))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_dtree_separable_property(seed):
    """On data where the label is a function of the features, enough
    leaves always reach zero training error."""
    rng = np.random.default_rng(seed)
    X = rng.integers(0, 2, size=(60, 5)).astype(float)
    y = (X[:, 0] + 2 * X[:, 1] * X[:, 2]).astype(int)
    t = C.DecisionTree(max_leaf_nodes=64).fit(X, y)
    assert t.training_error(X, y) == 0.0


# -- rules ---------------------------------------------------------------------

def test_rule_text_matches_paper_style(spmv_space):
    g, scheds = spmv_space
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    tree = C.algorithm1(fm.X, lab.labels)
    rulesets = C.extract_rulesets(tree, fm.features)
    texts = [r.text() for rs in rulesets for r in rs.rules]
    assert any("before" in t for t in texts)


def test_annotate_over_and_under_constrained():
    f1 = C.Feature("order", "a", "b")
    f2 = C.Feature("order", "b", "c")
    canon = [C.RuleSet([C.Rule(f1, 1)], class_label=0, n_samples=10,
                       pure=True)]
    over = C.RuleSet([C.Rule(f1, 1), C.Rule(f2, 0)], class_label=0,
                     n_samples=5, pure=True)
    under = C.RuleSet([C.Rule(f2, 0)], class_label=0, n_samples=5,
                      pure=True)
    C.annotate_vs_canonical([over, under], canon)
    assert not over.insufficient and len(over.extraneous) == 1
    assert under.insufficient
