"""Straggler detection: per-rank step-time accounting + slow-rank report.

On a real pod every host records its step wall-time (the bulk-
synchronous step makes per-host timing meaningful: a straggler drags the
collective). The monitor flags ranks persistently slower than
``threshold`` x median and recommends mitigation (evict + elastic
re-mesh, see repro.ft.elastic). Tests feed synthetic timings.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics


@dataclasses.dataclass
class StragglerReport:
    step: int
    median_s: float
    slow_ranks: dict[int, float]  # rank -> seconds


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, window: int = 20,
                 min_observations: int = 5):
        self.threshold = threshold
        self.window = window
        self.min_obs = min_observations
        self._times: dict[int, collections.deque] = {}
        self._last_step = 0

    def record(self, rank: int, step: int, seconds: float) -> None:
        self._times.setdefault(
            rank, collections.deque(maxlen=self.window)).append(seconds)
        self._last_step = max(self._last_step, step)

    def report(self) -> StragglerReport | None:
        means = {r: statistics.fmean(t) for r, t in self._times.items()
                 if len(t) >= self.min_obs}
        if len(means) < 2:
            return None
        med = statistics.median(means.values())
        slow = {r: m for r, m in means.items()
                if m > self.threshold * med}
        if not slow:
            return None
        return StragglerReport(self._last_step, med, slow)
