"""jit'd public wrapper for the flash-attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

__all__ = ["mha", "attention_ref"]


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
        causal: bool = True, block_q: int = 128, block_k: int = 128,
        interpret: bool | None = None) -> jax.Array:
    """Multi-head attention. q: (B, H, Sq, D); k, v: (B, H, Skv, D).

    Pads Sq/Skv up to the block sizes (padded kv masked by position,
    padded q rows sliced off) and D up to the 128-lane tile.
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, h, sq, d = q.shape
    skv = k.shape[2]
    pq = (-sq) % block_q
    pk = (-skv) % block_k
    pd = (-d) % 128
    if pd:
        zq = ((0, 0), (0, 0), (0, 0), (0, pd))
        q, k, v = (jnp.pad(x, zq) for x in (q, k, v))
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    dp = d + pd
    qf = q.reshape(b * h, sq + pq, dp)
    kf = k.reshape(b * h, skv + pk, dp)
    vf = v.reshape(b * h, skv + pk, dp)
    # With right-aligned causal masking, padded q rows sit BELOW the real
    # rows and padded kv columns sit after the diagonal — the causal mask
    # must exclude padded kv for real queries, which it does because
    # padded kv positions > every real query position when pk rows are
    # appended at the end. Scale of padded columns is irrelevant for
    # non-causal ONLY if masked; so non-causal inputs must be pre-padded
    # by the caller (ops asserts).
    assert causal or (pq == 0 and pk == 0), \
        "non-causal requires block-aligned shapes"
    # Right-aligned causal offset is computed from padded shapes; equal
    # padding on both sides preserves it (block_q == block_k and
    # sq == skv, the training/prefill self-attention case).
    assert not causal or pq == pk, \
        "causal padding requires pq == pk (use equal blocks, sq == skv)"
    out = flash_attention(qf, kf, vf, causal=causal, block_q=block_q,
                          block_k=block_k, scale=d ** -0.5,
                          interpret=interpret)
    out = out.reshape(b, h, sq + pq, dp)
    return out[:, :, :sq, :d]
