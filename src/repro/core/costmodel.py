"""Analytic machine model: simulate an expanded schedule's makespan.

The container is CPU-only, so MCTS needs a deterministic, fast objective
that reflects *TPU-class* hardware. This discrete-event model simulates:

  * a host control thread executing the expanded item sequence in order,
  * N device "streams" (serialization chains; on TPU, the compute stream
    and DMA/ICI channels) with FIFO semantics,
  * asynchronous point-to-point transfers with rendezvous semantics
    (a transfer starts once both the local post and the symmetric remote
    post have happened; ranks are modeled as symmetric, which is exact for
    the paper's uniform band SpMV and for bulk-synchronous LM steps),
  * CUDA-event-style sync ops as produced by :mod:`repro.core.sync`.

Durations come from op metadata (flops / HBM bytes / comm bytes) and the
:class:`Machine` roofline constants. Defaults are TPU v5e-like:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses

from repro.core.dag import CommRole, Graph, OpKind, Schedule
from repro.core.sync import ExpandedItem, expand


@dataclasses.dataclass(frozen=True)
class Machine:
    flops_per_s: float = 197e12       # bf16 peak per chip
    hbm_bytes_per_s: float = 819e9    # HBM bandwidth
    link_bytes_per_s: float = 50e9    # per-ICI-link
    launch_overhead_s: float = 5e-6   # async op launch cost on host
    cpu_op_s: float = 1e-6            # generic synchronous host op
    sync_op_s: float = 0.5e-6         # event record / wait bookkeeping
    comm_latency_s: float = 5e-6      # point-to-point latency

    def gpu_duration(self, flops: float, bytes_hbm: float) -> float:
        t = 0.0
        if flops:
            t = max(t, flops / self.flops_per_s)
        if bytes_hbm:
            t = max(t, bytes_hbm / self.hbm_bytes_per_s)
        return max(t, 1e-7)

    def transfer_duration(self, nbytes: float) -> float:
        return self.comm_latency_s + nbytes / self.link_bytes_per_s


@dataclasses.dataclass
class SimResult:
    makespan: float
    op_start: dict[str, float]
    op_end: dict[str, float]


def op_durations(graph: Graph, machine: Machine | None = None
                 ) -> dict[str, float]:
    """Duration of every DAG op under ``machine``.

    Schedule-independent, so batched evaluation
    (:class:`repro.engine.base.BatchEvaluator` and friends) computes this once
    and passes it to :func:`simulate` for every schedule in the batch.
    The expressions mirror the per-op fallback inside :func:`simulate`
    exactly, keeping batched results bit-identical to unbatched ones.
    """
    m = machine or Machine()
    out: dict[str, float] = {}
    for name, op in graph.ops.items():
        if op.duration is not None:
            out[name] = op.duration
        elif op.kind is OpKind.GPU:
            out[name] = m.gpu_duration(op.flops, op.bytes_hbm)
        else:
            out[name] = m.cpu_op_s
    return out


def simulate(graph: Graph, schedule: Schedule,
             machine: Machine | None = None,
             durations: dict[str, float] | None = None) -> SimResult:
    """Simulate the expanded schedule; return its makespan (seconds).

    ``durations`` optionally supplies precomputed per-op durations (from
    :func:`op_durations`) so batch callers skip the per-op roofline math.
    """
    m = machine or Machine()
    items: list[ExpandedItem] = expand(graph, schedule)

    cpu_t = 0.0
    stream_t: dict[int, float] = {}
    stream_wait: dict[int, float] = {}   # pending CSWE floor per stream
    event_t: dict[str, float] = {}       # recorded-op name -> event time
    op_start: dict[str, float] = {}
    op_end: dict[str, float] = {}

    # Rendezvous bookkeeping (symmetric-rank model). Multiple channels
    # (per-neighbor fine-grained DAGs) are keyed by the op-name suffix
    # after PostSend/PostRecv; the symmetric remote send for our recv on
    # channel s is our own send on the *twin* channel (l <-> r; same
    # channel when there is only one).
    post_send_t: dict[str, float] = {}
    post_recv_t: dict[str, float] = {}
    send_bytes: dict[str, float] = {}
    recv_bytes: dict[str, float] = {}
    _twin = {"_l": "_r", "_r": "_l",
             # 3-D halo faces: our recv on the -d face pairs with the
             # symmetric neighbor's +d send (== our own +d send).
             "_xn": "_xp", "_xp": "_xn", "_yn": "_yp", "_yp": "_yn",
             "_zn": "_zp", "_zp": "_zn"}

    def transfer_done(kind: str, suffix: str) -> float:
        if kind == "send":
            # Eager/buffered semantics: the send buffer is reusable once
            # the wire transfer finishes, independent of the remote post.
            assert suffix in post_send_t, "WaitSend before PostSend"
            return post_send_t[suffix] + \
                m.transfer_duration(send_bytes[suffix])
        twin = _twin.get(suffix, suffix)
        if twin not in post_send_t:
            twin = suffix
        assert twin in post_send_t and suffix in post_recv_t, \
            "WaitRecv before both posts - DAG should prevent this"
        return max(post_send_t[twin], post_recv_t[suffix]) + \
            m.transfer_duration(recv_bytes[suffix])

    for it in items:
        if it.kind == "CER":
            # Event enqueued on the producer's stream right after it: event
            # fires when everything currently in that stream completes.
            event_t[it.anchor] = stream_t.get(it.stream, 0.0)
            cpu_t += m.sync_op_s
            continue
        if it.kind == "CES":
            cpu_t += m.sync_op_s
            for w in it.waits:
                cpu_t = max(cpu_t, event_t[w])
            continue
        if it.kind == "CSWE":
            cpu_t += m.sync_op_s
            floor = max(event_t[w] for w in it.waits)
            s = it.stream
            stream_wait[s] = max(stream_wait.get(s, 0.0), floor)
            continue

        op = graph.ops[it.name]
        if op.kind is OpKind.GPU:
            cpu_t += m.launch_overhead_s  # async launch
            s = it.stream
            start = max(cpu_t, stream_t.get(s, 0.0),
                        stream_wait.pop(s, 0.0))
            dur = durations[it.name] if durations is not None else (
                op.duration if op.duration is not None else
                m.gpu_duration(op.flops, op.bytes_hbm))
            op_start[it.name] = start
            op_end[it.name] = start + dur
            stream_t[s] = start + dur
            continue

        # Synchronous CPU op.
        dur = durations[it.name] if durations is not None else (
            op.duration if op.duration is not None else m.cpu_op_s)
        op_start[it.name] = cpu_t
        if op.comm_role is CommRole.POST_SEND:
            cpu_t += dur
            sfx = it.name.removeprefix("PostSend")
            post_send_t[sfx] = cpu_t
            send_bytes[sfx] = op.comm_bytes
        elif op.comm_role is CommRole.POST_RECV:
            cpu_t += dur
            sfx = it.name.removeprefix("PostRecv")
            post_recv_t[sfx] = cpu_t
            recv_bytes[sfx] = op.comm_bytes
        elif op.comm_role is CommRole.WAIT_SEND:
            cpu_t += dur
            cpu_t = max(cpu_t, transfer_done(
                "send", it.name.removeprefix("WaitSend")))
        elif op.comm_role is CommRole.WAIT_RECV:
            cpu_t += dur
            cpu_t = max(cpu_t, transfer_done(
                "recv", it.name.removeprefix("WaitRecv")))
        else:
            cpu_t += dur
        op_end[it.name] = cpu_t

    makespan = max([cpu_t] + list(stream_t.values()))
    return SimResult(makespan=makespan, op_start=op_start, op_end=op_end)


def makespan(graph: Graph, schedule: Schedule,
             machine: Machine | None = None) -> float:
    return simulate(graph, schedule, machine).makespan
