"""Design-rule generation from a trained decision tree (paper §IV-D, §V).

Every root->leaf path is a *ruleset* for the leaf's majority performance
class. Feature decisions render to text exactly like the paper:

    order feature, went right (value 1):  "u before v"
    order feature, went left  (value 0):  "v before u"
    stream feature, right:                "u same stream as v"
    stream feature, left:                 "u different stream than v"

Rulesets from reduced searches are annotated against the canonical
(exhaustive-search) rulesets: *overconstrained* (extra harmless rules) or
*underconstrained* ("insufficient rules" — missing constraints), §V.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.features import Feature
from repro.rules.trees import DecisionTree


@dataclasses.dataclass(frozen=True)
class Rule:
    feature: Feature
    value: int  # 0 or 1

    def text(self) -> str:
        return self.feature.describe(self.value)

    def canonical_atom(self) -> tuple:
        """Normalized identity so negations/symmetries compare equal.

        order:(u,v,1) == "u before v"; order:(u,v,0) == "v before u" is a
        *different* atom. stream features are symmetric in (u,v) already
        (u < v by construction).
        """
        return (self.feature.kind, self.feature.u, self.feature.v,
                self.value)


@dataclasses.dataclass
class RuleSet:
    rules: list[Rule]
    class_label: int
    n_samples: int
    pure: bool                       # leaf contains a single class
    extraneous: list[Rule] = dataclasses.field(default_factory=list)
    insufficient: bool = False

    def atoms(self) -> frozenset:
        return frozenset(r.canonical_atom() for r in self.rules)

    def render(self) -> list[str]:
        out = [r.text() for r in self.rules]
        if self.insufficient:
            out.append("insufficient rules")
        return out


def extract_rulesets(tree: DecisionTree,
                     features: list[Feature]) -> list[RuleSet]:
    """One RuleSet per leaf, sorted by sample count (descending)."""
    out: list[RuleSet] = []
    for path, leaf in tree.paths():
        rules = [Rule(features[f], 1 if went_right else 0)
                 for (f, _t, went_right) in path]
        n_nonzero = int(np.count_nonzero(leaf.value))
        out.append(RuleSet(
            rules=rules,
            class_label=int(tree.classes_[leaf.majority_class()]),
            n_samples=leaf.n_samples,
            pure=n_nonzero <= 1,
        ))
    out.sort(key=lambda r: -r.n_samples)
    return out


def rules_by_class(rulesets: list[RuleSet]) -> dict[int, list[RuleSet]]:
    grouped: dict[int, list[RuleSet]] = {}
    for rs in rulesets:
        grouped.setdefault(rs.class_label, []).append(rs)
    return grouped


def annotate_vs_canonical(candidate: list[RuleSet],
                          canonical: list[RuleSet]) -> None:
    """Mark each candidate ruleset over/under-constrained (paper §V).

    A candidate ruleset R for class c is *consistent* with canonical
    ruleset C (same class) if C's atoms are a subset of R's — extra atoms
    in R are extraneous-but-harmless. If no canonical ruleset of the same
    class is a subset of R, R is underconstrained ("insufficient rules").
    """
    canon_by_class = rules_by_class(canonical)
    for rs in candidate:
        best_extra: list[Rule] | None = None
        for canon in canon_by_class.get(rs.class_label, []):
            if canon.atoms() <= rs.atoms():
                extra_atoms = rs.atoms() - canon.atoms()
                extra = [r for r in rs.rules
                         if r.canonical_atom() in extra_atoms]
                if best_extra is None or len(extra) < len(best_extra):
                    best_extra = extra
        if best_extra is None:
            rs.insufficient = True
            rs.extraneous = []
        else:
            rs.insufficient = False
            rs.extraneous = best_extra


# ---------------------------------------------------------------------------
# Table V: how well subset-derived rules generalize to the whole space.
# ---------------------------------------------------------------------------

def class_range_accuracy_loop(tree: DecisionTree,
                              X_full: np.ndarray,
                              times_full: np.ndarray,
                              class_ranges: list[tuple[float, float]]
                              ) -> float:
    """Reference (per-sample Python loop) for the vectorized version."""
    pred = tree.predict(X_full)
    times_full = np.asarray(times_full, dtype=np.float64)
    ok = 0
    for c, t in zip(pred, times_full):
        lo, hi = class_ranges[int(c)]
        if lo <= t <= hi:
            ok += 1
    return ok / max(1, len(times_full))


def class_range_accuracy(tree: DecisionTree,
                         X_full: np.ndarray,
                         times_full: np.ndarray,
                         class_ranges: list[tuple[float, float]]) -> float:
    """Fraction of implementations whose measured time falls within the
    time range of the class the tree assigns them (paper Table V).

    One batched tree descent plus a gather of the per-class (lo, hi)
    bounds; locked to :func:`class_range_accuracy_loop` by tests.
    """
    times_full = np.asarray(times_full, dtype=np.float64)
    if times_full.size == 0:
        return 0.0
    pred = tree.predict(X_full).astype(np.int64)
    ranges = np.asarray(class_ranges, dtype=np.float64)
    lo, hi = ranges[pred, 0], ranges[pred, 1]
    ok = int(np.count_nonzero((lo <= times_full) & (times_full <= hi)))
    return ok / times_full.size


def render_rules_table(grouped: dict[int, list[RuleSet]],
                       top_k: int = 3) -> str:
    """Markdown-ish rendering like Tables VI-VIII."""
    lines: list[str] = []
    for c in sorted(grouped):
        lines.append(f"## performance class {c + 1}")
        for rs in grouped[c][:top_k]:
            lines.append(f"  ruleset ({rs.n_samples} samples"
                         f"{', impure' if not rs.pure else ''}"
                         f"{', underconstrained' if rs.insufficient else ''})")
            extra = {r.canonical_atom() for r in rs.extraneous}
            for r in rs.rules:
                mark = "  [extraneous]" if r.canonical_atom() in extra else ""
                lines.append(f"    - {r.text()}{mark}")
            if rs.insufficient:
                lines.append("    - insufficient rules")
    return "\n".join(lines)
