"""Tree-kernel benchmarks: vectorized CART vs the loop reference, and
the gradient-boosted vs ridge surrogate screening race.

``tree_train_benches`` builds a Table-V-scale dataset (2000 random
halo3d schedules -> §IV-B features + §IV-A labels) and measures:

  * one ``DecisionTree`` fit, loop splitter vs vectorized splitter
    (cold = including the ``Presort`` analysis, warm = analysis
    shared, as the Algorithm-1 sweep and boosting rounds use it);
  * the full warm-started ``algorithm1`` sweep vs the seed-style loop
    sweep (fresh fit per trial, no shared presort / split cache);
  * a prediction-identity checksum between the two splitters — the
    speedup rows only count if the trees agree.

``surrogate_screen_benches`` races ``SurrogateGuided`` on halo3d with
the ridge vs the gradient-boosted surrogate at an equal
discrete-event-simulation budget (``sim_budget``, batch_size=1) and
reports each model's screening Spearman — the ROADMAP "smarter
surrogates" acceptance numbers.
"""
from __future__ import annotations

import time

import numpy as np

import repro.rules as R
import repro.search as S
from repro.core.dag import halo3d_dag

TRAIN_N = 2000          # Table-V-scale corpus (halo3d schedules)
SCREEN_SIMS = 300       # equal simulation budget for the screen race


def _halo3d_dataset(n: int = TRAIN_N, seed: int = 0):
    g = halo3d_dag()
    res = S.run_search(g, S.RandomSearch(g, 2, seed=seed), budget=n,
                       batch_size=64, backend="vectorized")
    fm, lab, _times = res.dataset()
    return fm, lab


def _loop_algorithm1(X: np.ndarray, y: np.ndarray) -> R.DecisionTree:
    """Seed-style Algorithm 1: fresh loop-splitter fit per trial, no
    shared presort or split cache (the honest pre-refactor baseline)."""
    mln = max(2, len(np.unique(y)))

    def train(k):
        t = R.DecisionTree(max_leaf_nodes=k, max_depth=k - 1,
                           splitter="loop").fit(X, y)
        return t.training_error(X, y), t

    err, clf = train(mln)
    improved = True
    while improved and err > 0.0:
        improved = False
        for i in range(1, 6):
            cur, nclf = train(mln + i)
            if cur < err:
                err, clf, mln = cur, nclf, mln + i
                improved = True
                break
    return clf


def tree_train_benches() -> list[str]:
    fm, lab = _halo3d_dataset()
    X, y = fm.X, lab.labels

    t0 = time.perf_counter()
    t_loop_tree = R.DecisionTree(8, 7, splitter="loop").fit(X, y)
    fit_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    R.DecisionTree(8, 7).fit(X, y)
    fit_cold = time.perf_counter() - t0

    ps = R.Presort(X)
    t0 = time.perf_counter()
    t_vec_tree = R.DecisionTree(8, 7).fit(X, y, presort=ps)
    fit_warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    loop_alg = _loop_algorithm1(X, y)
    alg_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    vec_alg = R.algorithm1(X, y)
    alg_vec = time.perf_counter() - t0

    identical = bool(
        (t_loop_tree.predict(X) == t_vec_tree.predict(X)).all()
        and (loop_alg.predict(X) == vec_alg.predict(X)).all())
    return [
        f"trees_fit_loop_ms,{fit_loop * 1e6:.2f},"
        f"{fit_loop * 1e3:.1f}",
        f"trees_fit_vectorized_cold_ms,{fit_cold * 1e6:.2f},"
        f"{fit_cold * 1e3:.1f}",
        f"trees_fit_vectorized_warm_ms,{fit_warm * 1e6:.2f},"
        f"{fit_warm * 1e3:.1f}",
        f"trees_fit_speedup_warm,{fit_warm * 1e6:.2f},"
        f"{fit_loop / fit_warm:.1f}",
        f"trees_algorithm1_loop_ms,{alg_loop * 1e6:.2f},"
        f"{alg_loop * 1e3:.1f}",
        f"trees_algorithm1_vectorized_ms,{alg_vec * 1e6:.2f},"
        f"{alg_vec * 1e3:.1f}",
        f"trees_algorithm1_speedup,{alg_vec * 1e6:.2f},"
        f"{alg_loop / alg_vec:.1f}",
        f"trees_prediction_identical,{alg_vec * 1e6:.2f},{identical}",
    ]


def _synthetic_blocks(n_rows: int, d: int, block: int, seed: int = 0):
    """Re-callable block stream of a synthetic 0/1 corpus.

    Each call replays the same rows (fresh generator, fixed seed)
    without ever holding more than one ``(block, d)`` slab — exactly
    the shape a :class:`repro.driver.HistogramSink` streams, so the
    OOC peak-memory rows measure the training pass, not the fixture.
    """
    def blocks():
        rng = np.random.default_rng(seed)
        for lo in range(0, n_rows, block):
            m = min(block, n_rows - lo)
            yield (rng.random((m, d)) < 0.5).astype(np.int8)
    return blocks


def _synthetic_labels(n_rows: int, d: int, block: int,
                      seed: int = 0) -> np.ndarray:
    y = np.empty(n_rows, dtype=np.int64)
    lo = 0
    for X in _synthetic_blocks(n_rows, d, block, seed)():
        y[lo:lo + len(X)] = (X[:, 0] * 4 + X[:, 1] * 2 + X[:, 2]) % 3
        lo += len(X)
    return y


def ooc_distill_benches() -> list[str]:
    """Out-of-core vs dense tree training: time and peak memory.

    The headline row is ``distill_ooc_peak_mb`` — the histogram path's
    peak traced allocation at 100k rows, which must stay flat as the
    corpus grows (its ``derived`` column shows the 20k-row peak and
    the 100k/20k ratio). The dense rows materialize the matrix and pay
    the presort, so their peak scales with rows.
    """
    import tracemalloc
    from repro.rules.trees import fit_from_histograms

    D, BLOCK, MLN = 192, 4096, 8
    rows: list[str] = []

    n_small = 20_000
    Xd = np.concatenate(list(_synthetic_blocks(n_small, D, BLOCK)()))
    y_small = _synthetic_labels(n_small, D, BLOCK)
    tracemalloc.start()
    t0 = time.perf_counter()
    dense = R.DecisionTree(MLN, MLN - 1).fit(Xd, y_small)
    dense_t = time.perf_counter() - t0
    _, dense_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    del Xd

    tracemalloc.start()
    t0 = time.perf_counter()
    ooc_small = fit_from_histograms(_synthetic_blocks(n_small, D, BLOCK),
                                    y_small, max_leaf_nodes=MLN,
                                    max_depth=MLN - 1)
    ooc_small_t = time.perf_counter() - t0
    _, ooc_small_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    n_big = 100_000
    y_big = _synthetic_labels(n_big, D, BLOCK)
    tracemalloc.start()
    t0 = time.perf_counter()
    fit_from_histograms(_synthetic_blocks(n_big, D, BLOCK), y_big,
                        max_leaf_nodes=MLN, max_depth=MLN - 1)
    ooc_big_t = time.perf_counter() - t0
    _, ooc_big_peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()

    sig = []
    stack = [dense.root]
    while stack:
        nd = stack.pop()
        if nd.is_leaf:
            sig.append(("leaf", nd.n_samples, nd.majority_class()))
        else:
            sig.append((nd.feature, nd.threshold))
            stack += [nd.right, nd.left]
    sig2 = []
    stack = [ooc_small.root]
    while stack:
        nd = stack.pop()
        if nd.is_leaf:
            sig2.append(("leaf", nd.n_samples, nd.majority_class()))
        else:
            sig2.append((nd.feature, nd.threshold))
            stack += [nd.right, nd.left]

    mb = 1.0 / (1024 * 1024)
    rows += [
        f"distill_dense_time_20k,{dense_t * 1e6:.2f},"
        f"{dense_t * 1e3:.1f}ms",
        f"distill_dense_peak_mb,{dense_peak * mb * 1e3:.2f},"
        f"{dense_peak * mb:.1f}MB_at_20k_rows",
        f"distill_ooc_time,{ooc_small_t * 1e6:.2f},"
        f"{ooc_small_t * 1e3:.1f}ms_at_20k_rows",
        f"distill_ooc_time_100k,{ooc_big_t * 1e6:.2f},"
        f"{ooc_big_t * 1e3:.1f}ms",
        # us_per_call column carries the gated quantity: peak bytes at
        # 100k rows (scaled), which must NOT scale with the corpus.
        f"distill_ooc_peak_mb,{ooc_big_peak * mb * 1e3:.2f},"
        f"{ooc_small_peak * mb:.1f}MB_at_20k_"
        f"{ooc_big_peak * mb:.1f}MB_at_100k_"
        f"ratio_{ooc_big_peak / max(1, ooc_small_peak):.2f}",
        f"distill_ooc_identical,{ooc_small_t * 1e6:.2f},"
        f"{sig == sig2}",
    ]
    return rows


def surrogate_screen_benches() -> list[str]:
    rows = []
    quality = {}
    for name in ("ridge", "boost"):
        g = halo3d_dag()
        strat = S.SurrogateGuided(g, 2, seed=0, surrogate=name)
        ev = S.make_evaluator(g, "vectorized")
        t0 = time.perf_counter()
        res = S.run_search(g, strat, budget=None,
                           sim_budget=SCREEN_SIMS, batch_size=1,
                           evaluator=ev)
        wall = (time.perf_counter() - t0) \
            / max(1, res.cache_misses) * 1e6
        q = strat.screening_quality()
        quality[name] = q["spearman"]
        rows += [
            f"screen_{name}_halo3d_spearman,{wall:.2f},"
            f"{q['spearman']:.3f}",
            f"screen_{name}_halo3d_best_us,{wall:.2f},"
            f"{res.best()[1] * 1e6:.2f}",
            f"screen_{name}_halo3d_sims,{wall:.2f},"
            f"{res.cache_misses}_of_{SCREEN_SIMS}",
        ]
    rows.append(
        f"screen_boost_vs_ridge_spearman,0.00,"
        f"{quality['boost'] - quality['ridge']:+.3f}")
    return rows


def trees_benches() -> list[str]:
    return (tree_train_benches() + ooc_distill_benches()
            + surrogate_screen_benches())


if __name__ == "__main__":
    for row in trees_benches():
        print(row)
