"""Training launcher.

Two modes:
  * real run (CPU-sized by default): reduced config, synthetic data,
    checkpoints, straggler monitoring — the same loop a pod would run.
  * ``--dry-run``: lower+compile the full config on the production mesh
    (delegates to repro.launch.dryrun; no allocation).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --steps 100
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
      --shape train_4k --dry-run [--multi-pod]
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train")
    args = ap.parse_args()

    if args.dry_run:
        # Re-exec the dryrun module so XLA_FLAGS is set before jax init.
        import subprocess
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape]
        if args.multi_pod:
            cmd.append("--multi-pod")
        raise SystemExit(subprocess.call(cmd))

    import jax

    from repro.checkpoint.store import CheckpointStore
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, batch_for
    from repro.ft.restart import LoopConfig, TrainLoop
    from repro.models.model import LM
    from repro.optim.adamw import AdamW, warmup_cosine
    from repro.train.step import make_train_step

    cfg = get_reduced(args.arch)
    model = LM(cfg)
    print(f"{cfg.name}: {model.n_params():,} params")
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=warmup_cosine(3e-3, 20, args.steps))
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab=cfg.vocab, packed=True)
    step = jax.jit(make_train_step(model, opt))
    loop = TrainLoop(step, lambda s: batch_for(dcfg, s, cfg),
                     CheckpointStore(args.ckpt_dir),
                     LoopConfig(total_steps=args.steps, ckpt_every=50))
    loop.run(params, opt.init(params))
    for h in loop.history:
        print(f"step {int(h['step']):5d}  loss {h['loss']:.4f}")


if __name__ == "__main__":
    main()
