"""Mixture-of-experts MLP (DeepSeekMoE-style: shared + routed top-k).

GShard-grouped dispatch: tokens are grouped by the batch dim (one group
per sequence — groups shard over "data", experts over "model"), and the
capacity C is *per group*: C = ceil(S * top_k * cf / E). Dispatch/
combine tensors are (B, S, E, C) — device-local slices of a few hundred
MB, never global. Two strategies, selected by ``MoeConfig.dispatch``:

  * ``einsum`` — one-hot dispatch/combine matmuls on the MXU (the
    paper-era TPU baseline). Dispatch flops ~ S*E*C*d per group rival
    the expert flops at these shapes — visible as HLO-vs-model flops
    overhead in §Roofline.

  * ``gather`` — beyond-paper optimization: take/segment-style dispatch
    costing O(k * S * d) per group. Identical routing + capacity-drop
    semantics, far lower HLO flops; the MoE hillclimb in EXPERIMENTS
    §Perf measures the swap.

Routing: softmax over expert logits, top-k, renormalized weights;
Switch-style load-balancing auxiliary loss returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain
from repro.models.config import ModelConfig, MoeConfig
from repro.models.layers import mlp, mlp_specs
from repro.models.params import Spec, stack_specs


def moe_specs(cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    mc = cfg.moe
    de = mc.d_expert or cfg.d_ff
    out = {
        "router": Spec((cfg.d_model, mc.n_experts),
                       ("d_model", "experts")),
        "experts": stack_specs(mlp_specs(cfg, de), mc.n_experts,
                               "experts"),
    }
    if mc.n_shared:
        out["shared"] = mlp_specs(cfg, de * mc.n_shared)
    return out


def _routing(router_logits: jax.Array, mc: MoeConfig):
    """Top-k routing per token. logits: (B, S, E).

    Returns (weights (B,S,k), experts (B,S,k), aux_loss)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, mc.top_k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    b, s, e = probs.shape
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)   # (B,S,k,E)
    f = onehot.mean(axis=(0, 1, 2))
    p = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(f * p) * mc.router_aux_weight
    return top_w, top_e, aux


def _capacity(s: int, mc: MoeConfig, override: int | None = None) -> int:
    """Per-group expert capacity."""
    if override is not None:
        return min(s, override)
    c = int(s * mc.top_k * mc.capacity_factor / mc.n_experts) + 1
    return max(1, min(s, c))


def _expert_mlp(p_experts: dict, xe: jax.Array, kind: str) -> jax.Array:
    """xe: (E, ..., d) -> per-expert MLP via vmap over the E dim."""
    return jax.vmap(lambda p, x: mlp(p, x, kind))(p_experts, xe)


def _positions(top_e: jax.Array, e: int, c: int):
    """Slot positions within each (group, expert) capacity buffer.

    top_e: (B, S, k). Returns (pos (B,S,k), keep (B,S,k))."""
    b, s, k = top_e.shape
    flat = top_e.reshape(b, s * k)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)       # (B,S*k,E)
    pos_all = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(
        pos_all, flat[..., None], axis=-1)[..., 0]          # (B,S*k)
    pos = pos.reshape(b, s, k)
    return pos, pos < c


def _dispatch_einsum(p: dict, x: jax.Array, top_w, top_e, mc: MoeConfig,
                     kind: str, capacity: int | None) -> jax.Array:
    b, s, d = x.shape
    e, c = mc.n_experts, _capacity(s, mc, capacity)
    pos, keep = _positions(top_e, e, c)
    oh_e = jax.nn.one_hot(top_e, e, dtype=x.dtype)          # (B,S,k,E)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, c), c + 1,
                          dtype=x.dtype)[..., :c]           # (B,S,k,C)
    disp = jnp.einsum("bske,bskc->bsec", oh_e, oh_c)        # (B,S,E,C)
    comb = jnp.einsum("bske,bskc,bsk->bsec", oh_e, oh_c,
                      top_w.astype(x.dtype))
    xe = jnp.einsum("bsec,bsd->becd", disp, x)
    xe = constrain(xe, ("batch", "experts", None, "d_model"))
    ye = _moe_experts(p, xe, kind)
    return jnp.einsum("bsec,becd->bsd", comb, ye)


def _moe_experts(p: dict, xe: jax.Array, kind: str) -> jax.Array:
    """xe: (B, E, C, d) -> (B, E, C, d) through per-expert MLPs."""
    xe_t = xe.transpose(1, 0, 2, 3)                         # (E,B,C,d)
    ye = _expert_mlp(p["experts"], xe_t, kind)
    ye = ye.transpose(1, 0, 2, 3)
    return constrain(ye, ("batch", "experts", None, "d_model"))


def _dispatch_gather(p: dict, x: jax.Array, top_w, top_e, mc: MoeConfig,
                     kind: str, capacity: int | None) -> jax.Array:
    b, s, d = x.shape
    e, c = mc.n_experts, _capacity(s, mc, capacity)
    pos, keep = _positions(top_e, e, c)
    k = mc.top_k
    # Slot index within the group's (E*C) buffer; drops -> scratch slot.
    slot = jnp.where(keep, top_e * c + pos, e * c)          # (B,S,k)
    flat_slot = slot.reshape(b, s * k)
    token_idx = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[:, None], (s, k)).reshape(s * k)

    def scatter_group(slots_g):
        tok = jnp.zeros((e * c + 1,), jnp.int32).at[slots_g].set(
            token_idx)
        fil = jnp.zeros((e * c + 1,), bool).at[slots_g].set(
            slots_g < e * c)
        return tok[:e * c], fil[:e * c]

    token_of_slot, filled = jax.vmap(scatter_group)(flat_slot)
    xe = jnp.take_along_axis(x, token_of_slot[..., None], axis=1)
    xe = jnp.where(filled[..., None], xe, 0.0)              # (B,E*C,d)
    xe = constrain(xe.reshape(b, e, c, d),
                   ("batch", "experts", None, "d_model"))
    ye = _moe_experts(p, xe, kind).reshape(b, e * c, d)

    def w_group(slots_g, w_g):
        w = jnp.zeros((e * c + 1,), top_w.dtype).at[slots_g].set(w_g)
        return w[:e * c]

    w_of_slot = jax.vmap(w_group)(flat_slot,
                                  top_w.reshape(b, s * k))
    weighted = ye * w_of_slot[..., None].astype(ye.dtype)
    weighted = jnp.where(filled[..., None], weighted, 0.0)

    def gather_back(tok_g, w_slots_g):
        return jnp.zeros((s, d), w_slots_g.dtype).at[tok_g].add(
            w_slots_g)

    return jax.vmap(gather_back)(token_of_slot, weighted)


def moe_mlp(p: dict, x: jax.Array, cfg: ModelConfig,
            capacity: int | None = None):
    """x: (B, S, d) -> (y, aux_loss). Groups = batch dim.

    ``capacity`` override: the decode path passes S (the per-group token
    count) so serving is *dropless* (exact routing); training keeps the
    capacity-bounded behavior standard for TPU MoE.
    """
    mc = cfg.moe
    logits = x @ p["router"].astype(x.dtype)                # (B,S,E)
    top_w, top_e, aux = _routing(logits, mc)
    if mc.dispatch == "einsum":
        y = _dispatch_einsum(p, x, top_w, top_e, mc, cfg.mlp, capacity)
    else:
        y = _dispatch_gather(p, x, top_w, top_e, mc, cfg.mlp, capacity)
    y = y.astype(x.dtype)
    if mc.n_shared:
        y = y + mlp(p["shared"], x, cfg.mlp)
    return y, aux
