"""Pallas TPU pack kernel: out[j] = x[idx[j]].

The paper's Pack vertex gathers x entries into per-neighbor send buffers.
For band matrices the halo is contiguous (a slice — no kernel needed);
for irregular index sets the TPU-idiomatic gather is a chunked one-hot
matmul: stream x through VMEM in width-CH chunks, build the (C, CH)
one-hot of the indices that fall in the chunk, and accumulate the MXU
product. No per-lane hardware gather is required.

Cost: O(C * n) MACs per C outputs — worth it on TPU when the index set is
irregular and x is VMEM-resident (n up to ~1M f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pack_body(idx_ref, x_ref, out_ref, *, chunk: int, n_chunks: int,
               block_c: int):
    idx = idx_ref[0, :]                                  # (C,) int32
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_c, chunk), 1)

    def step(c, acc):
        c0 = c * chunk
        xw = x_ref[0, pl.ds(c0, chunk)].astype(jnp.float32)   # (CH,)
        rel = idx[:, None] - c0
        onehot = (iota == rel).astype(jnp.float32)            # (C, CH)
        return acc + jax.lax.dot_general(
            onehot, xw[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]

    acc = jax.lax.fori_loop(0, n_chunks,
                            step, jnp.zeros((block_c,), jnp.float32))
    out_ref[...] = acc[None, :]


@functools.partial(jax.jit,
                   static_argnames=("block_c", "chunk", "interpret"))
def pack(x: jax.Array, idx: jax.Array, block_c: int = 256,
         chunk: int = 1024, interpret: bool = True) -> jax.Array:
    """Gather x[idx] with the chunked one-hot kernel."""
    n = x.shape[0]
    m = idx.shape[0]
    np_ = _round_up(n, chunk)
    mp = _round_up(m, block_c)
    x_p = jnp.zeros((1, np_), x.dtype).at[0, :n].set(x)
    idx_p = jnp.full((1, mp), -1, jnp.int32).at[0, :m].set(
        idx.astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(_pack_body, chunk=chunk,
                          n_chunks=np_ // chunk, block_c=block_c),
        grid=(mp // block_c,),
        in_specs=[
            pl.BlockSpec((1, block_c), lambda b: (0, b)),
            pl.BlockSpec((1, np_), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda b: (0, b)),
        out_shape=jax.ShapeDtypeStruct((1, mp), jnp.float32),
        interpret=interpret,
    )(idx_p, x_p)
    return out[0, :m].astype(x.dtype)
