"""Sequence-to-vector feature transformation (paper §IV-B).

For a set of expanded schedules (original ops + inserted sync ops):

  * one *ordering* feature per ordered pair (u, v) of items:
      1 if u appears before v in the expanded sequence, else 0
    (only (u, v) with u < v lexicographically are kept; the reverse pair is
    its complement and adds no information);
  * one *stream* feature per unordered pair of GPU ops:
      1 if both are bound to the same stream, else 0.

Features that take the same value in every schedule (e.g. DAG-implied
orderings) are dropped — they have no discriminatory power.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.dag import Graph, OpKind, Schedule
from repro.core.sync import expanded_names


@dataclasses.dataclass(frozen=True)
class Feature:
    kind: str  # 'order' | 'stream'
    u: str
    v: str

    def describe(self, value: int) -> str:
        """Human-readable rule text for this feature taking ``value``."""
        if self.kind == "order":
            return (f"{self.u} before {self.v}" if value
                    else f"{self.v} before {self.u}")
        return (f"{self.u} same stream as {self.v}" if value
                else f"{self.u} different stream than {self.v}")


@dataclasses.dataclass
class FeatureMatrix:
    features: list[Feature]
    X: np.ndarray  # (n_schedules, n_features) int8

    def names(self) -> list[str]:
        return [f"{f.kind}:{f.u}<{f.v}" for f in self.features]


def _positions(names: list[str]) -> dict[str, int]:
    return {n: i for i, n in enumerate(names)}


def featurize(graph: Graph, schedules: list[Schedule]) -> FeatureMatrix:
    """Build the (pruned) feature matrix for ``schedules``."""
    expanded = [expanded_names(graph, s) for s in schedules]
    streams = [s.streams() for s in schedules]

    # Universe of items = union across schedules (sync-op sets can differ
    # between stream assignments).
    universe = sorted(set(itertools.chain.from_iterable(expanded)))
    gpu = sorted(graph.gpu_ops())

    feats: list[Feature] = []
    for u, v in itertools.combinations(universe, 2):
        feats.append(Feature("order", u, v))
    for u, v in itertools.combinations(gpu, 2):
        feats.append(Feature("stream", u, v))

    X = np.zeros((len(schedules), len(feats)), dtype=np.int8)
    for i, (names, st) in enumerate(zip(expanded, streams)):
        pos = _positions(names)
        for j, f in enumerate(feats):
            if f.kind == "order":
                pu, pv = pos.get(f.u), pos.get(f.v)
                X[i, j] = 1 if (pu is not None and pv is not None
                                and pu < pv) else 0
            else:
                X[i, j] = 1 if st.get(f.u) == st.get(f.v) else 0

    # Drop constant features.
    keep = [j for j in range(len(feats))
            if X[:, j].min() != X[:, j].max()]
    return FeatureMatrix([feats[j] for j in keep], X[:, keep])


def featurize_like(graph: Graph, schedules: list[Schedule],
                   reference: FeatureMatrix) -> np.ndarray:
    """Feature values for new schedules in an existing feature basis.

    Used by Table V evaluation: classify the *entire* space with a tree
    trained on an MCTS subset (whose feature pruning defined the basis).
    """
    expanded = [expanded_names(graph, s) for s in schedules]
    streams = [s.streams() for s in schedules]
    X = np.zeros((len(schedules), len(reference.features)), dtype=np.int8)
    for i, (names, st) in enumerate(zip(expanded, streams)):
        pos = _positions(names)
        for j, f in enumerate(reference.features):
            if f.kind == "order":
                pu, pv = pos.get(f.u), pos.get(f.v)
                X[i, j] = 1 if (pu is not None and pv is not None
                                and pu < pv) else 0
            else:
                X[i, j] = 1 if st.get(f.u) == st.get(f.v) else 0
    return X
