"""End-to-end smoke gate (select with ``pytest -m smoke``)."""
import pytest

from benchmarks.smoke import run_smoke


@pytest.mark.smoke
def test_smoke_search_to_rules_end_to_end():
    out = run_smoke(budget=200, seed=0)
    assert out["wall_s"] < 30.0
    assert out["n_evaluations"] == 200
    assert 1 <= out["n_schedules"] <= 200
    assert out["spread"] > 1.1          # schedule choice matters
    assert out["n_classes"] >= 1
    assert out["n_rulesets"] >= 1
    assert out["training_error"] <= 0.05
