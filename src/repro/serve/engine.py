"""Batched serving: prefill + decode loop with KV/state caches.

``serve_step`` (one new token for the whole batch against a seq_len
cache) is the function the decode_32k / long_500k cells lower. The
:class:`Engine` drives it end-to-end for the examples: batched greedy /
temperature sampling with position-aligned sequences (continuous
batching is out of scope; the cache layout supports it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models.model import LM


def make_serve_step(model: LM):
    """serve_step(params, caches, tokens (B,1), pos) ->
    (next_tokens, logits, caches)."""

    def serve_step(params, caches, tokens, pos):
        logits, caches = model.decode_step(params, tokens, pos, caches)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], logits, caches

    return serve_step


def cache_axes(model: LM):
    """Logical axes for the decode caches (mirrors init_caches)."""
    def axes_of(path_leaf):
        # keyed by array rank + semantics; caches are dicts with fixed
        # key names, so map by key.
        return None

    caches = jax.eval_shape(
        lambda: model.init_caches(1, 8, n_memory=8))
    # Build by key name.
    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k in ("k", "v", "ck", "cv"):
                out[k] = ("layers", "batch", "kv_seq", "kv_stored",
                          "head_dim")
            elif k == "conv":
                out[k] = ("layers", "batch", None, "d_inner")
            elif k == "h":
                out[k] = ("layers", "batch", "d_inner", None)
            elif k == "shift":
                out[k] = ("layers", "batch", "d_model")
            elif k == "s":
                out[k] = ("layers", "batch", "heads", "head_dim", None)
            else:
                raise KeyError(k)
        return out

    return walk(caches)


def serve_shardings(model: LM, mesh: Mesh, batch: int, t_max: int,
                    n_memory: int = 0,
                    rules: Mapping[str, Any] | None = None):
    p_axes = model.param_axes()
    p_shapes = model.abstract_params()
    p_sh = shd.tree_shardings(p_axes, mesh, rules, p_shapes)
    c_axes = cache_axes(model)
    c_shapes = jax.eval_shape(
        lambda: model.init_caches(batch, t_max, n_memory=n_memory))
    c_sh = shd.tree_shardings(c_axes, mesh, rules, c_shapes)
    tok_sh = NamedSharding(mesh, shd.batch_spec(mesh, 1, rules,
                                                batch_size=batch))
    return p_sh, c_sh, tok_sh


@dataclasses.dataclass
class Engine:
    model: LM
    params: Any
    t_max: int

    def generate(self, prompts: jax.Array, n_new: int,
                 frontend: jax.Array | None = None) -> jax.Array:
        """prompts: (B, S) -> (B, n_new) greedy continuation."""
        cfg = self.model.cfg
        batch = {"tokens": prompts}
        if frontend is not None:
            batch["frontend"] = frontend
        n_front = cfg.frontend.n_positions if cfg.family == "vlm" else 0
        logits, caches = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.t_max)
        )(self.params, batch)
        step = jax.jit(make_serve_step(self.model))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        out = [tok]
        pos = prompts.shape[1] + n_front
        for i in range(n_new - 1):
            tok, _, caches = step(self.params, caches, tok,
                                  jnp.asarray(pos + i))
            out.append(tok)
        return jnp.concatenate(out, axis=1)
