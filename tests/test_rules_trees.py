"""The rules-subsystem tree stack: vectorized-vs-loop split identity,
sklearn cross-checks, batch prediction, warm starts, regression trees,
and the gradient-boosted surrogate."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 container: seeded-random fallback
    from _hypothesis_fallback import given, settings, strategies as st

import repro.core as C
import repro.rules as R


def tree_signature(tree):
    """(feature, threshold) preorder + leaf stats — full structure."""
    out = []

    def walk(nd):
        if nd.is_leaf:
            out.append(("leaf", nd.n_samples, nd.majority_class()))
            return
        out.append((nd.feature, nd.threshold))
        walk(nd.left)
        walk(nd.right)

    walk(tree.root)
    return out


def random_dataset(rng, kind):
    n = int(rng.integers(8, 120))
    d = int(rng.integers(1, 10))
    if kind == 0:                       # the paper's 0/1 features
        X = rng.integers(0, 2, size=(n, d)).astype(float)
    elif kind == 1:                     # small-cardinality ordinals
        X = rng.integers(0, 4, size=(n, d)).astype(float)
    elif kind == 2:                     # continuous
        X = rng.random((n, d))
    else:                               # mixed + constant columns
        X = np.concatenate(
            [rng.integers(0, 2, size=(n, d)).astype(float),
             rng.random((n, 2)), np.ones((n, 1))], axis=1)
    y = rng.integers(0, int(rng.integers(2, 5)), size=n)
    return X, y


# -- vectorized splitter == loop reference ------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_vectorized_splitter_identical_to_loop(seed):
    """The property pin: on random (X, y) of every feature flavor the
    vectorized and loop splitters grow bit-identical trees."""
    rng = np.random.default_rng(seed)
    X, y = random_dataset(rng, seed % 4)
    if len(np.unique(y)) < 2:
        y[0] = y[0] + 1
    mln = int(rng.integers(2, 14))
    tv = R.DecisionTree(mln, splitter="vectorized").fit(X, y)
    tl = R.DecisionTree(mln, splitter="loop").fit(X, y)
    assert tree_signature(tv) == tree_signature(tl)
    np.testing.assert_array_equal(tv.predict(X), tl.predict(X))


def test_vectorized_identical_across_feature_chunks(monkeypatch):
    """The sorted-path feature chunking must not change results: with a
    tiny _FEATURE_BLOCK every multi-valued dataset spans many chunks,
    and the chunk-local -> global feature mapping is exercised."""
    from repro.rules import trees as T

    monkeypatch.setattr(T, "_FEATURE_BLOCK", 8)
    rng = np.random.default_rng(13)
    for kind in (1, 2, 3):
        X, y = random_dataset(rng, kind)
        if len(np.unique(y)) < 2:
            y[0] = y[0] + 1
        tv = R.DecisionTree(8, splitter="vectorized").fit(X, y)
        tl = R.DecisionTree(8, splitter="loop").fit(X, y)
        assert tree_signature(tv) == tree_signature(tl), kind
        # regression trees share the chunked kernel
        yr = rng.standard_normal(len(y))
        rt = R.RegressionTree(max_leaf_nodes=6).fit(X, yr)
        assert rt.n_leaves() >= 1


def test_vectorized_identical_on_exhaustive_spmv():
    """Acceptance pin: prediction-identical trees on the exhaustive
    280-schedule SpMV dataset, through the full Algorithm-1 sweep."""
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    tv = R.algorithm1(fm.X, lab.labels)
    tl = R.algorithm1(fm.X, lab.labels, splitter="loop")
    assert tree_signature(tv) == tree_signature(tl)
    np.testing.assert_array_equal(tv.predict(fm.X), tl.predict(fm.X))
    assert tv.training_error(fm.X, lab.labels) == 0.0


def test_algorithm1_warm_start_matches_cold_fits():
    """The shared Presort + split cache must not change the sweep's
    outcome: every trial equals a from-scratch fit."""
    rng = np.random.default_rng(3)
    X = rng.integers(0, 2, size=(150, 12)).astype(float)
    y = (X[:, 0] + 2 * X[:, 1] * X[:, 2] + X[:, 3]).astype(int)
    warm = R.algorithm1(X, y)
    k = warm.max_leaf_nodes
    cold = R.DecisionTree(max_leaf_nodes=k, max_depth=k - 1).fit(X, y)
    assert tree_signature(warm) == tree_signature(cold)


def test_split_cache_rejects_nothing_but_matches():
    """Explicit split_cache sharing across equal-data fits is exact."""
    rng = np.random.default_rng(4)
    X = rng.random((80, 6))
    y = rng.integers(0, 3, size=80)
    ps = R.Presort(X)
    cache: dict = {}
    a = R.DecisionTree(6).fit(X, y, presort=ps, split_cache=cache)
    assert cache  # populated
    b = R.DecisionTree(6).fit(X, y, presort=ps, split_cache=cache)
    assert tree_signature(a) == tree_signature(b)


def test_presort_shape_mismatch_raises():
    ps = R.Presort(np.zeros((10, 3)))
    with pytest.raises(ValueError, match="presort"):
        R.DecisionTree(2).fit(np.zeros((11, 3)), np.zeros(11),
                              presort=ps)


def test_batch_predict_equals_single_descent():
    rng = np.random.default_rng(5)
    X = rng.random((120, 7))
    y = rng.integers(0, 4, size=120)
    t = R.DecisionTree(10).fit(X, y)
    Xq = rng.random((50, 7))
    batch = t.predict(Xq)
    single = np.array([t.classes_[t._leaf(x).majority_class()]
                       for x in Xq])
    np.testing.assert_array_equal(batch, single)


# -- sklearn cross-check ------------------------------------------------------

def _sklearn_tree(k, seed=0):
    sktree = pytest.importorskip("sklearn.tree")
    return sktree.DecisionTreeClassifier(
        criterion="gini", class_weight="balanced", max_leaf_nodes=k,
        max_depth=k - 1, random_state=seed)


@pytest.mark.parametrize("seed,kind", [(0, 0), (1, 1), (2, 2), (3, 3)])
def test_dtree_matches_sklearn_training_error(seed, kind):
    """Same CART recipe (gini, balanced weights, best-first growth
    under max_leaf_nodes) -> same training error as sklearn."""
    rng = np.random.default_rng(seed)
    X, y = random_dataset(rng, kind)
    if len(np.unique(y)) < 2:
        y[0] = y[0] + 1
    for k in (2, 4, 8):
        ours = R.DecisionTree(k, max_depth=k - 1).fit(X, y)
        sk = _sklearn_tree(k).fit(X, y)
        ours_err = ours.training_error(X, y)
        sk_err = float(np.mean(sk.predict(X) != y))
        assert ours_err == pytest.approx(sk_err, abs=1e-12), k
        assert ours.n_leaves() == sk.get_n_leaves(), k


def test_algorithm1_matches_sklearn_on_spmv():
    """The paper pipeline's tree agrees with sklearn at the chosen
    hyperparameters on the exhaustive SpMV dataset."""
    pytest.importorskip("sklearn")
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    times = np.array([C.makespan(g, s) for s in scheds])
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    ours = R.algorithm1(fm.X, lab.labels)
    k = ours.max_leaf_nodes
    sk = _sklearn_tree(k).fit(fm.X, lab.labels)
    assert ours.training_error(fm.X, lab.labels) == \
        pytest.approx(float(np.mean(sk.predict(fm.X) != lab.labels)),
                      abs=1e-12)


def test_regression_tree_matches_sklearn():
    sktree = pytest.importorskip("sklearn.tree")
    rng = np.random.default_rng(7)
    X = rng.random((200, 6))
    y = 2.0 * X[:, 0] + (X[:, 1] > 0.5) - X[:, 2] ** 2 \
        + 0.01 * rng.standard_normal(200)
    for k in (4, 8, 16):
        ours = R.RegressionTree(max_leaf_nodes=k).fit(X, y)
        sk = sktree.DecisionTreeRegressor(max_leaf_nodes=k,
                                          random_state=0).fit(X, y)
        ours_mse = float(np.mean((ours.predict(X) - y) ** 2))
        sk_mse = float(np.mean((sk.predict(X) - y) ** 2))
        assert ours_mse == pytest.approx(sk_mse, rel=1e-9), k


# -- regression tree ----------------------------------------------------------

def test_regression_tree_brute_force_first_split():
    """First split must maximize SSE reduction over every candidate."""
    rng = np.random.default_rng(11)
    X = rng.random((40, 4))
    y = rng.standard_normal(40)
    t = R.RegressionTree(max_leaf_nodes=2).fit(X, y)
    assert not t.root.is_leaf

    def sse(v):
        return float(((v - v.mean()) ** 2).sum()) if v.size else 0.0

    best = None
    for f in range(X.shape[1]):
        vals = np.unique(X[:, f])
        for j in range(len(vals) - 1):
            thr = (vals[j] + vals[j + 1]) / 2.0
            mask = X[:, f] <= thr
            gain = sse(y) - sse(y[mask]) - sse(y[~mask])
            if best is None or gain > best + 1e-12:
                best = gain
    got_mask = X[:, t.root.feature] <= t.root.threshold
    got_gain = sse(y) - sse(y[got_mask]) - sse(y[~got_mask])
    assert got_gain == pytest.approx(best, rel=1e-9)


def test_regression_tree_constant_target_is_leaf():
    X = np.random.default_rng(0).random((30, 3))
    t = R.RegressionTree(max_leaf_nodes=8).fit(X, np.ones(30))
    assert t.n_leaves() == 1
    np.testing.assert_allclose(t.predict(X), 1.0)


def test_regression_tree_respects_limits():
    rng = np.random.default_rng(2)
    X = rng.random((300, 5))
    y = rng.standard_normal(300)
    for k in (2, 5, 9):
        t = R.RegressionTree(max_leaf_nodes=k).fit(X, y)
        assert 1 <= t.n_leaves() <= k
    t = R.RegressionTree(max_leaf_nodes=64, max_depth=3).fit(X, y)
    assert t.depth() <= 3


# -- gradient-boosted surrogate ----------------------------------------------

def test_boosted_surrogate_fits_nonlinear_target():
    """Boosting must capture a feature interaction the linear ridge
    cannot (XOR-shaped makespan)."""
    import random as pyrandom

    import repro.search as S

    g = C.spmv_dag()
    rng = pyrandom.Random(0)
    train = [S.random_schedule(g, 2, rng) for _ in range(200)]
    held = [S.random_schedule(g, 2, rng) for _ in range(100)]
    fm = C.featurize(g, train + held)
    # synthetic nonlinear target over the real feature space
    t_all = (fm.X[:, 0] ^ fm.X[:, 1]).astype(float) \
        + 0.1 * fm.X[:, 2]

    boost = R.GradientBoostedSurrogate(g, n_estimators=100,
                                       refit_every=1)
    ridge = S.RidgeSurrogate(g, refit_every=1)
    for s, t in zip(train, t_all[:200]):
        boost.observe(s, float(t))
        ridge.observe(s, float(t))
    err_b = float(np.mean((boost.predict(held) - t_all[200:]) ** 2))
    err_r = float(np.mean((ridge.predict(held) - t_all[200:]) ** 2))
    assert err_b < err_r
    assert boost.n_trees > 0


def test_boosted_surrogate_degenerate_predicts_mean():
    import random as pyrandom

    import repro.search as S

    g = C.spmv_dag()
    sur = R.GradientBoostedSurrogate(g, refit_every=1)
    s = S.random_schedule(g, 2, pyrandom.Random(0))
    assert sur.predict([s]) == pytest.approx([0.0])  # no data: mean 0
    sur.observe(s, 3.0)
    sur.observe(s, 5.0)  # identical schedules: no features survive
    np.testing.assert_allclose(sur.predict([s]), [4.0])


def test_surrogate_registry_and_seam():
    import repro.search as S

    g = C.spmv_dag()
    assert set(S.SURROGATES) >= {"ridge", "boost"}
    guided = S.SurrogateGuided(g, 2, surrogate="boost",
                               surrogate_kwargs={"n_estimators": 10})
    assert isinstance(guided.surrogate, R.GradientBoostedSurrogate)
    assert guided.surrogate.n_estimators == 10
    # pre-built objects pass through
    pre = S.RidgeSurrogate(g)
    assert S.SurrogateGuided(g, 2, surrogate=pre).surrogate is pre
    with pytest.raises(ValueError, match="unknown surrogate"):
        S.make_surrogate(g, "nope")
    with pytest.raises(ValueError, match="surrogate_kwargs"):
        S.SurrogateGuided(g, 2, surrogate=pre,
                          surrogate_kwargs={"x": 1})
    # refit_every forwards to any named surrogate; l2 is ridge-only
    gb = S.SurrogateGuided(g, 2, surrogate="boost", refit_every=3)
    assert gb.surrogate.refit_every == 3
    gr = S.SurrogateGuided(g, 2, l2=0.5, refit_every=3)
    assert gr.surrogate.l2 == 0.5 and gr.surrogate.refit_every == 3
    with pytest.raises(ValueError, match="ridge"):
        S.SurrogateGuided(g, 2, surrogate="boost", l2=0.5)


def test_boost_guided_search_runs_end_to_end():
    import repro.search as S

    g = C.spmv_dag()
    strat = S.SurrogateGuided(g, 2, seed=0, warmup=16,
                              surrogate="boost",
                              surrogate_kwargs={"n_estimators": 20})
    res = S.run_search(g, strat, budget=60, batch_size=4)
    assert res.n_proposed == 60
    q = strat.screening_quality()
    assert q["n_screened"] > 0 and q["n_compared"] > 0


# -- re-exports ---------------------------------------------------------------

def test_core_reexports_the_rules_subsystem():
    """repro.core's one-stop names must be the rules-subsystem objects."""
    assert C.DecisionTree is R.DecisionTree
    assert C.algorithm1 is R.algorithm1
    assert C.label_times is R.label_times
    assert C.extract_rulesets is R.extract_rulesets
    assert C.class_range_accuracy is R.class_range_accuracy
