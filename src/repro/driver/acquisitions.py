"""Acquisition functions: how a candidate pool is ranked for evaluation.

The two-stage strategies (``SurrogateGuided``, the portfolio) screen a
cheap candidate pool with a learned cost model and send only the most
promising ``k`` schedules to the expensive evaluator. *How* "promising"
is scored is the acquisition function — the Bayesian-optimization seam
the autotuning literature (OptiML, the Memeti et al. survey) builds
on. This module makes it a registry:

``argmin_topk`` (default)
    Rank by predicted time alone — exactly the screening
    ``SurrogateGuided`` has always done (stable argsort of the
    surrogate's predictions). Pure exploitation of the model's mean.

``ucb``
    Lower confidence bound ``mu - beta * sigma`` (we *minimize* time,
    so optimism-in-the-face-of-uncertainty subtracts the deviation).
    ``beta=0`` reproduces ``argmin_topk`` ordering.

``expected_improvement``
    Classic EI against the best observed time:
    ``EI = (best - mu - xi) * Phi(z) + sigma * phi(z)`` with
    ``z = (best - mu - xi) / sigma``; candidates are ranked by ``-EI``
    (all scores here are *lower-is-better*). Candidates with zero
    predicted deviation fall back to their plain improvement
    ``max(best - mu - xi, 0)``.

Uncertainty comes from :func:`predict_with_std`: surrogates that
expose ``predict_with_std(schedules) -> (mu, sd)`` (the boosted
ensemble's per-tree disagreement,
:meth:`repro.rules.boost.GradientBoostedSurrogate.predict_with_std`)
report real deviations; anything else (e.g. the ridge model) gets
``sd = 0``, which degrades every acquisition to ``argmin_topk``
ordering instead of failing.

Every acquisition is a callable

    acq(surrogate, pool, best=None) -> (scores, mu)

where ``scores`` ranks the pool (lower = evaluate first; callers take
``np.argsort(scores, kind="stable")[:k]``) and ``mu`` is the
predicted mean time per candidate — returned alongside so screening-
quality logs always record the *prediction*, never the acquisition
score. The registry stores factories: ``make_acquisition("ucb",
beta=0.5)`` builds the configured callable.
"""
from __future__ import annotations

import math
from typing import Callable, Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class Surrogate(Protocol):
    """What an acquisition needs from a cost model."""

    def predict(self, schedules: Sequence) -> np.ndarray: ...


AcquisitionFn = Callable[..., "tuple[np.ndarray, np.ndarray]"]


def predict_with_std(surrogate, schedules
                     ) -> tuple[np.ndarray, np.ndarray]:
    """(predicted mean, predicted deviation) per schedule.

    Uses the surrogate's own ``predict_with_std`` when it has one;
    otherwise the plain prediction with zero deviation — so
    uncertainty-aware acquisitions degrade to mean-ranking (never
    crash) on surrogates that cannot quantify uncertainty.
    """
    fn = getattr(surrogate, "predict_with_std", None)
    if fn is not None:
        mu, sd = fn(schedules)
        return (np.asarray(mu, dtype=np.float64),
                np.asarray(sd, dtype=np.float64))
    mu = np.asarray(surrogate.predict(schedules), dtype=np.float64)
    return mu, np.zeros_like(mu)


# -- the built-in acquisitions ------------------------------------------------

def argmin_topk() -> AcquisitionFn:
    """Rank by predicted time — the original two-stage screening."""

    def acq(surrogate, pool, best: float | None = None):
        mu = np.asarray(surrogate.predict(pool), dtype=np.float64)
        return mu, mu

    acq.name = "argmin_topk"
    return acq


def ucb(beta: float = 1.0) -> AcquisitionFn:
    """Lower confidence bound ``mu - beta * sd`` (minimization UCB)."""
    if beta < 0.0:
        raise ValueError("beta must be >= 0")

    def acq(surrogate, pool, best: float | None = None):
        mu, sd = predict_with_std(surrogate, pool)
        return mu - beta * sd, mu

    acq.name = "ucb"
    return acq


_SQRT2 = math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)
_erf = np.vectorize(math.erf, otypes=[np.float64])


def _norm_cdf(z: np.ndarray) -> np.ndarray:
    return 0.5 * (1.0 + _erf(z / _SQRT2))


def _norm_pdf(z: np.ndarray) -> np.ndarray:
    return _INV_SQRT_2PI * np.exp(-0.5 * z * z)


def expected_improvement(xi: float = 0.0,
                         relative: bool = True) -> AcquisitionFn:
    """Expected improvement over the best observed time.

    ``xi`` shifts the improvement threshold: positive trades more
    exploration (a candidate must promise to beat the incumbent by a
    margin before its mean counts), negative leans exploitation —
    inflating every candidate's nominal improvement pushes the
    ``Phi(z)`` term toward 1, so the ranking approaches mean-first with
    uncertainty as the tie-breaker (the "greedy EI" operating point
    that wins the screening-quality races in BENCH_5). With
    ``relative=True`` (default) ``xi`` is a fraction of the incumbent
    (``margin = xi * |best|``), so one setting transfers across graphs
    whose makespans differ by orders of magnitude; ``relative=False``
    reads ``xi`` in absolute time units.

    With no observed best yet (or a surrogate reporting zero deviation
    everywhere) the ordering falls back to plain predicted-time
    ranking, so warm starts behave like ``argmin_topk``.
    """

    def acq(surrogate, pool, best: float | None = None):
        mu, sd = predict_with_std(surrogate, pool)
        if best is None or not np.any(sd > 0.0):
            return mu, mu
        margin = xi * abs(best) if relative else xi
        imp = best - mu - margin
        pos = sd > 0.0
        z = np.where(pos, imp / np.where(pos, sd, 1.0), 0.0)
        ei = np.where(pos,
                      imp * _norm_cdf(z) + sd * _norm_pdf(z),
                      np.maximum(imp, 0.0))
        scores = -ei
        # Zero EI (deterministic candidates past the incumbent) cannot
        # rank within itself — every such candidate scores exactly 0,
        # which a stable argsort would resolve by pool order. Fall back
        # to predicted-time order *behind* every positive-EI candidate
        # (their scores are < 0) instead of spending budget pool-first.
        flat = ei <= 0.0
        if np.any(flat):
            mu_f = mu[flat]
            span = float(mu_f.max() - mu_f.min())
            scores[flat] = 1.0 + (mu_f - mu_f.min()) / (span or 1.0)
        return scores, mu

    acq.name = "expected_improvement"
    return acq


# -- the registry -------------------------------------------------------------

ACQUISITIONS: dict[str, Callable[..., AcquisitionFn]] = {}
"""Acquisition factories: name -> ``factory(**kwargs) -> acq_fn``."""


def register_acquisition(name: str,
                         factory: Callable[..., AcquisitionFn]) -> None:
    """Add an acquisition factory to the :data:`ACQUISITIONS` registry.

    Factories are called as ``factory(**kwargs)`` and must return a
    callable ``acq(surrogate, pool, best=None) -> (scores, mu)`` with
    lower-is-better ``scores`` aligned to ``pool``.
    """
    ACQUISITIONS[name] = factory


register_acquisition("argmin_topk", argmin_topk)
register_acquisition("ucb", ucb)
register_acquisition("expected_improvement", expected_improvement)


def make_acquisition(acquisition: str = "argmin_topk",
                     **kwargs) -> AcquisitionFn:
    """Construct an acquisition function by registry name."""
    try:
        factory = ACQUISITIONS[acquisition]
    except KeyError:
        raise ValueError(
            f"unknown acquisition {acquisition!r}; registered: "
            f"{sorted(ACQUISITIONS)}") from None
    return factory(**kwargs)


def resolve_acquisition(acquisition, kwargs: dict | None
                        ) -> AcquisitionFn:
    """Registry name -> built callable; pre-built callables pass through.

    The one name-or-callable resolution shared by every acquisition
    consumer (``SurrogateGuided``, ``SearchDriver``): ``kwargs`` only
    apply to registry names — combining them with a pre-built callable
    raises instead of being silently dropped.
    """
    if isinstance(acquisition, str):
        return make_acquisition(acquisition, **(kwargs or {}))
    if kwargs is not None:
        raise ValueError(
            "acquisition_kwargs only applies when acquisition is a "
            "registry name, not a pre-built callable")
    return acquisition
