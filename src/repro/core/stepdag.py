"""Op-DAG adapters: the paper's technique applied to the framework itself.

The paper demonstrates schedule search on SpMV. Here we expose the LM
``train_step`` of *this* framework as an op-DAG so the same MCTS + rules
pipeline discovers collective-overlap schedules ("reduce-scatter(l) before
bwd(l-2)", channel assignments) scored by the TPU machine model.

Vertices per transformer layer l (data-parallel + tensor-parallel step):

  fwd_l  (GPU, compute)        layer forward
  bwd_l  (GPU, compute)        layer backward (~2x fwd flops)
  rs_l   (GPU, ICI channel)    reduce-scatter of layer-l gradients
  [ag_l  (GPU, ICI channel)]   ZeRO-style param all-gather before fwd_l
  opt    (GPU, compute)        optimizer update (needs all rs_l)

"Streams" = 1 compute stream + ``n_channels`` ICI channels. Collectives
are asynchronous device ops, so — unlike the paper's CPU-posted MPI — they
are GPU-type vertices; binding one to the compute stream models a
non-overlapped (blocking) collective, binding it to a channel models
overlap. This is the TPU-native translation of stream assignment.
"""
from __future__ import annotations

import dataclasses

from repro.core.dag import Graph, Op, OpKind


@dataclasses.dataclass(frozen=True)
class StepCosts:
    """Per-layer cost terms (per chip), derivable from a roofline cell."""

    fwd_flops: float
    bwd_flops: float
    fwd_bytes: float
    bwd_bytes: float
    grad_bytes: float           # reduce-scattered per layer per chip
    param_gather_bytes: float = 0.0  # ZeRO all-gather per layer (0 = off)
    opt_bytes: float = 0.0


def train_step_dag(n_layers: int, costs: StepCosts,
                   zero_sharded: bool = False) -> Graph:
    """Build the train-step op-DAG for schedule search."""
    g = Graph()
    for l in range(n_layers):
        g.add_op(Op(f"fwd{l}", OpKind.GPU, flops=costs.fwd_flops,
                    bytes_hbm=costs.fwd_bytes))
        g.add_op(Op(f"bwd{l}", OpKind.GPU, flops=costs.bwd_flops,
                    bytes_hbm=costs.bwd_bytes))
        # Collectives: duration = bytes / link bandwidth; expressed via
        # bytes_hbm=0 and an explicit duration set by the machine model
        # caller through comm-equivalent bytes on the ICI. We encode the
        # wire time directly as `duration` when building with a machine.
        g.add_op(Op(f"rs{l}", OpKind.GPU, flops=0.0, bytes_hbm=0.0,
                    comm_bytes=costs.grad_bytes))
        if zero_sharded and costs.param_gather_bytes:
            g.add_op(Op(f"ag{l}", OpKind.GPU, comm_bytes=
                        costs.param_gather_bytes))
    g.add_op(Op("opt", OpKind.GPU, flops=0.0, bytes_hbm=costs.opt_bytes))

    for l in range(n_layers):
        if l + 1 < n_layers:
            g.add_edge(f"fwd{l}", f"fwd{l + 1}")
        if zero_sharded and costs.param_gather_bytes:
            g.add_edge(f"ag{l}", f"fwd{l}")
            g.add_edge(f"ag{l}", f"bwd{l}")  # params needed again in bwd
        g.add_edge(f"bwd{l}", f"rs{l}")
        g.add_edge(f"rs{l}", "opt")
    g.add_edge(f"fwd{n_layers - 1}", f"bwd{n_layers - 1}")
    for l in range(n_layers - 1, 0, -1):
        g.add_edge(f"bwd{l}", f"bwd{l - 1}")
    return g.finalize()


def with_comm_durations(graph: Graph, link_bytes_per_s: float,
                        latency_s: float = 2e-6) -> Graph:
    """Materialize collective durations (wire time) as fixed op durations.

    The machine model treats GPU-op duration as max(flops, hbm) terms; ICI
    collectives are wire-limited, so we pin duration = latency + B/bw.
    Returns a new Graph with the same structure.
    """
    out = Graph.__new__(Graph)
    out.ops = {}
    out.preds = {k: set(v) for k, v in graph.preds.items()}
    out.succs = {k: set(v) for k, v in graph.succs.items()}
    out.version = 0  # fresh object: caches key on identity + version
    for name, op in graph.ops.items():
        if op.kind is OpKind.GPU and op.comm_bytes:
            dur = latency_s + op.comm_bytes / link_bytes_per_s
            out.ops[name] = dataclasses.replace(op, duration=dur)
        else:
            out.ops[name] = op
    return out
