"""Unified design-space search subsystem.

One strategy protocol (:class:`SearchStrategy`); strategies from
exhaustive enumeration to the surrogate-screened two-stage search and
the greedy→MCTS→surrogate portfolio; a batched + memoized evaluator;
and the :func:`run_search` pipeline that turns any of them into the
(features, labels, times) dataset the rules pipeline consumes. See
README.md in this package for the contract.
"""
from repro.search.evaluator import BatchEvaluator, canonical_key
from repro.search.mcts import MCTSSearch
from repro.search.pipeline import SearchResult, run_search
from repro.search.strategy import (ExhaustiveSearch, GreedyCostModel,
                                   RandomSearch, SearchStrategy,
                                   eligible_items, random_schedule)
from repro.search.surrogate import (PortfolioSearch, RidgeSurrogate,
                                    SurrogateGuided, spearman)

__all__ = [
    "BatchEvaluator", "canonical_key",
    "MCTSSearch",
    "SearchResult", "run_search",
    "ExhaustiveSearch", "GreedyCostModel", "RandomSearch",
    "SearchStrategy", "eligible_items", "random_schedule",
    "PortfolioSearch", "RidgeSurrogate", "SurrogateGuided", "spearman",
]
