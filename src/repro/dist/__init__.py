"""Distribution utilities: logical-axis sharding rules and compressed
data-parallel gradient synchronization."""
