"""Pallas TPU flash attention (forward): blockwise online softmax.

The canonical TPU structure (cf. jax.experimental.pallas.ops.tpu):

  grid = (B*H, Sq/block_q, Skv/block_k)   — kv is the MINOR grid dim,
  so for a fixed (bh, q-block) the kernel visits kv blocks in order,
  carrying the online-softmax state (m, l, acc) in VMEM scratch and
  writing the normalized output on the last kv step. Block shapes are
  MXU-aligned (block_q x d and block_k x d tiles; d is a multiple of
  128 for the assigned archs' head dims or padded by ops.py).

Causal masking is positional (q block offset vs kv block offset), so
fully-masked blocks contribute nothing (and `ops.py` never visits kv
blocks strictly above the diagonal: the kv grid extent is set to the
full Skv, masking handles the rest — a production version would use a
triangular grid; noted in EXPERIMENTS as future perf headroom).

Validated against ref.py in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                block_q: int, block_k: int, n_kv: int, scale: float,
                causal: bool, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale        # (bq, d)
    k = k_ref[0].astype(jnp.float32)                # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if causal:
        q_pos = qi * block_q + \
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
            + q_offset
        k_pos = ki * block_k + \
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    if causal:
        p = jnp.where(k_pos <= q_pos, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret",
                     "scale"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, scale: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Skv, D) — heads pre-flattened.

    Sq/Skv must divide by the block sizes (ops.py pads); causal
    alignment assumes the queries are the LAST Sq positions of the kv
    sequence (standard decode/prefill layout).
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    assert sq % block_q == 0 and skv % block_k == 0
    n_q = sq // block_q
    n_kv = skv // block_k
    # NOTE: when the head dim is lane-padded by ops.py, the true scale
    # must come from the caller (the padded d would skew the softmax).
    scale = d ** -0.5 if scale is None else scale

    return pl.pallas_call(
        functools.partial(
            _flash_body, block_q=block_q, block_k=block_k, n_kv=n_kv,
            scale=scale, causal=causal, q_offset=skv - sq),
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accum
        ],
        interpret=interpret,
    )(q, k, v)
