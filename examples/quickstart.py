"""Quickstart: the paper's pipeline end-to-end in ~30 lines.

  DAG -> MCTS -> labels -> features -> decision tree -> design rules

Usage: PYTHONPATH=src python examples/quickstart.py [--iters 200]
"""
import argparse

import numpy as np

import repro.core as C
from repro.search import MCTSSearch, run_search


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=200)
    args = ap.parse_args()

    # 1. The program: the paper's distributed SpMV, as an op DAG.
    graph = C.spmv_dag()

    # 2. Explore the (ordering x stream assignment) space with MCTS,
    #    scored by the TPU machine model (the "sim" backend).
    result = run_search(graph, MCTSSearch(graph, 2, seed=0),
                        budget=args.iters, batch_size=1)
    times = np.array(result.times)
    print(f"explored {len(result.schedules)} implementations; "
          f"spread {times.max() / times.min():.2f}x "
          f"({times.min() * 1e6:.1f}us .. {times.max() * 1e6:.1f}us)")

    # 3. Class labels from the sorted measurements (Fig. 4).
    labels = C.label_times(times)
    print(f"{labels.n_classes} performance classes, "
          f"sizes {np.bincount(labels.labels).tolist()}")

    # 4. Feature vectors + decision tree (Alg. 1).
    fm = C.featurize(graph, result.schedules)
    tree = C.algorithm1(fm.X, labels.labels)
    print(f"tree: {tree.n_leaves()} leaves, depth {tree.depth()}, "
          f"train error {tree.training_error(fm.X, labels.labels):.3f}")

    # 5. Design rules per performance class (Tables VI-VIII).
    rulesets = C.extract_rulesets(tree, fm.features)
    print()
    print(C.render_rules_table(C.rules_by_class(rulesets), top_k=2))


if __name__ == "__main__":
    main()
