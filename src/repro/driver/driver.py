"""The acquisition-aware search driver: the propose/observe round loop.

:class:`SearchDriver` owns the control path that used to live inline
in ``repro.search.pipeline.run_search``: rounds of

    propose pool -> score pool with an acquisition function
        -> evaluate the chosen batch -> observe -> stream to sinks

against any :class:`~repro.search.strategy.SearchStrategy` and any
evaluation-engine backend. ``run_search`` remains the public entry
point — a thin wrapper constructing a driver with no acquisition
override and no sinks, which is **bit-compatible** with the
pre-driver loop: identical proposal sequence, evaluator traffic,
dedup, budget/stall accounting, and therefore byte-identical
``(features, labels, times)`` for every strategy/backend/seed combo
(locked by tests/test_driver.py).

What the driver adds over the old loop:

* **Acquisition override** (``acquisition=``): for strategies that
  speak the pool protocol
  (:class:`~repro.search.strategy.PoolSearchStrategy` —
  ``SurrogateGuided`` and anything the portfolio delegates to it),
  the driver takes over screening: it asks the strategy for its raw
  candidate pool and ranks it with a named
  :data:`~repro.driver.acquisitions.ACQUISITIONS` entry
  (``argmin_topk`` reproduces the strategy's built-in behavior
  exactly; ``ucb`` / ``expected_improvement`` add uncertainty from
  the boosted ensemble's per-tree variance). Strategies without a
  pool (MCTS, random, exhaustive) ignore the override and propose as
  usual.
* **Sinks** (``sinks=``): every evaluated batch is streamed — with
  its run-level freshness mask — to each attached
  :class:`~repro.driver.sinks.Sink` (``"dataset"`` folds the corpus
  incrementally for streaming distillation; ``"trace"`` records the
  per-round choice stream). Names resolve through
  :func:`~repro.driver.sinks.make_sink`; pre-built objects pass
  through.

Determinism: the driver adds no randomness of its own. Proposal RNG
lives in the strategy, evaluation noise in the evaluator (seeded per
canonical key), and acquisition scoring is a pure function of the
surrogate state — so the same seed and corpus choose the same batch
on every analytic backend (locked by the cross-backend tests).
"""
from __future__ import annotations

import time

import numpy as np

from repro import obs
from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.driver.acquisitions import AcquisitionFn, resolve_acquisition
from repro.driver.sinks import Sink, make_sink
from repro.engine import make_evaluator
from repro.engine.base import EvaluatorBase
from repro.search.pipeline import SearchResult
from repro.search.strategy import PoolSearchStrategy, SearchStrategy
from repro.space.base import DesignSpace, as_space


class SearchDriver:
    """Round-based search loop: propose -> screen -> evaluate -> stream.

    Single-use: construct, :meth:`run` once, read the
    :class:`~repro.search.pipeline.SearchResult`. All parameters
    shared with ``run_search`` keep its exact semantics (see that
    docstring for budget/sim_budget/batch_size/stall_limit); the
    driver-only knobs are ``acquisition`` / ``acquisition_kwargs``
    (registry name or a pre-built ``acq(surrogate, pool, best=)``
    callable), ``sinks`` (registry names or pre-built objects; the
    caller owns sink lifecycle — the driver only ``consume``\\ s), and
    the persistent evaluation store (``store=`` a shared
    :class:`~repro.engine.store.EvalStore` / ``store_path=`` a file
    the evaluator opens and owns) forwarded to the evaluator the
    driver constructs — with it, ``sim_budget`` and the stall detector
    meter fresh evaluations (misses + store hits), so a warm search
    replays the cold trajectory byte-identically at zero measurement
    cost.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 strategy: SearchStrategy,
                 machine: Machine | None = None,
                 budget: int | None = 2000,
                 batch_size: int = 1,
                 evaluator: EvaluatorBase | None = None,
                 backend: str | None = None,
                 backend_kwargs: dict | None = None,
                 sim_budget: int | None = None,
                 stall_limit: int = 1000,
                 store=None,
                 store_path: "str | None" = None,
                 acquisition: "str | AcquisitionFn | None" = None,
                 acquisition_kwargs: dict | None = None,
                 sinks: "tuple | list" = ()):
        if evaluator is not None and machine is not None:
            raise ValueError(
                "pass either machine= or evaluator= (the evaluator "
                "already owns a machine), not both")
        if evaluator is not None and (backend is not None
                                      or backend_kwargs is not None):
            raise ValueError(
                "pass either backend=/backend_kwargs= or a "
                "preconfigured evaluator=, not both")
        if acquisition is None and acquisition_kwargs is not None:
            raise ValueError(
                "acquisition_kwargs requires acquisition=")
        if evaluator is not None and (store is not None
                                      or store_path is not None):
            raise ValueError(
                "pass store=/store_path= only when the driver builds "
                "the evaluator; attach the store to your preconfigured "
                "evaluator= instead")
        if store is not None and store_path is not None:
            raise ValueError("pass store= or store_path=, not both")
        for k in ("store", "store_path"):
            if backend_kwargs and k in backend_kwargs and (
                    store is not None or store_path is not None):
                raise ValueError(
                    f"{k} passed both directly and in backend_kwargs")
        self.space = as_space(graph)
        self.graph = graph
        self.strategy = strategy
        self.machine = machine
        self.budget = budget
        self.batch_size = batch_size
        self.evaluator = evaluator
        self.backend = backend
        self.backend_kwargs = backend_kwargs
        self.store = store
        self.store_path = store_path
        self.sim_budget = sim_budget
        self.stall_limit = stall_limit
        self.acquisition = None if acquisition is None else \
            resolve_acquisition(acquisition, acquisition_kwargs)
        self.sinks: list[Sink] = [
            make_sink(s, self.space) if isinstance(s, str) else s
            for s in sinks]
        self._ran = False
        self._round = 0       # current round index (spans + sinks agree)

    # -- one round's proposal ------------------------------------------
    def _choose(self, ask: int) -> list[Schedule]:
        """The round's batch: acquisition-screened when possible.

        With an acquisition override and a pool-protocol strategy, the
        driver screens the strategy's raw pool itself (the strategy
        still keeps the screening bookkeeping — pending predictions,
        pool counters — so ``screening_quality()`` reports whichever
        acquisition actually ran). Otherwise the strategy's own
        ``propose`` is the whole story, clamped exactly like the
        pre-driver loop.
        """
        s = self.strategy
        if self.acquisition is not None \
                and isinstance(s, PoolSearchStrategy):
            with obs.span("driver.propose", round=self._round):
                pool = s.propose_pool(ask)
            if pool is not None:
                obs.counter("driver.pool_size").add(len(pool))
                with obs.span("driver.acquire", round=self._round,
                              pool=len(pool)):
                    chosen = s.screen(pool, ask, self.acquisition)
                # same over-returning clamp as the propose() path: a
                # screen() that ignores its budget must not overshoot
                return s.pad(chosen, ask)[:ask]
        with obs.span("driver.propose", round=self._round):
            return s.propose(ask)[:ask]

    # -- the loop -------------------------------------------------------
    def run(self) -> SearchResult:
        """Drive the strategy to completion; see ``run_search``."""
        if self._ran:
            raise RuntimeError(
                "SearchDriver is single-use: strategy and sink state "
                "carry across rounds, so re-running would double-count "
                "observations; construct a fresh driver instead")
        self._ran = True
        owns_evaluator = self.evaluator is None
        kwargs = dict(self.backend_kwargs or {})
        if self.store is not None:
            kwargs["store"] = self.store
        if self.store_path is not None:
            kwargs["store_path"] = self.store_path
        ev = self.evaluator if self.evaluator is not None else \
            make_evaluator(self.graph, self.backend or "sim",
                           machine=self.machine, **kwargs)
        budget, batch_size = self.budget, self.batch_size
        sim_budget, stall_limit = self.sim_budget, self.stall_limit
        hits0, misses0 = ev.cache_hits, ev.cache_misses
        store0 = ev.store_hits
        # sim_budget and the stall detector meter *fresh evaluations*
        # (paid measurements + store warm hits), so a search against a
        # warmed persistent store replays the cold run's trajectory —
        # byte-identical results — instead of running unbounded on free
        # lookups. Storeless, fresh == misses: the pre-store semantics.
        fresh0 = ev.fresh_evals()
        schedules: list[Schedule] = []
        times: list[float] = []
        seen: set[bytes] = set()
        n_proposed = 0
        stalled = 0
        # Telemetry is a pure observer: spans/counters/gauges are never
        # read back, so the trajectory is byte-identical with a live
        # registry attached (locked by tests/test_obs.py). The
        # round-by-round summary lands on SearchResult.telemetry only
        # when a registry is enabled — the disabled default pays one
        # flag check per round.
        tel = obs.current()
        rounds_tel: "list[dict] | None" = [] if tel.enabled else None
        best = float("inf")

        try:
            with obs.span("driver.run",
                          strategy=type(self.strategy).__name__,
                          backend=ev.backend):
                while ((budget is None or n_proposed < budget) and
                       (sim_budget is None
                        or ev.fresh_evals() - fresh0 < sim_budget)):
                    ask = batch_size if budget is None else \
                        min(batch_size, budget - n_proposed)
                    round_span = obs.span("driver.round",
                                          round=self._round)
                    round_span.__enter__()
                    try:
                        batch = self._choose(ask)
                        if not batch:
                            break
                        n_proposed += len(batch)
                        batch_fresh0 = ev.fresh_evals()
                        bh0, bs0, bm0 = (ev.cache_hits, ev.store_hits,
                                         ev.cache_misses)
                        ev_t0 = time.perf_counter() if tel.enabled \
                            else 0.0
                        with obs.span("driver.evaluate",
                                      round=self._round, n=len(batch)):
                            eb = ev.evaluate_batch(batch)
                        ev_wall = time.perf_counter() - ev_t0 \
                            if tel.enabled else 0.0
                        fresh = np.zeros(len(eb), dtype=bool)
                        with obs.span("driver.observe",
                                      round=self._round):
                            for i, (schedule, key, t) in enumerate(eb):
                                self.strategy.observe(schedule, float(t))
                                if key not in seen:
                                    seen.add(key)
                                    fresh[i] = True
                                    schedules.append(schedule)
                                    times.append(float(t))
                            for sink in self.sinks:
                                sink.consume(eb, fresh)
                        n_fresh = int(np.count_nonzero(fresh))
                        if tel.enabled:
                            tel.counter("driver.proposed").add(len(batch))
                            tel.counter("driver.fresh").add(n_fresh)
                            tel.counter("driver.fresh_evals").add(
                                ev.fresh_evals() - batch_fresh0)
                            if len(eb) and float(np.min(eb.times)) < best:
                                best = float(np.min(eb.times))
                                tel.gauge("driver.best").set(best)
                            round_span.set(n=len(batch), n_fresh=n_fresh)
                            rounds_tel.append({
                                "round": self._round,
                                "n": len(batch),
                                "n_fresh": n_fresh,
                                "best": best if best < float("inf")
                                else None,
                                "evaluate_s": ev_wall,
                                "memory_hits": ev.cache_hits - bh0,
                                "store_hits": ev.store_hits - bs0,
                                "misses": ev.cache_misses - bm0,
                            })
                        if sim_budget is not None or budget is None:
                            if ev.fresh_evals() == batch_fresh0:
                                stalled += len(batch)
                                if stalled >= stall_limit:
                                    break
                            else:
                                stalled = 0
                    finally:
                        round_span.__exit__(None, None, None)
                    self._round += 1
        finally:
            if owns_evaluator:
                ev.close()

        return SearchResult(graph=getattr(self.space, "graph", None),
                            schedules=schedules,
                            times=times, n_proposed=n_proposed,
                            cache_hits=ev.cache_hits - hits0,
                            cache_misses=ev.cache_misses - misses0,
                            store_hits=ev.store_hits - store0,
                            space=self.space,
                            telemetry=rounds_tel)
