"""The single search entry point: strategy x evaluator -> dataset.

``run_search`` is the one code path behind the paper reproduction
(benchmarks/paper.py), the SpMV baseline, and the LM-step scenario
(examples/schedule_search.py): it drives any :class:`SearchStrategy`
against any evaluation-engine backend (:mod:`repro.engine` —
serial/vectorized/pool/wallclock, selected with ``backend=``) and
collects the deduplicated (schedule, time) observations.
``SearchResult.dataset()`` then emits the (features, labels, times)
triple consumed by the rules distillation subsystem
(:mod:`repro.rules`) — or pass the whole result to
:func:`repro.rules.distill` for the one-call search -> rules report.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import Machine
from repro.core.dag import Graph, Schedule
from repro.core.features import FeatureMatrix, featurize
from repro.core.labels import Labeling, label_times
from repro.engine import make_evaluator
from repro.engine.base import EvaluatorBase
from repro.search.strategy import SearchStrategy


@dataclasses.dataclass
class SearchResult:
    """Deduplicated observations from one search run."""

    graph: Graph
    schedules: list[Schedule]
    times: list[float]
    n_proposed: int
    cache_hits: int
    cache_misses: int

    def best(self) -> tuple[Schedule, float]:
        if not self.schedules:
            raise ValueError(
                "empty search result (budget 0 or strategy proposed "
                "nothing) has no best schedule")
        i = int(np.argmin(self.times))
        return self.schedules[i], self.times[i]

    def times_array(self) -> np.ndarray:
        return np.asarray(self.times, dtype=np.float64)

    def dataset(self) -> tuple[FeatureMatrix, Labeling, np.ndarray]:
        """(features, labels, times) for the rules pipeline."""
        times = self.times_array()
        return (featurize(self.graph, self.schedules),
                label_times(times), times)


def run_search(graph: Graph, strategy: SearchStrategy,
               machine: Machine | None = None,
               budget: int | None = 2000,
               batch_size: int = 1,
               evaluator: EvaluatorBase | None = None,
               backend: str | None = None,
               backend_kwargs: dict | None = None,
               sim_budget: int | None = None,
               stall_limit: int = 1000) -> SearchResult:
    """Drive ``strategy`` for up to ``budget`` evaluations.

    ``budget`` counts proposals (evaluations), not distinct schedules;
    ``None`` means run until the strategy exhausts — or, for
    strategies that never return an empty batch, until ``stall_limit``
    consecutive proposals yield no fresh simulation.
    ``batch_size`` is how many schedules are requested per ``propose``
    call; 1 reproduces the paper's strictly sequential loop (each
    observation lands before the next proposal), larger values trade
    strategy-state freshness for evaluator throughput. A strategy that
    returns more than it was asked for is clamped to the remaining
    budget — the excess is neither evaluated nor counted.

    ``sim_budget`` bounds *discrete-event simulations* (evaluator cache
    misses) instead of proposals: the loop stops once the strategy has
    spent that many distinct simulations. Checked between batches, so a
    batch may overshoot by up to ``batch_size - 1``; use
    ``batch_size=1`` for an exact cap. This is the fair-comparison knob
    for strategies (e.g. surrogate screening) that trade many cheap
    proposals for few expensive simulations. A strategy that never
    exhausts (random rollouts, surrogate padding) makes no progress a
    ``sim_budget`` or ``budget=None`` loop can observe once the space
    runs out of new implementations; whenever the loop is not bounded
    by a proposal ``budget``, ``stall_limit`` therefore breaks it
    after that many consecutive proposals without a single fresh
    simulation.

    ``backend`` selects the evaluation engine by registry name
    (:func:`repro.engine.make_evaluator`: ``"sim"`` (default),
    ``"vectorized"``, ``"pool"``, ``"wallclock"``), with
    ``backend_kwargs`` forwarded to its constructor — e.g.
    ``backend="pool", backend_kwargs={"n_workers": 4}``. All analytic
    backends are bit-identical, so the backend is a pure
    throughput/objective choice. A backend created here is closed when
    the search returns; pass a preconfigured ``evaluator`` instead to
    keep its memo cache alive across runs.

    Every proposal is evaluated and fed back via ``observe``; the result
    keeps the first observation per canonical schedule (matching how the
    paper's MCTS records its rollout set). Pass either ``machine`` or a
    preconfigured ``evaluator`` (which owns its machine), not both (and
    not ``backend`` with ``evaluator`` — the evaluator already *is* a
    backend); a shared evaluator keeps its memo cache across runs, and
    the result's cache counters report this run's traffic only.
    """
    if evaluator is not None and machine is not None:
        raise ValueError(
            "pass either machine= or evaluator= (the evaluator already "
            "owns a machine), not both")
    if evaluator is not None and (backend is not None
                                  or backend_kwargs is not None):
        raise ValueError(
            "pass either backend=/backend_kwargs= or a preconfigured "
            "evaluator=, not both")
    owns_evaluator = evaluator is None
    ev = evaluator if evaluator is not None else \
        make_evaluator(graph, backend or "sim", machine=machine,
                       **(backend_kwargs or {}))
    hits0, misses0 = ev.cache_hits, ev.cache_misses
    schedules: list[Schedule] = []
    times: list[float] = []
    seen: set[tuple] = set()
    n_proposed = 0
    stalled = 0

    try:
        while ((budget is None or n_proposed < budget) and
               (sim_budget is None
                or ev.cache_misses - misses0 < sim_budget)):
            ask = batch_size if budget is None else \
                min(batch_size, budget - n_proposed)
            batch = strategy.propose(ask)[:ask]
            if not batch:
                break
            n_proposed += len(batch)
            batch_misses0 = ev.cache_misses
            for schedule, (key, t) in zip(batch, ev.evaluate_keyed(batch)):
                strategy.observe(schedule, t)
                if key not in seen:
                    seen.add(key)
                    schedules.append(schedule)
                    times.append(t)
            if sim_budget is not None or budget is None:
                if ev.cache_misses == batch_misses0:
                    stalled += len(batch)
                    if stalled >= stall_limit:
                        break
                else:
                    stalled = 0
    finally:
        if owns_evaluator:
            ev.close()

    return SearchResult(graph=graph, schedules=schedules, times=times,
                        n_proposed=n_proposed,
                        cache_hits=ev.cache_hits - hits0,
                        cache_misses=ev.cache_misses - misses0)
