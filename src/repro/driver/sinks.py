"""Streaming result sinks: consume evaluated batches as they land.

The pre-driver pipeline materialized the whole deduplicated schedule
list and re-featurized it from scratch whenever the rules pipeline ran
(``SearchResult.dataset()`` -> ``featurize`` -> the full double
expansion). Sinks invert that: the :class:`~repro.driver.driver.
SearchDriver` streams every evaluated :class:`~repro.engine.base.
EvalBatch` (plus the run-level freshness mask) to each attached sink
*during* the search, so by the time the search returns, the dataset is
already folded.

``dataset`` — :class:`DatasetSink`
    Folds each batch's fresh (first-seen canonical) schedules into an
    incremental :class:`~repro.core.features.FeatureBasis` (schedules
    are sync-expanded exactly once, never re-featurized) and an
    incremental time histogram. ``dataset()`` then emits the same
    ``(features, labels, times)`` triple ``SearchResult.dataset()``
    computes from scratch — byte-identical, locked by test — and
    ``distill()`` hands the streamed matrix straight to
    :func:`repro.rules.distill` (``features=``), skipping the
    re-featurization pass entirely. The doubling histogram is the seed
    of the ROADMAP's out-of-core distillation path: label/split
    statistics folded per batch instead of recomputed per corpus.

``trace`` — :class:`TraceSink`
    Records one row per driver round (canonical keys chosen, fresh
    count, running best) — the determinism probe used by the
    cross-backend acquisition tests and the benchmark race logs.

Sinks implement one method::

    consume(batch: EvalBatch, fresh: np.ndarray) -> None

where ``fresh[i]`` marks the first occurrence of ``batch.keys[i]``
within the driver run (the same dedup that builds
``SearchResult.schedules``). Registered factories are constructed as
``factory(graph, **kwargs)`` via :func:`make_sink`.
"""
from __future__ import annotations

from typing import Callable, Protocol, runtime_checkable

import numpy as np

from repro.core.dag import Graph, Schedule
from repro.core.features import (DegenerateFeatureSpaceError,
                                 FeatureMatrix)
from repro.engine.base import EvalBatch
from repro.space.base import DesignSpace, as_space


@runtime_checkable
class Sink(Protocol):
    """Consumer of evaluated batches streamed by the search driver."""

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        """Fold one evaluated batch (with run-level freshness mask)."""
        ...


class StreamingHistogram:
    """Fixed-width counts over a range that doubles on overflow.

    The incremental form of ``np.histogram``: ``add`` folds a batch
    into ``2 * half_bins`` equal-width bins spanning ``[0, hi)``; when
    a value lands past ``hi`` the range doubles and adjacent bin pairs
    merge (counts are preserved exactly), so the memory footprint is
    constant no matter how many observations stream through. This is
    the label-histogram seed for out-of-core distillation: class
    boundaries can be estimated from the folded counts without holding
    every observation.
    """

    def __init__(self, half_bins: int = 128):
        if half_bins < 1:
            raise ValueError("half_bins must be >= 1")
        self.n_bins = 2 * half_bins
        self.counts = np.zeros(self.n_bins, dtype=np.int64)
        self.hi = 0.0                      # upper edge; 0 = no data yet

    def add(self, values: np.ndarray) -> None:
        v = np.asarray(values, dtype=np.float64)
        if v.size == 0:
            return
        if np.any(v < 0.0):
            raise ValueError("times must be non-negative")
        vmax = float(v.max())
        if self.hi == 0.0:
            self.hi = vmax * 2.0 if vmax > 0.0 else 1.0
        while vmax >= self.hi:
            # Doubling merges adjacent bin pairs: counts are preserved
            # exactly, and because the doubled edges coincide with
            # every second old edge (scaling by 2 is exact in binary
            # floating point), the merged counts are exactly what
            # np.histogram would produce over the new edges.
            self.counts = (self.counts[0::2] + self.counts[1::2])
            self.counts = np.concatenate(
                [self.counts, np.zeros(self.n_bins // 2, np.int64)])
            self.hi *= 2.0
        idx = np.minimum((v / self.hi * self.n_bins).astype(np.int64),
                         self.n_bins - 1)
        # np.histogram's boundary correction: the scaled floor can land
        # one bin off when v sits within a rounding error of an edge;
        # nudge against the actual edges so counts match np.histogram
        # on edges() bin for bin.
        edges = self.edges()
        idx[v < edges[idx]] -= 1
        idx[(v >= edges[idx + 1]) & (idx != self.n_bins - 1)] += 1
        np.add.at(self.counts, idx, 1)

    @property
    def n(self) -> int:
        return int(self.counts.sum())

    def edges(self) -> np.ndarray:
        """Bin edges, ``np.histogram`` convention (n_bins + 1 values)."""
        return np.linspace(0.0, self.hi, self.n_bins + 1)


class DatasetSink:
    """Incremental ``(features, labels, times)`` accumulator.

    Mirrors the ``SearchResult`` dedup contract — the first observation
    per canonical schedule, in first-appearance order — so
    :meth:`dataset` is byte-identical to ``SearchResult.dataset()``
    while featurizing each schedule exactly once, the round it arrives.
    """

    def __init__(self, graph: "Graph | DesignSpace",
                 half_bins: int = 128):
        self.space = as_space(graph)
        self.graph = getattr(self.space, "graph", None)
        self.basis = self.space.feature_basis()
        self.schedules: list[Schedule] = []
        self.times: list[float] = []
        self.histogram = StreamingHistogram(half_bins=half_bins)
        self.n_consumed = 0                # every evaluation, dups too
        self._seen: set[bytes] = set()     # sink-lifetime dedup

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        self.n_consumed += len(batch)
        # The fresh mask is *per driver run*; the sink keeps its own
        # canonical-key set so one sink fed by several runs (e.g. over
        # a shared memoized evaluator) still holds each implementation
        # exactly once.
        idx = [i for i, (k, f) in enumerate(zip(batch.keys, fresh))
               if f and k not in self._seen]
        if not idx:
            return
        self._seen.update(batch.keys[i] for i in idx)
        new = [batch.schedules[i] for i in idx]
        self.basis.add(new)
        self.schedules.extend(new)
        t_new = np.asarray(batch.times)[idx]
        self.times.extend(float(t) for t in t_new)
        self.histogram.add(t_new)

    # -- the streamed corpus -------------------------------------------
    def times_array(self) -> np.ndarray:
        return np.asarray(self.times, dtype=np.float64)

    def matrix(self) -> FeatureMatrix:
        """Constant-pruned feature matrix of everything streamed so far.

        Same contract as :func:`repro.core.features.featurize`
        (including :class:`DegenerateFeatureSpaceError` on a corpus
        with no discriminating features) — but the expansion work was
        already paid batch by batch.
        """
        fm = self.basis.matrix()
        if not fm.features:
            raise DegenerateFeatureSpaceError(
                f"streamed corpus of {len(self.schedules)} schedule(s) "
                "has no discriminating features after constant-column "
                "pruning; at least 2 distinct schedules are required")
        return fm

    def dataset(self):
        """(features, labels, times) — ``SearchResult.dataset()`` shape."""
        from repro.rules.labels import label_times
        times = self.times_array()
        return self.matrix(), label_times(times), times

    def distill(self, **kwargs):
        """:func:`repro.rules.distill` on the streamed corpus.

        Passes the incrementally-built matrix via ``features=`` so the
        rules pipeline never re-featurizes the schedule list.
        """
        from repro.rules.pipeline import distill
        return distill(self, features=self.matrix(), **kwargs)


class TraceSink:
    """Per-round trace: what was chosen, what was fresh, running best.

    ``rounds[i]`` is a dict with ``round`` (the 0-based round index —
    the driver calls each sink exactly once per round, so this is the
    same numbering the driver's ``driver.round`` telemetry spans
    carry), ``keys`` (canonical cache keys of the round's batch, in
    proposal order), ``n_fresh``, and ``best`` (the minimum time
    observed up to and including that round). Canonical keys make
    traces comparable across evaluation backends — the cross-backend
    determinism tests assert exact equality of the key streams.
    """

    def __init__(self, graph: "Graph | DesignSpace | None" = None):
        self.rounds: list[dict] = []
        self._best = float("inf")

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        if len(batch):
            self._best = min(self._best, float(np.min(batch.times)))
        self.rounds.append({
            "round": len(self.rounds),
            "keys": tuple(batch.keys),
            "n_fresh": int(np.count_nonzero(fresh)),
            "best": self._best,
        })

    def key_stream(self, rounds: bool = False) -> tuple:
        """All chosen canonical keys, round-concatenated (for equality).

        The default shape is unchanged (a flat tuple of keys);
        ``rounds=True`` pairs every key with its round index —
        ``((round, key), ...)`` — so consumers can line the choice
        stream up against round-indexed telemetry spans.
        """
        if rounds:
            return tuple((r["round"], k)
                         for r in self.rounds for k in r["keys"])
        return tuple(k for r in self.rounds for k in r["keys"])


class TelemetrySink:
    """The obs-backed sink: stream per-round markers into the active
    telemetry registry (:mod:`repro.obs`).

    Emits one ``sink.round`` instant event per consumed batch (with
    the same 0-based round numbering as :class:`TraceSink` and the
    driver's ``driver.round`` spans — each sink sees exactly one
    ``consume`` per round), bumps the ``sink.consumed`` /
    ``sink.fresh`` counters, and tracks the running best as the
    ``sink.best`` gauge. Registered as ``"telemetry"`` in
    :data:`SINKS`, so ``SearchDriver(..., sinks=["telemetry"])`` puts
    round markers in a trace without any bespoke sink code. A no-op
    under the disabled default registry.
    """

    def __init__(self, graph: "Graph | DesignSpace | None" = None):
        self.n_rounds = 0
        self._best = float("inf")

    def consume(self, batch: EvalBatch, fresh: np.ndarray) -> None:
        from repro import obs
        tel = obs.current()
        if tel.enabled:
            n_fresh = int(np.count_nonzero(fresh))
            if len(batch):
                self._best = min(self._best,
                                 float(np.min(batch.times)))
            tel.event("sink.round", round=self.n_rounds, n=len(batch),
                      n_fresh=n_fresh,
                      best=self._best if self._best < float("inf")
                      else None)
            tel.counter("sink.consumed").add(len(batch))
            tel.counter("sink.fresh").add(n_fresh)
            if self._best < float("inf"):
                tel.gauge("sink.best").set(self._best)
        self.n_rounds += 1


# -- the registry -------------------------------------------------------------

SINKS: dict[str, Callable[..., Sink]] = {}
"""Sink factories: name -> ``factory(graph, **kwargs) -> sink``."""


def register_sink(name: str, factory: Callable[..., Sink]) -> None:
    """Add a sink factory to the :data:`SINKS` registry."""
    SINKS[name] = factory


register_sink("dataset", DatasetSink)
register_sink("trace", TraceSink)
register_sink("telemetry", TelemetrySink)


def make_sink(sink: str, graph: "Graph | DesignSpace",
              **kwargs) -> Sink:
    """Construct a sink by registry name."""
    try:
        factory = SINKS[sink]
    except KeyError:
        raise ValueError(
            f"unknown sink {sink!r}; registered: {sorted(SINKS)}"
        ) from None
    return factory(graph, **kwargs)
