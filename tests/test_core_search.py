"""Cost model, MCTS, and the end-to-end paper pipeline."""
import numpy as np
import pytest

import repro.core as C
from repro.search import MCTSSearch, run_search


def _mcts_run(g, iterations, seed):
    """Sequential MCTS + analytic objective (the paper's §III-C loop).

    ``batch_size=1`` makes ``run_search`` propose-observe strictly
    sequentially, which is sequence-identical to the historical
    ``core.MCTS(...).run(iterations)`` wrapper this replaced.
    """
    strategy = MCTSSearch(g, 2, seed=seed)
    res = run_search(g, strategy, budget=iterations, batch_size=1)
    return strategy, res


@pytest.fixture(scope="module")
def spmv():
    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    times = np.array([C.makespan(g, s) for s in scheds])
    return g, scheds, times


def test_costmodel_deterministic(spmv):
    g, scheds, times = spmv
    again = np.array([C.makespan(g, s) for s in scheds])
    np.testing.assert_array_equal(times, again)


def test_costmodel_spread_matches_paper_scale(spmv):
    """Paper Fig. 1: 1.47x fastest-to-slowest on their space; ours is
    the same DAG at the same granularity — expect a comparable spread."""
    _, _, times = spmv
    spread = times.max() / times.min()
    assert 1.2 < spread < 2.5, spread


def test_costmodel_overlap_beats_serialization(spmv):
    g, scheds, times = spmv
    best = scheds[int(np.argmin(times))]
    worst = scheds[int(np.argmax(times))]
    # The fastest schedule overlaps the local multiply with the halo
    # exchange: Pack must be scheduled before yL delays PostSend.
    border = best.order()
    assert border.index("PostSend") < border.index("yR")
    assert times.max() > times.min()
    # Worst schedules serialize comm behind compute on one stream.
    assert C.makespan(g, worst) >= C.makespan(g, best)


def test_mcts_full_exploration(spmv):
    g, scheds, times = spmv
    m, res = _mcts_run(g, 10_000, seed=3)
    assert m.root.fully_explored
    assert len(res.schedules) == len(scheds)
    assert np.isclose(min(res.times), times.min())
    assert np.isclose(max(res.times), times.max())


def test_mcts_partial_run_unique_and_valid(spmv):
    g, _, _ = spmv
    _, res = _mcts_run(g, 60, seed=0)
    keys = {s.key() for s in res.schedules}
    assert len(keys) == len(res.schedules)
    for s in res.schedules:
        C.validate_schedule(g, s)


def test_mcts_backprop_ranges(spmv):
    g, _, _ = spmv
    m, res = _mcts_run(g, 50, seed=1)
    assert m.root.t_min == min(res.times)
    assert m.root.t_max == max(res.times)
    for child in m.root.children.values():
        assert child.t_min >= m.root.t_min - 1e-12
        assert child.t_max <= m.root.t_max + 1e-12


def test_table5_accuracy_improves_with_iterations(spmv):
    """Paper Table V: class-range accuracy rises with MCTS budget."""
    g, scheds, times = spmv
    accs = []
    for iters in (25, 100, 400):
        _, res = _mcts_run(g, iters, seed=1)
        lab = C.label_times(np.array(res.times))
        fm = C.featurize(g, res.schedules)
        tree = C.algorithm1(fm.X, lab.labels)
        Xf = C.featurize_like(g, scheds, fm)
        accs.append(C.class_range_accuracy(
            tree, Xf, times, lab.class_ranges()))
    assert accs[-1] >= accs[0]
    assert accs[-1] >= 0.95


def test_end_to_end_rules_pipeline(spmv):
    g, scheds, times = spmv
    lab = C.label_times(times)
    fm = C.featurize(g, scheds)
    tree = C.algorithm1(fm.X, lab.labels)
    assert tree.training_error(fm.X, lab.labels) == 0.0
    rulesets = C.extract_rulesets(tree, fm.features)
    assert rulesets and all(rs.rules for rs in rulesets)
    grouped = C.rules_by_class(rulesets)
    assert set(grouped) == set(range(lab.n_classes))
    # canonical self-annotation: every canonical set is consistent
    C.annotate_vs_canonical(rulesets, rulesets)
    assert not any(rs.insufficient for rs in rulesets if rs.pure)
    text = C.render_rules_table(grouped)
    assert "before" in text or "stream" in text


def test_halo3d_future_work_dag():
    """Paper §VI names 3-D halo exchange as the next target; the DAG
    builder + multi-channel cost model support it (examples/halo3d.py)."""
    from repro.core.dag import halo3d_dag
    g = halo3d_dag()
    assert g.n_vertices() == 39  # 6 faces x 6 ops + Inner + start/end
    _, res = _mcts_run(g, 120, seed=0)
    for s in res.schedules[:20]:
        C.validate_schedule(g, s)
    times = np.array(res.times)
    assert times.max() / times.min() > 1.2  # schedule matters
    lab = C.label_times(times)
    fm = C.featurize(g, res.schedules)
    tree = C.algorithm1(fm.X, lab.labels)
    assert tree.training_error(fm.X, lab.labels) <= 0.05
