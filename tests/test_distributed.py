"""Multi-device tests (shard_map SpMV, compressed DP sync, elastic
re-mesh, dry-run cell builder). These need >1 device, so each runs in a
subprocess with XLA_FLAGS set before jax initializes — the main test
process keeps the default single device (per the launch-layer rule)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_spmv_matches_oracle():
    run_sub("""
import numpy as np, jax
from jax.sharding import Mesh
from repro.spmv.matrix import band_matrix, partition, stack_partitions
from repro.spmv.distributed import make_distributed_spmv
A = band_matrix(n=1024, nnz=8192, half_bandwidth=256, seed=1)
x = np.random.default_rng(2).standard_normal(1024).astype(np.float32)
parts = partition(A, 4)
st = stack_partitions(parts)
mesh = Mesh(np.array(jax.devices()[:4]), ("ranks",))
ref = A.matvec(x)
for uk in (False, True):
    run = make_distributed_spmv(mesh, use_kernel=uk)
    y = np.asarray(run(st["local_vals"], st["local_cols"],
                       st["remote_vals"], st["remote_cols"],
                       x.reshape(4, 256))).reshape(-1)
    err = np.abs(y - ref).max() / np.abs(ref).max()
    assert err < 1e-5, (uk, err)
print("OK")
""", devices=4)


def test_compressed_dp_sync_bounded_error():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp, functools
from jax.sharding import Mesh, PartitionSpec as P
from repro.dist.compat import shard_map
from repro.dist.compress import compressed_psum_mean, init_ef, psum_mean
mesh = Mesh(np.array(jax.devices()), ("data",))
g_local = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64) / 1000.0

@functools.partial(shard_map, mesh=mesh,
                   in_specs=(P("data"), P("data")),
                   out_specs=(P("data"), P("data")), check_vma=False)
def sync(g, e):
    out, new_e = compressed_psum_mean({"w": g[0]}, {"w": e[0]}, "data")
    return out["w"][None], new_e["w"][None]

e0 = jnp.zeros((8, 64), jnp.float32)
synced, ef = sync(g_local, e0)
exact = np.asarray(g_local).mean(axis=0)
got = np.asarray(synced)[0]
rel = np.abs(got - exact).max() / (np.abs(exact).max() + 1e-9)
assert rel < 1e-2, rel
# error feedback holds the quantization residual
assert np.abs(np.asarray(ef)).max() > 0
print("OK")
""")


def test_elastic_remesh_resharding():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.ft.elastic import degraded_mesh, remesh_state
from repro.dist.sharding import tree_shardings
devs = np.array(jax.devices()).reshape(4, 2)
mesh = Mesh(devs, ("data", "model"))
state = {"w": jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)}
axes = {"w": ("batch", "d_ff")}
sh = tree_shardings(axes, mesh, None,
                    jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape,
                                 a.dtype), state))
state = jax.tree.map(jax.device_put, state, sh)
# lose 2 devices -> (2, 2) mesh, reshard
new_mesh = degraded_mesh(devs, ("data", "model"), lost=2)
assert new_mesh.devices.shape == (3, 2)
out = remesh_state(state, axes, new_mesh)
np.testing.assert_array_equal(np.asarray(out["w"]),
                              np.asarray(state["w"]))
print("OK")
""")


def test_dryrun_cell_builder_small_mesh():
    """build_cell + lower + compile on an 8-device (2x4) mesh with a
    reduced arch config — the same code path the 512-device dry-run
    exercises, kept cheap for CI."""
    run_sub("""
import numpy as np, jax
import dataclasses
from jax.sharding import Mesh
import repro.launch.inputs as inputs
import repro.configs as cfgs
from repro.launch import hlo as H

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

# monkeypatch: reduced config + tiny shape cell
from repro.configs.shapes import SHAPES, ShapeCell
SHAPES["tiny_train"] = ShapeCell("tiny_train", 64, 8, "train")
SHAPES["tiny_decode"] = ShapeCell("tiny_decode", 64, 8, "decode")
real_get = cfgs.get_config
cfgs.get_config = lambda name: cfgs.get_reduced(name)
inputs.cfgs = cfgs

for arch in ("granite-3-8b", "deepseek-moe-16b", "jamba-v0.1-52b"):
    for shape in ("tiny_train", "tiny_decode"):
        cell = inputs.build_cell(arch, shape, mesh)
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        compiled = jitted.lower(*cell.args).compile()
        assert compiled.memory_analysis() is not None
        a = H.analyze(compiled.as_text())
        assert a.dot_flops > 0
        print(arch, shape, "OK")
""")


def test_production_mesh_multi_pod_shapes():
    run_sub("""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.devices.shape == (16, 16)
assert m1.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
assert m2.axis_names == ("pod", "data", "model")
print("OK")
""", devices=512)
