"""Kernel + evaluator microbenchmarks (interpret-mode wall clock on
CPU; the numbers calibrate relative costs, not TPU throughput)."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.bench import measure
from repro.kernels.pack import ops as pack_ops
from repro.kernels.spmv import ops as spmv_ops
from repro.spmv.matrix import band_matrix


def kernel_benches() -> list[str]:
    rows = []
    A = band_matrix(n=4096, nnz=32768, half_bandwidth=1024, seed=0)
    x = np.random.default_rng(0).standard_normal(4096).astype(np.float32)
    va, ca, xa = (jnp.asarray(A.vals), jnp.asarray(A.cols),
                  jnp.asarray(x))

    t = measure(lambda: spmv_ops.ell_matvec(va, ca, xa).block_until_ready())
    rows.append(f"kernel_ell_matvec_4k,{t * 1e6:.1f},interpret")
    t = measure(lambda: spmv_ops.ell_matvec_ref(va, ca, xa)
                .block_until_ready())
    rows.append(f"kernel_ell_matvec_ref_4k,{t * 1e6:.1f},oracle")

    idx = jnp.asarray(
        np.random.default_rng(1).integers(0, 4096, 1024).astype(np.int32))
    t = measure(lambda: pack_ops.pack(xa, idx).block_until_ready())
    rows.append(f"kernel_pack_1k,{t * 1e6:.1f},interpret")
    t = measure(lambda: pack_ops.pack_ref(xa, idx).block_until_ready())
    rows.append(f"kernel_pack_ref_1k,{t * 1e6:.1f},oracle")
    return rows


def search_eval_benches() -> list[str]:
    """Cost-model evaluation throughput on the SpMV baseline: the
    unified pipeline's batched+memoized evaluator vs the naive
    per-schedule loop it replaced, plus end-to-end search rates."""
    import repro.core as C
    import repro.search as S

    g = C.spmv_dag()
    scheds = list(C.enumerate_schedules(g, 2))
    rows = []

    t0 = time.perf_counter()
    naive = [C.makespan(g, s) for s in scheds]
    t_naive = (time.perf_counter() - t0) / len(scheds)
    rows.append(f"search_eval_naive,{t_naive * 1e6:.2f},"
                f"{1.0 / t_naive:.0f}_scheds_per_s")

    ev = S.BatchEvaluator(g)
    t0 = time.perf_counter()
    batched = ev.evaluate(scheds)
    t_batch = (time.perf_counter() - t0) / len(scheds)
    assert batched == naive  # bit-identical (tests lock this in too)
    rows.append(f"search_eval_batched,{t_batch * 1e6:.2f},"
                f"{1.0 / t_batch:.0f}_scheds_per_s")

    t0 = time.perf_counter()
    ev.evaluate(scheds)  # second sweep: pure transposition-cache hits
    t_hit = (time.perf_counter() - t0) / len(scheds)
    rows.append(f"search_eval_cached,{t_hit * 1e6:.2f},"
                f"{1.0 / t_hit:.0f}_scheds_per_s")

    t0 = time.perf_counter()
    res = S.run_search(g, S.RandomSearch(g, 2, seed=0), budget=2000,
                       batch_size=64)
    t_rand = (time.perf_counter() - t0) / res.n_proposed
    rows.append(f"search_random_pipeline,{t_rand * 1e6:.2f},"
                f"hit_rate={res.cache_hits / res.n_proposed:.2f}")

    port = S.PortfolioSearch(g, 2, seed=0)
    t0 = time.perf_counter()
    res = S.run_search(g, port, budget=2000)
    t_port = (time.perf_counter() - t0) / res.n_proposed
    q = port.screening_quality()
    rows.append(f"search_portfolio_pipeline,{t_port * 1e6:.2f},"
                f"screened={q['n_screened']}/rho={q['spearman']:.2f}")
    return rows


def model_benches() -> list[str]:
    """Reduced-arch step wall-clock: train + decode per arch family."""
    import jax
    from repro.configs import get_reduced
    from repro.data.pipeline import DataConfig, batch_for
    from repro.models.model import LM
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step

    rows = []
    for arch in ("smollm-360m", "deepseek-moe-16b", "rwkv6-3b",
                 "jamba-v0.1-52b"):
        cfg = get_reduced(arch)
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = AdamW(learning_rate=1e-3)
        ostate = opt.init(params)
        dcfg = DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab)
        step = jax.jit(make_train_step(m, opt))
        batch = batch_for(dcfg, 0, cfg)

        def run():
            out = step(params, ostate, batch)
            jax.block_until_ready(out[2]["loss"])

        t = measure(run)
        rows.append(f"train_step_{arch},{t * 1e6:.1f},reduced-cfg")
    return rows
