"""moonshot-v1-16b-a3b [moe]: kimi/moonlight, 64 routed top-6 + 2 shared
[hf:moonshotai/Moonlight-16B-A3B]."""
from repro.models.config import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, mlp="swiglu",
    moe=MoeConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
)

REDUCED = ModelConfig(
    name="moonshot-v1-16b-a3b-reduced", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=512, mlp="swiglu",
    moe=MoeConfig(capacity_factor=8.0, n_experts=8, top_k=2, n_shared=1, d_expert=96),
)
