"""Benchmark harness — one function per paper table/figure, plus kernel
and substrate microbenches. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

from benchmarks.kernels_bench import (kernel_benches, model_benches,
                                      search_eval_benches)
from benchmarks.paper import (fig1_spread, fig4_labels, fig5_tree,
                              granularity_ablation, noise_robustness,
                              stepdag_overlap, table5_accuracy,
                              tables678_rules)


def main() -> None:
    print("name,us_per_call,derived")
    for fn in (fig1_spread, fig4_labels, fig5_tree, table5_accuracy,
               tables678_rules, stepdag_overlap, granularity_ablation,
               noise_robustness, search_eval_benches, kernel_benches,
               model_benches):
        for row in fn():
            print(row, flush=True)


if __name__ == "__main__":
    main()
