"""3-D halo exchange — the paper's named future-work target (§VI),
implemented: per-face Pack/Send/Recv/Wait/boundary-update vertices, an
overlap-friendly Inner bulk update, MCTS over (order x stream) with the
TPU machine model, and decision-tree design rules.

Usage: PYTHONPATH=src python examples/halo3d.py [--iters 1500]
"""
import argparse

import numpy as np

import repro.core as C
from repro.core.dag import halo3d_dag
from repro.search import MCTSSearch, run_search


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=1500)
    ap.add_argument("--streams", type=int, default=2)
    args = ap.parse_args()

    graph = halo3d_dag()
    print(f"3-D halo DAG: {graph.n_vertices()} vertices "
          f"({len(graph.gpu_ops())} GPU ops, 6 faces + Inner)")

    res = run_search(graph, MCTSSearch(graph, args.streams, seed=0),
                     budget=args.iters, batch_size=1)
    times = np.array(res.times)
    best = res.schedules[int(np.argmin(times))]
    print(f"explored {len(res.schedules)} schedules; "
          f"spread {times.max() / times.min():.2f}x "
          f"({times.min() * 1e6:.1f}..{times.max() * 1e6:.1f} us)")

    # Where does Inner land in the best schedule? (the overlap window)
    order = best.order()
    n_before = sum(1 for n in order[:order.index("Inner")]
                   if n.startswith("PostSend"))
    print(f"best schedule posts {n_before}/6 sends before launching "
          f"Inner (communication window opened first)")

    labels = C.label_times(times)
    fm = C.featurize(graph, res.schedules)
    tree = C.algorithm1(fm.X, labels.labels)
    rulesets = C.extract_rulesets(tree, fm.features)
    print(f"\n{labels.n_classes} classes; design rules:")
    print(C.render_rules_table(C.rules_by_class(rulesets), top_k=1))


if __name__ == "__main__":
    main()
